"""AOT lowering: HLO text emission sanity (full PJRT round-trip is covered
by the Rust integration test rust/tests/runtime_artifacts.rs)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.kernels import ref


def test_hlo_text_emission_and_integer_dataflow():
    spec = jax.ShapeDtypeStruct((16, 8), jnp.float32)
    lowered = jax.jit(aot.int_attention_f32).lower(spec, spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # The integer dataflow is visible in the lowered module: s8 quantized
    # inputs, s32 accumulators, a u8 probability tensor, and no exponential
    # op anywhere (the LUT is baked in as a 32-byte constant).
    assert "s8" in text
    assert "s32" in text
    assert "u8" in text
    assert "exponential" not in text, "IndexSoftmax must not lower to exp()"


def test_float_oracle_hlo_has_exponential():
    spec = jax.ShapeDtypeStruct((16, 8), jnp.float32)
    lowered = jax.jit(aot.float_attention_f32).lower(spec, spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "exponential" in text  # the detour the paper removes

def test_index_softmax_f32_wrapper_matches_ref():
    rng = np.random.default_rng(0)
    logits = rng.integers(-20000, 20000, size=(8, 32)).astype(np.float32)
    alpha = np.array([0.002], dtype=np.float32)
    (p,) = jax.jit(aot.index_softmax_f32)(jnp.asarray(logits),
                                          jnp.asarray(alpha))
    want = ref.index_softmax_ref(jnp.asarray(logits, dtype=jnp.int32),
                                 jnp.float32(0.002))
    np.testing.assert_allclose(np.asarray(p) * 255.0, np.asarray(want),
                               atol=1e-4)
