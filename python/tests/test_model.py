"""L2 model tests: shapes, causality, layout parity with the Rust loader."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

SMALL = dict(vocab=32, d_model=16, n_layers=2, n_heads=2, max_seq=32,
             mlp_mult=2)


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), SMALL)


class TestForward:
    def test_shapes(self, params):
        tokens = jnp.arange(8) % 32
        logits = model.forward(params, tokens, SMALL)
        assert logits.shape == (8, 32)
        assert bool(jnp.isfinite(logits).all())

    def test_causality(self, params):
        """Changing a future token must not change earlier logits."""
        t1 = jnp.array([1, 2, 3, 4, 5])
        t2 = jnp.array([1, 2, 3, 4, 29])
        l1 = model.forward(params, t1, SMALL)
        l2 = model.forward(params, t2, SMALL)
        np.testing.assert_allclose(np.asarray(l1[:4]), np.asarray(l2[:4]),
                                   atol=1e-5)
        assert not np.allclose(np.asarray(l1[4]), np.asarray(l2[4]))

    def test_int_attention_mode_close_to_float(self, params):
        tokens = jnp.arange(12) % 32
        lf = np.asarray(model.forward(params, tokens, SMALL, attention="float"))
        li = np.asarray(model.forward(params, tokens, SMALL, attention="int"))
        cos = (lf * li).sum() / (np.linalg.norm(lf) * np.linalg.norm(li))
        assert cos > 0.98, cos

    def test_loss_positive_and_near_uniform_at_init(self, params):
        tokens = jnp.arange(16) % 32
        loss = float(model.loss_fn(params, tokens, SMALL))
        assert 1.0 < loss < 6.0  # ln(32) = 3.47 for uniform

    def test_gradients_flow(self, params):
        tokens = jnp.arange(10) % 32
        grads = jax.grad(model.loss_fn)(params, tokens, SMALL)
        gnorm = float(jnp.sqrt(sum(
            jnp.sum(g * g) for g in jax.tree.leaves(grads))))
        assert gnorm > 0.0 and np.isfinite(gnorm)


class TestLayout:
    def test_param_count_matches_flat(self, params):
        flat = model.to_flat(params, SMALL)
        assert flat.shape[0] == model.param_count(SMALL)

    def test_flat_order_starts_with_embeddings(self, params):
        flat = np.asarray(model.to_flat(params, SMALL))
        emb = np.asarray(params["tok_emb"]).ravel()
        np.testing.assert_array_equal(flat[:emb.size], emb)

    def test_unflatten_roundtrip(self, params):
        from compile.aot import unflatten
        flat = np.asarray(model.to_flat(params, SMALL))
        back = unflatten(flat, SMALL)
        for k in ("tok_emb", "pos_emb", "ln_f_g"):
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(params[k]))
        np.testing.assert_array_equal(
            np.asarray(back["blocks"][1]["w2"]),
            np.asarray(params["blocks"][1]["w2"]))

    def test_default_config_param_count(self):
        # ~0.9M params for the shipped tiny config.
        n = model.param_count(model.CONFIG)
        assert 800_000 < n < 1_200_000, n
