"""Trainer components: corpus, Adam, loss descent on a few steps."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, train

SMALL = dict(vocab=256, d_model=32, n_layers=1, n_heads=2, max_seq=64,
             mlp_mult=2)


def test_corpus_deterministic_and_byte_clean():
    a = train.synthetic_corpus(5000, seed=1)
    b = train.synthetic_corpus(5000, seed=1)
    assert a == b and len(a) == 5000
    toks = train.encode(a)
    assert toks.min() >= 0 and toks.max() < 256
    assert "=" in a


def test_adam_moves_params_toward_lower_loss():
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, SMALL)
    opt = train.adam_init(params)
    text = train.synthetic_corpus(20_000, seed=3)
    data = train.encode(text)
    rng = np.random.default_rng(0)

    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, b: model.batched_loss(p, b, SMALL)))

    def batch():
        starts = rng.integers(0, len(data) - 33, size=4)
        return jnp.stack([jnp.asarray(data[s:s + 32]) for s in starts])

    first, _ = loss_grad(params, batch())
    losses = []
    for _ in range(30):
        loss, grads = loss_grad(params, batch())
        params, opt = adam_step(params, grads, opt)
        losses.append(float(loss))
    # Loss must descend measurably within 30 steps on structured text.
    assert np.mean(losses[-5:]) < float(first) - 0.2, (float(first), losses[-5:])


def adam_step(params, grads, opt):
    return train.adam_update(params, grads, opt, lr=3e-3)
