"""Pallas kernels vs pure-jnp oracles -- the core L1 correctness signal.

The IndexSoftmax/IntAttention kernels must be *bit-exact* against the
integer reference (same eq. 7-15 arithmetic), and the full pipeline must
track the FP32 attention oracle closely. Hypothesis sweeps shapes, dtypes
ranges and hyperparameters.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import index_softmax as ks
from compile.kernels import int_attention as ka
from compile.kernels import ref


def rand_logits(rng, m, l, spread):
    return jnp.asarray(rng.integers(-spread, spread + 1, size=(m, l)),
                       dtype=jnp.int32)


class TestLut:
    def test_default_lut_is_32_bytes(self):
        lut = ref.build_lut_u8()
        assert lut.shape == (32,)
        assert lut.dtype == jnp.uint8
        assert int(lut[0]) == 255 and int(lut[-1]) == 0

    def test_lut_monotone(self):
        lut = np.asarray(ref.build_lut_u8())
        assert (np.diff(lut.astype(np.int32)) <= 0).all()

    @pytest.mark.parametrize("b", [2, 3, 4, 5, 6, 8])
    def test_lut_matches_formula(self, b):
        lut = np.asarray(ref.build_lut_u8(b=b))
        n = 1 << b
        for i in range(n - 1):
            expect = round(255 * np.exp(-6.6 * i / (n - 1)))
            assert lut[i] == expect


class TestQuantize:
    def test_scale_formula(self):
        x = jnp.array([[0.0, -2.54, 1.0]])
        q, s = ref.quantize_i8_ref(x)
        assert abs(float(s) - 2.54 / 127.0) < 1e-7
        assert int(q[0, 1]) == -127

    def test_zero_tensor(self):
        q, s = ref.quantize_i8_ref(jnp.zeros((4, 4)))
        assert float(s) == 1.0
        assert not np.asarray(q).any()

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_error_half_step(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(8, 16)), dtype=jnp.float32)
        q, s = ref.quantize_i8_ref(x)
        back = q.astype(jnp.float32) * s
        assert float(jnp.max(jnp.abs(x - back))) <= float(s) / 2 + 1e-6


class TestIndexSoftmaxKernel:
    """Pallas kernel == integer reference, bit for bit."""

    @given(
        m=st.integers(1, 48),
        l=st.integers(1, 96),
        spread=st.integers(1, 50_000),
        alpha=st.floats(1e-5, 0.3),
        seed=st.integers(0, 2**32 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_bit_exact_vs_ref(self, m, l, spread, alpha, seed):
        rng = np.random.default_rng(seed)
        logits = rand_logits(rng, m, l, spread)
        got = ks.index_softmax(logits, jnp.float32(alpha))
        want = ref.index_softmax_ref(logits, jnp.float32(alpha))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("b,c", [(2, 6.6), (4, 4.4), (5, 6.6), (6, 8.8)])
    def test_hyperparameters_sweep(self, b, c):
        rng = np.random.default_rng(7)
        logits = rand_logits(rng, 16, 64, 10_000)
        got = ks.index_softmax(logits, jnp.float32(0.002), b=b, c=c)
        want = ref.index_softmax_ref(logits, jnp.float32(0.002), b=b, c=c)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_blocking_invariance(self):
        """Different block_q grids must not change the result."""
        rng = np.random.default_rng(3)
        logits = rand_logits(rng, 100, 64, 20_000)
        a = ks.index_softmax(logits, jnp.float32(0.001), block_q=16)
        b = ks.index_softmax(logits, jnp.float32(0.001), block_q=128)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rows_sum_near_255(self):
        rng = np.random.default_rng(5)
        logits = rand_logits(rng, 32, 128, 20_000)
        p = np.asarray(ks.index_softmax(logits, jnp.float32(0.001)))
        sums = p.astype(np.int32).sum(axis=1)
        assert (np.abs(sums - 255) <= 16).all(), sums

    def test_uniform_rows(self):
        logits = jnp.full((2, 8), 42, dtype=jnp.int32)
        p = np.asarray(ks.index_softmax(logits, jnp.float32(0.001)))
        assert (p == p[0, 0]).all()
        assert abs(int(p[0, 0]) - 32) <= 1

    def test_clipped_tail_is_zero(self):
        # alpha=0.01 -> c_int=660; delta=1000 clipped to the zero bucket.
        logits = jnp.array([[1000, 900, 0]], dtype=jnp.int32)
        p = np.asarray(ks.index_softmax(logits, jnp.float32(0.01)))
        assert p[0, 2] == 0
        assert p[0, 0] == 255 - p[0, 1]  # renormalized over survivors

    def test_approximates_float_softmax(self):
        rng = np.random.default_rng(11)
        # Gaussian logits (realistic peaked rows); near-uniform rows bottom
        # out at the u8 resolution floor and are tested separately above.
        logits = jnp.asarray(rng.normal(0.0, 400.0, size=(8, 256)),
                             dtype=jnp.int32)
        alpha = jnp.float32(0.004)
        p = np.asarray(ks.index_softmax(logits, alpha)).astype(np.float64) / 255.0
        f = np.asarray(logits, dtype=np.float64) * 0.004
        e = np.exp(f - f.max(axis=1, keepdims=True))
        pref = e / e.sum(axis=1, keepdims=True)
        cos = (p * pref).sum() / (np.linalg.norm(p) * np.linalg.norm(pref))
        assert cos > 0.98, cos


class TestIntAttentionKernel:
    @given(
        m=st.integers(1, 40),
        l=st.integers(1, 64),
        d=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**32 - 1),
    )
    @settings(max_examples=15, deadline=None)
    def test_bit_exact_vs_ref(self, m, l, d, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(m, d)), dtype=jnp.float32)
        k = jnp.asarray(rng.normal(size=(l, d)), dtype=jnp.float32)
        v = jnp.asarray(rng.normal(size=(l, d)), dtype=jnp.float32)
        got = ka.int_attention(q, k, v)
        want = ref.int_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=1e-6)

    def test_close_to_float_attention(self):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.normal(size=(32, 32)), dtype=jnp.float32)
        k = jnp.asarray(rng.normal(size=(64, 32)), dtype=jnp.float32)
        v = jnp.asarray(rng.normal(size=(64, 32)), dtype=jnp.float32)
        got = np.asarray(ka.int_attention(q, k, v)).ravel()
        want = np.asarray(ref.float_attention_ref(q, k, v)).ravel()
        cos = (got * want).sum() / (np.linalg.norm(got) * np.linalg.norm(want))
        assert cos > 0.99, cos

    def test_blocking_invariance(self):
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.normal(size=(70, 16)), dtype=jnp.float32)
        k = jnp.asarray(rng.normal(size=(48, 16)), dtype=jnp.float32)
        v = jnp.asarray(rng.normal(size=(48, 16)), dtype=jnp.float32)
        a = ka.int_attention(q, k, v, block_q=16)
        b = ka.int_attention(q, k, v, block_q=128)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_vmem_estimate_within_budget(self):
        est = ka.mxu_utilization_estimate(4096, 4096, 128, block_q=128)
        assert est["vmem_bytes"] <= 4 * 1024 * 1024
        assert est["mxu_fraction"] > 0.9  # GEMMs dominate the op mix


class TestCausal:
    def test_index_softmax_ref_causal(self):
        rng = np.random.default_rng(6)
        logits = rand_logits(rng, 6, 6, 10_000)
        p = np.asarray(ref.index_softmax_ref(logits, jnp.float32(0.001),
                                             causal=True))
        assert (np.triu(p, 1) == 0).all()
        assert p[0, 0] == 255

    def test_int_attention_ref_causal_first_row(self):
        rng = np.random.default_rng(8)
        q = jnp.asarray(rng.normal(size=(8, 8)), dtype=jnp.float32)
        k = jnp.asarray(rng.normal(size=(8, 8)), dtype=jnp.float32)
        v = jnp.asarray(rng.normal(size=(8, 8)), dtype=jnp.float32)
        out = np.asarray(ref.int_attention_ref(q, k, v, causal=True))
        # First row attends only to itself: output ~ dequantized v[0].
        v8, sv = ref.quantize_i8_ref(v)
        expect = np.asarray(v8[0], dtype=np.float32) * float(sv)
        np.testing.assert_allclose(out[0], expect, atol=float(sv))
