"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compiler_ir('hlo')`` protos or ``.serialize()``) is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects; converting the stablehlo
module to an XlaComputation and dumping ``as_hlo_text`` reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).  All functions are
lowered with ``return_tuple=True``; the Rust side unwraps with
``decompose_tuple``.

Artifacts produced (all f32-typed interfaces so the Rust runtime's
``run_f32`` covers them):

  int_attention_head_l{L}_d{D}.hlo.txt   full IntAttention head (Pallas L1)
  index_softmax_l{L}.hlo.txt             IndexSoftmax on (scaled) f32 logits
  float_attention_head_l{L}_d{D}.hlo.txt FP32 oracle head (parity checks)
  tiny_lm_logits_t{T}.hlo.txt            trained-LM forward, weights inlined
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import int_attention as ka
from .kernels import index_softmax as ks
from .kernels import ref as kref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the default printer elides arrays beyond a
    # few elements as "{...}", which the crate-side text parser silently
    # accepts and mis-executes (the LUT came back as garbage). Cost: bigger
    # .hlo.txt files; correctness: non-negotiable.
    return comp.as_hlo_text(True)


def write(out_dir: pathlib.Path, name: str, lowered) -> None:
    text = to_hlo_text(lowered)
    path = out_dir / f"{name}.hlo.txt"
    path.write_text(text)
    print(f"  {path.name}: {len(text) / 1e3:.0f} kB")


def int_attention_f32(q, k, v):
    """f32-interface IntAttention head (quantize inside, Pallas kernel for
    the O(L^2) core)."""
    return (ka.int_attention(q, k, v),)


def float_attention_f32(q, k, v):
    return (kref.float_attention_ref(q, k, v),)


def index_softmax_f32(logits, alpha):
    """f32-interface IndexSoftmax: logits are alpha-scaled back to ints on
    the way in (the Rust caller holds INT32 logits; f32 carries them exactly
    up to 2^24, ample for the demo shapes)."""
    li = jnp.round(logits).astype(jnp.int32)
    p = ks.index_softmax(li, alpha[0])
    return (p.astype(jnp.float32) / 255.0,)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--head-shapes", default="64x32,256x64",
                    help="comma list of LxD attention-head shapes")
    ap.add_argument("--lm-t", type=int, default=32)
    ap.add_argument("--skip-lm", action="store_true")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    print(f"lowering artifacts into {out}")

    for shape in args.head_shapes.split(","):
        l, d = (int(x) for x in shape.strip().split("x"))
        spec = jax.ShapeDtypeStruct((l, d), jnp.float32)
        write(out, f"int_attention_head_l{l}_d{d}",
              jax.jit(int_attention_f32).lower(spec, spec, spec))
        write(out, f"float_attention_head_l{l}_d{d}",
              jax.jit(float_attention_f32).lower(spec, spec, spec))
        logits_spec = jax.ShapeDtypeStruct((l, l), jnp.float32)
        alpha_spec = jax.ShapeDtypeStruct((1,), jnp.float32)
        write(out, f"index_softmax_l{l}",
              jax.jit(index_softmax_f32).lower(logits_spec, alpha_spec))

    if not args.skip_lm:
        # Trained-LM forward with weights inlined as constants: the
        # self-contained artifact the compose example serves through PJRT.
        weights_bin = out / "weights.bin"
        if weights_bin.exists():
            flat = np.frombuffer(weights_bin.read_bytes(), dtype="<f4")
            params = unflatten(flat, model.CONFIG)
            t = args.lm_t

            def lm_logits(tokens_f32):
                tokens = jnp.clip(tokens_f32.astype(jnp.int32), 0,
                                  model.CONFIG["vocab"] - 1)
                return (model.forward(params, tokens, attention="float"),)

            spec = jax.ShapeDtypeStruct((t,), jnp.float32)
            write(out, f"tiny_lm_logits_t{t}", jax.jit(lm_logits).lower(spec))
        else:
            print("  (skipping tiny_lm artifact: run train.py first)")


def unflatten(flat, cfg):
    """Inverse of model.to_flat -- must track rust weights.rs order."""
    d, dm = cfg["d_model"], cfg["mlp_mult"] * cfg["d_model"]
    pos = [0]

    def take(*shape):
        n = int(np.prod(shape))
        a = jnp.asarray(flat[pos[0]:pos[0] + n]).reshape(shape)
        pos[0] += n
        return a

    params = {"tok_emb": take(cfg["vocab"], d),
              "pos_emb": take(cfg["max_seq"], d), "blocks": []}
    for _ in range(cfg["n_layers"]):
        params["blocks"].append({
            "ln1_g": take(d), "ln1_b": take(d),
            "wq": take(d, d), "wk": take(d, d),
            "wv": take(d, d), "wo": take(d, d),
            "ln2_g": take(d), "ln2_b": take(d),
            "w1": take(dm, d), "b1": take(dm),
            "w2": take(d, dm), "b2": take(d),
        })
    params["ln_f_g"] = take(d)
    params["ln_f_b"] = take(d)
    assert pos[0] == flat.size, (pos[0], flat.size)
    return params


if __name__ == "__main__":
    main()
