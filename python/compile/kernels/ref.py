"""Pure-jnp oracles for the L1 kernels.

Two layers of reference:

* ``*_ref`` -- bit-exact integer semantics of the paper's equations (2-3,
  7-15), written with plain jnp integer ops.  The Pallas kernels are tested
  against these for exact equality.
* ``float_attention_ref`` -- the FP32 softmax attention (eq. 1 + 6), the
  end-to-end numerical oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

DEFAULT_B = 5
DEFAULT_C = 6.6


def quantize_i8_ref(x):
    """Per-tensor symmetric INT8 (paper eq. 2-3). Returns (x_i8, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax == 0.0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def build_lut_u8(b: int = DEFAULT_B, c: float = DEFAULT_C):
    """UINT8 exponential LUT (paper eq. 10 + 13): 2^b entries, last is 0.

    Built with *numpy* so that inside a jit trace the table is a literal
    constant — the lowered HLO contains the 32 bytes, not exp() ops (the
    whole point of the paper: no exponential on the runtime path).
    """
    n = 1 << b
    i = np.arange(n, dtype=np.float32)
    vals = np.exp(-c * i / (n - 1))
    vals[n - 1] = 0.0
    return jnp.asarray(np.round(255.0 * vals).astype(np.uint8))


def lut_lookup(lut, idx):
    """32-entry LUT gather (paper eq. 14)."""
    return jnp.take(lut, idx, axis=0)


def c_int_of(alpha, c: float = DEFAULT_C):
    """Quantization-aligned integer clipping threshold (eq. 8), >= 1."""
    return jnp.maximum(jnp.round(c / alpha), 1.0).astype(jnp.int64)


def index_softmax_ref(logits_i32, alpha, b: int = DEFAULT_B, c: float = DEFAULT_C,
                      causal: bool = False):
    """Bit-exact IndexSoftmax (paper eq. 7-15) on INT32 logits.

    Returns the UINT8 probability matrix P-hat.  All arithmetic below is
    integer except the one-off scalar ``c_int`` derivation, mirroring the
    rust implementation exactly (round-half-away-from-zero on nonnegative
    numerators via ``(2*num + den) // (2*den)``).
    """
    logits = logits_i32.astype(jnp.int64)
    m, l = logits.shape
    n1 = (1 << b) - 1
    lut = build_lut_u8(b, c).astype(jnp.int32)
    c_int = c_int_of(alpha, c)

    if causal:
        col = jnp.arange(l)[None, :]
        row = jnp.arange(m)[:, None]
        valid = col <= row
    else:
        valid = jnp.ones((m, l), dtype=bool)

    neg = jnp.iinfo(jnp.int64).min
    masked = jnp.where(valid, logits, neg)
    row_max = jnp.max(masked, axis=1, keepdims=True)
    delta = row_max - logits  # eq. 7 (m - A), >= 0 on valid entries

    # eq. 9 + 11: clip, then round(delta * n1 / c_int) in integers
    clipped = jnp.minimum(delta, c_int)
    idx = ((2 * clipped * n1 + c_int) // (2 * c_int)).astype(jnp.int32)
    e = jnp.where(valid, lut_lookup(lut, idx), 0)  # eq. 14

    s = jnp.sum(e, axis=1, keepdims=True)  # eq. 15 widened accumulator
    p = (2 * 255 * e + s) // (2 * s)
    return jnp.where(valid, p, 0).astype(jnp.uint8)


def int_attention_ref(q, k, v, b: int = DEFAULT_B, c: float = DEFAULT_C,
                      causal: bool = False):
    """Full IntAttention pipeline oracle (paper Sec. 3): f32 in, f32 out.

    quantize -> i8 GEMM -> IndexSoftmax -> u8*i8 GEMM -> single rescale.
    """
    d = q.shape[-1]
    q8, sq = quantize_i8_ref(q)
    k8, sk = quantize_i8_ref(k)
    v8, sv = quantize_i8_ref(v)
    logits = jnp.matmul(
        q8.astype(jnp.int32), k8.astype(jnp.int32).T,
        preferred_element_type=jnp.int32)
    alpha = sq * sk / jnp.sqrt(jnp.float32(d))
    p = index_softmax_ref(logits, alpha, b, c, causal)
    acc = jnp.matmul(
        p.astype(jnp.int32), v8.astype(jnp.int32),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (sv / 255.0)


def float_attention_ref(q, k, v, causal: bool = False):
    """FP32 scaled-dot-product attention (paper eq. 1 + 6)."""
    d = q.shape[-1]
    logits = jnp.matmul(q, k.T) / jnp.sqrt(jnp.float32(d))
    if causal:
        m, l = logits.shape
        mask = jnp.arange(l)[None, :] <= jnp.arange(m)[:, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.matmul(p, v)
