"""L1 Pallas kernel: **IndexSoftmax** (paper eq. 7-15).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles rows
across NEON lanes; on TPU we tile `block_q` logit rows per grid step so the
INT32 tile, the 32-byte LUT and the UINT8 output tile live in VMEM, with
row-max / row-sum as intra-tile VPU reductions. `interpret=True` everywhere
on this host — real-TPU lowering emits a Mosaic custom-call the CPU PJRT
plugin cannot execute (see /opt/xla-example/README.md).

The kernel is bit-exact against `ref.index_softmax_ref`: same integer
rounding `(2·num + den) // (2·den)` on nonnegative numerators.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _index_softmax_kernel(logits_ref, lut_ref, c_int_ref, out_ref, *, n1,
                          block_q, causal):
    """One grid step: a (block_q, L) tile of INT32 logits → UINT8 P̂ tile."""
    logits = logits_ref[...].astype(jnp.int64)
    lut = lut_ref[...].astype(jnp.int32)
    c_int = c_int_ref[0].astype(jnp.int64)
    l = logits.shape[1]

    if causal:
        # Global row index of each tile row → decoder prefill mask.
        row0 = pl.program_id(0) * block_q
        rows = row0 + jnp.arange(block_q)[:, None]
        valid = jnp.arange(l)[None, :] <= rows
        neg = jnp.iinfo(jnp.int32).min
        logits = jnp.where(valid, logits, neg)

    # eq. 7: row-wise max-subtraction (the m − A sign convention).
    row_max = jnp.max(logits, axis=1, keepdims=True)
    delta = row_max - logits
    # eq. 9: integer-domain clipping (sparsity-aware pruning); masked-out
    # entries have huge delta and land in the LUT's zero bucket.
    clipped = jnp.minimum(delta, c_int)
    # eq. 11: index mapping, round-half-away on nonnegative ints.
    idx = ((2 * clipped * n1 + c_int) // (2 * c_int)).astype(jnp.int32)
    # eq. 14: LUT gather (32-entry UINT8 table broadcast in VMEM).
    e = ref.lut_lookup(lut, idx)
    if causal:
        e = jnp.where(valid, e, 0)
    # eq. 15: integer scale normalization with a widened accumulator.
    s = jnp.sum(e, axis=1, keepdims=True)
    s = jnp.maximum(s, 1)  # padded rows (beyond M) are all-invalid  # padded rows (beyond M) are all-invalid
    p = (2 * 255 * e + s) // (2 * s)
    out_ref[...] = p.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("b", "c", "block_q", "causal"))
def index_softmax(logits_i32, alpha, b: int = ref.DEFAULT_B,
                  c: float = ref.DEFAULT_C, block_q: int = 128,
                  causal: bool = False):
    """IndexSoftmax over INT32 logits `[M, L]` → UINT8 `[M, L]`.

    `alpha = s_Q·s_K/√d` enters only through the scalar `c_int` (eq. 8);
    everything per-element is integer.
    """
    m, l = logits_i32.shape
    n1 = (1 << b) - 1
    lut = ref.build_lut_u8(b, c)
    c_int = ref.c_int_of(alpha, c).reshape((1,)).astype(jnp.int64)

    block_q = min(block_q, m)
    # Pad M to a multiple of block_q so the grid is exact.
    pad = (-m) % block_q
    if pad:
        logits_i32 = jnp.pad(logits_i32, ((0, pad), (0, 0)))
    grid = (logits_i32.shape[0] // block_q,)

    out = pl.pallas_call(
        functools.partial(_index_softmax_kernel, n1=n1, block_q=block_q,
                          causal=causal),
        grid=grid,
        in_specs=[
            # (block_q, L) INT32 tile staged in VMEM per grid step.
            pl.BlockSpec((block_q, l), lambda i: (i, 0)),
            # The 2^b-entry LUT: broadcast to every step (fits registers).
            pl.BlockSpec((lut.shape[0],), lambda i: (0,)),
            # Scalar c_int.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((logits_i32.shape[0], l), jnp.uint8),
        interpret=True,
    )(logits_i32, lut, c_int)
    return out[:m]


def vmem_bytes_estimate(block_q: int, l: int, b: int = ref.DEFAULT_B) -> int:
    """Per-grid-step VMEM footprint (DESIGN.md §Perf L1 target ≤ ~1 MiB):
    INT32 logits tile + i64 staging + UINT8 out tile + LUT."""
    return block_q * l * 4 + block_q * l * 8 + block_q * l + (1 << b)
