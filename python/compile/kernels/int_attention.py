"""L1 Pallas kernel: the full **IntAttention** head (paper §3, Figure 3).

One kernel = one attention head: INT8 Q̂/K̂/V̂ tiles in VMEM, the Q̂K̂ᵀ and
P̂V̂ matmuls on the MXU int8 path (`preferred_element_type=int32` — the TPU
analogue of the paper's NEON SDOT/I8MM), IndexSoftmax on the VPU between
them, and a single f32 rescale at the end. No dequantize→softmax→requantize
detour exists in the lowered module — inspect the HLO text in artifacts/.

Grid: `block_q` query rows per step; K̂/V̂ are resident across steps (their
VMEM cost is L·d bytes each — at L=4096, d=128 that is 512 KiB + 512 KiB,
inside the ~1 MiB/core budget with the logits tile streamed).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _int_attention_kernel(q_ref, k_ref, v_ref, lut_ref, c_int_ref, sv_ref,
                          out_ref, *, n1, block_q, causal):
    q8 = q_ref[...].astype(jnp.int32)
    k8 = k_ref[...].astype(jnp.int32)
    v8 = v_ref[...].astype(jnp.int32)
    lut = lut_ref[...].astype(jnp.int32)
    c_int = c_int_ref[0].astype(jnp.int64)
    sv = sv_ref[0]

    # Q̂K̂ᵀ with INT32 accumulation (eq. 4) — MXU int8 mode on real TPU.
    logits = jnp.matmul(q8, k8.T, preferred_element_type=jnp.int32)

    # IndexSoftmax (eq. 7-15), integer end to end.
    logits64 = logits.astype(jnp.int64)
    if causal:
        row0 = pl.program_id(0) * block_q
        rows = row0 + jnp.arange(logits64.shape[0])[:, None]
        valid = jnp.arange(logits64.shape[1])[None, :] <= rows
        logits64 = jnp.where(valid, logits64, jnp.iinfo(jnp.int32).min)
    row_max = jnp.max(logits64, axis=1, keepdims=True)
    delta = row_max - logits64
    clipped = jnp.minimum(delta, c_int)
    idx = ((2 * clipped * n1 + c_int) // (2 * c_int)).astype(jnp.int32)
    e = ref.lut_lookup(lut, idx)  # eq. 14 LUT gather
    if causal:
        e = jnp.where(valid, e, 0)
    s = jnp.sum(e, axis=1, keepdims=True)
    s = jnp.maximum(s, 1)  # padded rows (beyond M) are all-invalid
    # Materialize P̂ as UINT8 (the paper's ×255 unsigned formulation) before
    # the aggregation GEMM — the u8 tensor is visible in the lowered HLO.
    p_u8 = ((2 * 255 * e + s) // (2 * s)).astype(jnp.uint8)
    p = p_u8.astype(jnp.int32)

    # P̂V̂ with INT32 accumulation (§3.2), then the single output rescale
    # O = (s_V/255)·(P̂V̂) (eq. 5 + eq. 15 scale).
    acc = jnp.matmul(p, v8, preferred_element_type=jnp.int32)
    out_ref[...] = acc.astype(jnp.float32) * (sv / 255.0)


@functools.partial(jax.jit,
                   static_argnames=("b", "c", "block_q", "causal"))
def int_attention_quantized(q8, k8, v8, alpha, sv, b: int = ref.DEFAULT_B,
                            c: float = ref.DEFAULT_C, block_q: int = 128,
                            causal: bool = False):
    """IntAttention on pre-quantized INT8 inputs.

    `q8`: [M, d] int8; `k8`, `v8`: [L, d] int8; `alpha = s_Q·s_K/√d`;
    `sv` = s_V. Returns f32 `[M, d]`.
    """
    m, d = q8.shape
    l = k8.shape[0]
    n1 = (1 << b) - 1
    lut = ref.build_lut_u8(b, c)
    c_int = ref.c_int_of(alpha, c).reshape((1,)).astype(jnp.int64)
    sv_arr = jnp.asarray(sv, dtype=jnp.float32).reshape((1,))

    block_q = min(block_q, m)
    pad = (-m) % block_q
    if pad:
        q8 = jnp.pad(q8, ((0, pad), (0, 0)))
    grid = (q8.shape[0] // block_q,)

    out = pl.pallas_call(
        functools.partial(_int_attention_kernel, n1=n1, block_q=block_q,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),   # Q̂ tile
            pl.BlockSpec((l, d), lambda i: (0, 0)),          # K̂ resident
            pl.BlockSpec((l, d), lambda i: (0, 0)),          # V̂ resident
            pl.BlockSpec((lut.shape[0],), lambda i: (0,)),   # LUT
            pl.BlockSpec((1,), lambda i: (0,)),              # c_int
            pl.BlockSpec((1,), lambda i: (0,)),              # s_V
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q8.shape[0], d), jnp.float32),
        interpret=True,
    )(q8, k8, v8, lut, c_int, sv_arr)
    return out[:m]


def int_attention(q, k, v, b: int = ref.DEFAULT_B, c: float = ref.DEFAULT_C,
                  block_q: int = 128, causal: bool = False):
    """Convenience wrapper: f32 in → dynamic quantization (eq. 2-3) → kernel.

    The quantization happens in plain jnp (it is O(L·d), not the hot spot);
    the O(L²) work runs inside the Pallas kernel.
    """
    d = q.shape[-1]
    q8, sq = ref.quantize_i8_ref(q)
    k8, sk = ref.quantize_i8_ref(k)
    v8, sv = ref.quantize_i8_ref(v)
    alpha = sq * sk / jnp.sqrt(jnp.float32(d))
    return int_attention_quantized(q8, k8, v8, alpha, sv, b, c, block_q,
                                   causal)


def mxu_utilization_estimate(m: int, l: int, d: int, block_q: int = 128) -> dict:
    """Static MXU/VMEM analysis for DESIGN.md §Perf (interpret=True gives no
    hardware timing): int8 MACs routed to the MXU vs VPU element ops."""
    mxu_macs = m * l * d * 2           # both GEMMs
    vpu_ops = m * l * 6                # max/sub/clip/idx/gather/sum per logit
    vmem = block_q * d + 2 * l * d + block_q * l * 4  # q + k/v + logits tile
    return {
        "mxu_macs": mxu_macs,
        "vpu_ops": vpu_ops,
        "mxu_fraction": mxu_macs / (mxu_macs + vpu_ops),
        "vmem_bytes": vmem,
    }
