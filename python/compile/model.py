"""L2: the tiny transformer LM in JAX.

Mirrors `rust/src/model/` exactly -- same pre-norm GPT block, same weight
layout (projections stored output-major, i.e. the transpose of the usual
jax `x @ W` convention), same tied LM head -- so weights trained here load
bit-for-bit into the Rust engine via the canonical flat order documented in
`rust/src/model/weights.rs`.

Two attention modes:

* ``attention="float"`` -- FP32 softmax attention (eq. 1+6); differentiable,
  used for build-time training.
* ``attention="int"``   -- the L1 Pallas IntAttention kernel per head; used
  for AOT export and for parity checks against the Rust pipeline.
"""

import jax
import jax.numpy as jnp

from .kernels import int_attention as ka
from .kernels import ref as kref

CONFIG = dict(vocab=256, d_model=128, n_layers=4, n_heads=4, max_seq=256,
              mlp_mult=4)


def d_head(cfg=None):
    cfg = cfg or CONFIG
    return cfg["d_model"] // cfg["n_heads"]


def init_params(key, cfg=None):
    """Random init; layout matches rust Weights::random."""
    cfg = cfg or CONFIG
    d, dm = cfg["d_model"], cfg["mlp_mult"] * cfg["d_model"]
    std = max(0.02, 1.0 / d ** 0.5)
    keys = jax.random.split(key, 2 + 6 * cfg["n_layers"])
    ki = iter(range(len(keys)))

    def mat(k, r, c):
        return std * jax.random.normal(keys[k], (r, c), dtype=jnp.float32)

    params = {
        "tok_emb": mat(next(ki), cfg["vocab"], d),
        "pos_emb": mat(next(ki), cfg["max_seq"], d),
        "blocks": [],
        "ln_f_g": jnp.ones((d,), jnp.float32),
        "ln_f_b": jnp.zeros((d,), jnp.float32),
    }
    for _ in range(cfg["n_layers"]):
        params["blocks"].append({
            "ln1_g": jnp.ones((d,), jnp.float32),
            "ln1_b": jnp.zeros((d,), jnp.float32),
            # output-major: row o holds the weights producing output o
            "wq": mat(next(ki), d, d),
            "wk": mat(next(ki), d, d),
            "wv": mat(next(ki), d, d),
            "wo": mat(next(ki), d, d),
            "ln2_g": jnp.ones((d,), jnp.float32),
            "ln2_b": jnp.zeros((d,), jnp.float32),
            "w1": mat(next(ki), dm, d),
            "b1": jnp.zeros((dm,), jnp.float32),
            "w2": mat(next(ki), d, dm),
            "b2": jnp.zeros((d,), jnp.float32),
        })
    return params


def layer_norm(x, g, b):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + 1e-5) * g + b


def linear(x, w, b=None):
    """Output-major linear: y = x @ w.T (+ b)."""
    y = x @ w.T
    return y if b is None else y + b


def _heads(x, n_heads):
    t, d = x.shape
    return x.reshape(t, n_heads, d // n_heads).transpose(1, 0, 2)


def attention_block(xn, blk, cfg, attention="float"):
    q = linear(xn, blk["wq"])
    k = linear(xn, blk["wk"])
    v = linear(xn, blk["wv"])
    nh = cfg["n_heads"]
    qs, ks, vs = _heads(q, nh), _heads(k, nh), _heads(v, nh)
    outs = []
    for h in range(nh):
        if attention == "int":
            outs.append(ka.int_attention(qs[h], ks[h], vs[h], causal=True))
        else:
            outs.append(kref.float_attention_ref(qs[h], ks[h], vs[h], causal=True))
    att = jnp.stack(outs, axis=0).transpose(1, 0, 2).reshape(xn.shape)
    return linear(att, blk["wo"])


def forward(params, tokens, cfg=None, attention="float"):
    """Token ids [T] -> logits [T, vocab]; causal."""
    cfg = cfg or CONFIG
    t = tokens.shape[0]
    pos = jnp.minimum(jnp.arange(t), cfg["max_seq"] - 1)
    x = params["tok_emb"][tokens] + params["pos_emb"][pos]
    for blk in params["blocks"]:
        xn = layer_norm(x, blk["ln1_g"], blk["ln1_b"])
        x = x + attention_block(xn, blk, cfg, attention)
        xn2 = layer_norm(x, blk["ln2_g"], blk["ln2_b"])
        h = jax.nn.gelu(linear(xn2, blk["w1"], blk["b1"]), approximate=True)
        x = x + linear(h, blk["w2"], blk["b2"])
    xf = layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    return xf @ params["tok_emb"].T  # tied head


def loss_fn(params, tokens, cfg=None):
    """Mean next-token cross entropy (nats)."""
    logits = forward(params, tokens, cfg, attention="float")
    targets = tokens[1:]
    lp = jax.nn.log_softmax(logits[:-1], axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, targets[:, None], axis=-1))


def batched_loss(params, batch, cfg=None):
    return jnp.mean(jax.vmap(lambda t: loss_fn(params, t, cfg))(batch))


def to_flat(params, cfg=None):
    """Serialize to the canonical flat f32 order of rust weights.rs."""
    cfg = cfg or CONFIG
    parts = [params["tok_emb"].ravel(), params["pos_emb"].ravel()]
    for blk in params["blocks"]:
        for name in ("ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
                     "ln2_g", "ln2_b", "w1", "b1", "w2", "b2"):
            parts.append(blk[name].ravel())
    parts += [params["ln_f_g"].ravel(), params["ln_f_b"].ravel()]
    return jnp.concatenate(parts).astype(jnp.float32)


def param_count(cfg=None):
    cfg = cfg or CONFIG
    d, dm = cfg["d_model"], cfg["mlp_mult"] * cfg["d_model"]
    emb = cfg["vocab"] * d + cfg["max_seq"] * d
    per = 4 * d * d + 4 * d + 2 * d * dm + dm + d
    return emb + cfg["n_layers"] * per + 2 * d
