"""Build-time trainer for the tiny byte-level LM (the Table 1/3/5/10
substitution model, DESIGN.md Sec. 2).

Trains on a synthetic structured corpus (arithmetic + word-bigram +
counting patterns -- learnable but non-trivial), then exports:

  artifacts/weights.bin        flat little-endian f32, rust canonical order
  artifacts/model_meta.json    config + param_count (rust loader validates)
  artifacts/corpus_train.txt   the training text
  artifacts/corpus_eval.txt    held-out text (rust fidelity evals read this)
  artifacts/train_log.json     loss curve (EXPERIMENTS.md e2e record)

Python runs once at build time; nothing here is on the serve path.
"""

import argparse
import json
import pathlib
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model

WORDS = ["edge", "device", "tensor", "integer", "attention", "softmax",
         "kernel", "lookup", "table", "quantize", "latency", "energy",
         "pipeline", "index"]


def synthetic_corpus(chars: int, seed: int) -> str:
    """Structured text; same pattern family as rust fidelity::synthetic_corpus
    (the texts need not be byte-identical -- rust reads the file we write)."""
    rng = random.Random(seed)
    out = []
    n = 0
    while n < chars:
        a, b = rng.randrange(10), rng.randrange(10)
        kind = rng.randrange(3)
        if kind == 0:
            s = f"{a} + {b} = {a + b} . "
        elif kind == 1:
            w = rng.choice(WORDS)
            s = f"{w} {WORDS[(WORDS.index(w) + 1) % len(WORDS)]} . "
        else:
            s = f"{a} {(a + 1) % 10} {(a + 2) % 10} . "
        out.append(s)
        n += len(s)
    return "".join(out)[:chars]


def encode(text: str) -> np.ndarray:
    return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=3e-4, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) /
        (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return params, {"m": m, "v": v, "t": t}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    cfg = model.CONFIG

    train_text = synthetic_corpus(200_000, seed=args.seed + 1)
    eval_text = synthetic_corpus(20_000, seed=args.seed + 2)
    (out / "corpus_train.txt").write_text(train_text)
    (out / "corpus_eval.txt").write_text(eval_text)
    data = encode(train_text)
    eval_data = encode(eval_text)

    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key, cfg)
    opt = adam_init(params)

    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, b: model.batched_loss(p, b, cfg)))
    eval_loss = jax.jit(lambda p, b: model.batched_loss(p, b, cfg))

    rng = np.random.default_rng(args.seed)

    def sample_batch(src):
        starts = rng.integers(0, len(src) - args.seq - 1, size=args.batch)
        return jnp.stack([jnp.asarray(src[s:s + args.seq]) for s in starts])

    log = []
    t0 = time.time()
    for step in range(args.steps):
        batch = sample_batch(data)
        loss, grads = loss_grad(params, batch)
        params, opt = adam_update(params, grads, opt)
        if step % 20 == 0 or step == args.steps - 1:
            ev = float(eval_loss(params, sample_batch(eval_data)))
            log.append({"step": step, "train_loss": float(loss),
                        "eval_loss": ev, "eval_ppl": float(np.exp(ev)),
                        "elapsed_s": round(time.time() - t0, 1)})
            print(f"step {step:4d} | train {float(loss):.4f} | "
                  f"eval {ev:.4f} (ppl {np.exp(ev):.2f})")

    flat = np.asarray(model.to_flat(params, cfg), dtype="<f4")
    assert flat.size == model.param_count(cfg), (flat.size, model.param_count(cfg))
    (out / "weights.bin").write_bytes(flat.tobytes())
    meta = dict(cfg)
    meta["param_count"] = int(flat.size)
    (out / "model_meta.json").write_text(json.dumps(meta))
    (out / "train_log.json").write_text(json.dumps(log, indent=1))
    print(f"wrote {flat.size} params ({flat.size * 4 / 1e6:.1f} MB) to {out}")


if __name__ == "__main__":
    main()
