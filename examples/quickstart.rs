//! Quickstart: run one attention head through every pipeline and compare
//! outputs, latency and the softmax-path share — the 60-second tour of what
//! IntAttention does.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use intattention::attention::{build_pipeline, AttentionConfig, PipelineKind};
use intattention::harness::workload::clustered_qkv;
use intattention::util::prng::Pcg64;
use intattention::util::stats::cosine_similarity;

fn main() {
    let (l, d) = (1024, 128);
    println!("IntAttention quickstart — one attention head, L={l}, d={d}\n");

    let mut rng = Pcg64::seed_from_u64(7);
    // Clustered inputs: realistic peaked attention rows (Figure 4), where
    // 8-bit probability resolution is meaningful at L=1024.
    let (q, k, v) = clustered_qkv(&mut rng, l, d, 8, 3.0);

    // FP32 is the numerical reference.
    let cfg = AttentionConfig::new(l, d);
    let reference = build_pipeline(PipelineKind::Fp32, cfg).forward(&q, &k, &v);

    println!(
        "{:>13} | {:>9} | {:>8} | {:>12} | breakdown",
        "pipeline", "time (ms)", "cos-sim", "softmax-path"
    );
    for kind in [
        PipelineKind::Fp32,
        PipelineKind::Fp16,
        PipelineKind::QuantOnly,
        PipelineKind::IntAttention,
        PipelineKind::ExaqInt3,
    ] {
        let mut pipe = build_pipeline(kind, cfg);
        let _ = pipe.forward(&q, &k, &v); // warm
        pipe.reset_stats();
        let out = pipe.forward(&q, &k, &v);
        let t = pipe.stage_times();
        println!(
            "{:>13} | {:>9.2} | {:>8.5} | {:>11.1}% | {}",
            kind.name(),
            t.total_ns() as f64 / 1e6,
            cosine_similarity(reference.as_slice(), out.as_slice()),
            100.0 * t.softmax_path_share(),
            t.render(),
        );
    }

    println!(
        "\nIntAttention removes the dequantize→softmax→requantize detour:\n\
         integer from the Q̂K̂ᵀ logits to the P̂V̂ aggregation (paper Fig. 1/3)."
    );
}
