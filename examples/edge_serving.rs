//! **End-to-end serving driver** (the DESIGN.md §5 e2e validation): load the
//! build-time-trained tiny LM, start the coordinator (continuous batching,
//! bounded-queue admission), replay a Poisson/Zipf request trace against it
//! under two attention backends, and report latency/throughput — recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example edge_serving
//! ```

use intattention::attention::PipelineKind;
use intattention::coordinator::batcher::BatchPolicy;
use intattention::coordinator::{Engine, EngineOptions, SubmitOptions};
use intattention::harness::experiments::load_or_random_weights;
use intattention::harness::workload::request_trace;
use intattention::model::tokenizer;
use intattention::util::prng::Pcg64;

fn main() {
    let weights = load_or_random_weights();
    let cfg = weights.cfg;
    let n_requests: usize = std::env::var("REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);

    // Prompts drawn from the training corpus distribution.
    let corpus = intattention::harness::fidelity::synthetic_corpus(8192, 5);
    let corpus_tokens = tokenizer::encode(&corpus);

    for kind in [PipelineKind::QuantOnly, PipelineKind::IntAttention] {
        let mut rng = Pcg64::seed_from_u64(99);
        let trace = request_trace(&mut rng, n_requests, 12.0, &[24, 64, 120], 16);
        let opts = EngineOptions {
            attention: kind,
            policy: BatchPolicy { max_active: 6, ..Default::default() },
            max_queue: 64,
            ..Default::default()
        };
        let handle = Engine::start(weights.clone(), opts);
        let t0 = std::time::Instant::now();
        let mut receivers = Vec::new();
        let mut rejected = 0;
        for r in &trace {
            if let Some(sleep) =
                std::time::Duration::from_micros(r.arrival_us).checked_sub(t0.elapsed())
            {
                std::thread::sleep(sleep);
            }
            let plen = r.prompt_len.min(cfg.max_seq.saturating_sub(r.gen_len + 1)).max(1);
            let start = (r.arrival_us as usize) % (corpus_tokens.len() - plen - 1);
            let prompt = corpus_tokens[start..start + plen].to_vec();
            match handle.submit(prompt, r.gen_len, SubmitOptions::sampling(0.7, 12)) {
                Ok(rx) => receivers.push(rx),
                Err(_) => rejected += 1,
            }
        }
        let mut ttfts = Vec::new();
        for mut rx in receivers {
            if let Ok(resp) = rx.recv_final() {
                ttfts.push(resp.ttft_us() as f64 / 1e3);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = handle.shutdown();
        println!("=== backend {} ===", kind.name());
        println!("  {}", snap.render());
        println!(
            "  wall {:.2}s | {} rejected | ttft mean {:.1} ms | p99 {:.1} ms",
            wall,
            rejected,
            intattention::util::stats::mean(&ttfts),
            intattention::util::stats::percentile(&ttfts, 99.0),
        );
    }
}
