//! Three-layer composition proof: the JAX/Pallas-lowered HLO artifacts
//! (L1 kernel inside an L2 function, AOT'd by `make artifacts`) execute
//! under the Rust PJRT runtime, and their numerics match the native Rust
//! IntAttention pipeline **bit-for-bit on the integer path** (identical
//! eq. 2–15 arithmetic on both sides of the language boundary).
//!
//! ```sh
//! make artifacts && cargo run --release --example pjrt_compose
//! ```

use intattention::attention::{build_pipeline, AttentionConfig, PipelineKind};
use intattention::harness::workload::random_qkv;
use intattention::runtime::{default_artifacts_dir, ArtifactRuntime};
use intattention::tensor::MatF32;
use intattention::util::prng::Pcg64;
use intattention::util::stats::{cosine_similarity, max_abs_diff};

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    let mut rt = ArtifactRuntime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts: {:?}\n", rt.list_artifacts());

    // --- L1/L2 kernel vs native Rust pipeline -----------------------------
    let (l, d) = (64usize, 32usize);
    let name = format!("int_attention_head_l{l}_d{d}");
    if !rt.has_artifact(&name) {
        anyhow::bail!("artifact '{name}' missing — run `make artifacts` first");
    }
    let mut rng = Pcg64::seed_from_u64(3);
    let (q, k, v) = random_qkv(&mut rng, l, d, 1.0);
    let shape = [l, d];

    let outs = rt.run(
        &name,
        &[
            (q.as_slice(), &shape),
            (k.as_slice(), &shape),
            (v.as_slice(), &shape),
        ],
    )?;
    let jax_out = MatF32::from_vec(l, d, outs[0].clone());

    let mut pipe = build_pipeline(PipelineKind::IntAttention, AttentionConfig::new(l, d));
    let rust_out = pipe.forward(&q, &k, &v);

    let cos = cosine_similarity(jax_out.as_slice(), rust_out.as_slice());
    let mad = max_abs_diff(jax_out.as_slice(), rust_out.as_slice());
    println!("IntAttention head ({l}x{d}): pallas-via-PJRT vs native rust");
    println!("  cosine similarity: {cos:.9}");
    println!("  max |Δ|:           {mad:.2e}");
    assert!(
        cos > 0.999_999,
        "integer paths must agree (same eq. 2-15 arithmetic): cos={cos}"
    );

    // --- FP32 oracle artifact sanity --------------------------------------
    let oracle = format!("float_attention_head_l{l}_d{d}");
    if rt.has_artifact(&oracle) {
        let outs = rt.run(
            &oracle,
            &[
                (q.as_slice(), &shape),
                (k.as_slice(), &shape),
                (v.as_slice(), &shape),
            ],
        )?;
        let fp_out = MatF32::from_vec(l, d, outs[0].clone());
        let cos_fp = cosine_similarity(fp_out.as_slice(), rust_out.as_slice());
        println!("\nFP32 oracle artifact vs rust IntAttention: cos {cos_fp:.5}");
    }

    // --- Trained LM through PJRT ------------------------------------------
    if rt.has_artifact("tiny_lm_logits_t32") {
        let tokens: Vec<f32> = (0..32).map(|i| (i * 7 % 200) as f32).collect();
        let outs = rt.run("tiny_lm_logits_t32", &[(&tokens, &[32usize][..])])?;
        let logits = &outs[0];
        println!(
            "\ntiny LM via PJRT: {} logits, finite: {}",
            logits.len(),
            logits.iter().all(|x| x.is_finite())
        );
    }

    println!("\nall three layers compose ✓");
    Ok(())
}
