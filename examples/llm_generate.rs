//! Generate text with the build-time-trained tiny LM under each attention
//! pipeline, and report per-pipeline perplexity on the held-out corpus —
//! the qualitative version of the Table 1 reproduction.
//!
//! ```sh
//! make artifacts && cargo run --release --example llm_generate
//! ```

use intattention::attention::PipelineKind;
use intattention::harness::experiments::load_or_random_weights;
use intattention::harness::fidelity::{eval_lm_fidelity, eval_sequences};
use intattention::model::lm::TinyLm;
use intattention::model::tokenizer;
use intattention::util::prng::Pcg64;

fn main() {
    let weights = load_or_random_weights();
    let cfg = weights.cfg;
    println!(
        "tiny LM: {} layers, d_model {}, {} heads, {} params\n",
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.param_count()
    );

    let prompt = "3 + 4 = ";
    for kind in [PipelineKind::Fp32, PipelineKind::QuantOnly, PipelineKind::IntAttention] {
        let mut lm = TinyLm::new(weights.clone(), kind);
        let mut rng = Pcg64::seed_from_u64(1);
        let out = lm.generate(&tokenizer::encode(prompt), 48, 0.7, 12, &mut rng);
        println!("[{:>12}] {prompt}{}", kind.name(), tokenizer::decode(&out).replace('\n', " "));
    }

    println!("\nheld-out fidelity (paper Table 1 shape):");
    let dir = intattention::runtime::default_artifacts_dir();
    let seqs = eval_sequences(&dir, 6, 160.min(cfg.max_seq), cfg.vocab);
    println!(
        "{:>13} | {:>10} | {:>18} | {:>9}",
        "pipeline", "perplexity", "top1-agree vs FP32", "loss MAD"
    );
    for kind in [
        PipelineKind::Fp32,
        PipelineKind::Fp16,
        PipelineKind::QuantOnly,
        PipelineKind::IntAttention,
    ] {
        let f = eval_lm_fidelity(&weights, kind, &seqs);
        println!(
            "{:>13} | {:>10.3} | {:>18.3} | {:>9.4}",
            f.pipeline, f.perplexity, f.top1_agreement, f.loss_mad
        );
    }
}
