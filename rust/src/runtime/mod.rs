//! PJRT runtime: loads the HLO-text artifacts produced by the build-time
//! JAX/Pallas layer (`python/compile/aot.py`) and executes them on the CPU
//! PJRT client via the `xla` crate — the L3↔L2/L1 bridge.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). All modules are lowered with
//! `return_tuple=True`, so results unwrap with `to_tuple1()` / tuple
//! accessors.
//!
//! The `xla` crate is not in the offline build cache, so the executing
//! implementation is gated behind the `pjrt` cargo feature (which requires
//! adding the dependency — see Cargo.toml). Without it this module compiles
//! a stub with the same API: directory/artifact bookkeeping works, but
//! [`ArtifactRuntime::load`] / [`ArtifactRuntime::run`] report that PJRT is
//! unavailable. [`PJRT_AVAILABLE`] lets tests and tools skip cleanly.

use anyhow::Result;
use std::path::{Path, PathBuf};

/// Whether this build can actually compile and execute artifacts.
pub const PJRT_AVAILABLE: bool = cfg!(feature = "pjrt");

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use anyhow::{Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A compiled, executable artifact.
    pub struct Executable {
        name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Executable {
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with f32 input buffers of the given shapes; returns all f32
        /// outputs flattened (the artifacts used here are single- or
        /// multi-output tuples of f32 arrays).
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, shape)| {
                    let lit = xla::Literal::vec1(data);
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).context("reshape input literal")
                })
                .collect::<Result<_>>()?;
            let mut result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .context("execute artifact")?[0][0]
                .to_literal_sync()
                .context("fetch result literal")?;
            // Lowered with return_tuple=True: decompose the tuple.
            let tuple = result.decompose_tuple().context("decompose result tuple")?;
            tuple
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().context("read f32 output"))
                .collect()
        }
    }

    /// Loads and caches compiled artifacts from a directory of `*.hlo.txt` files.
    pub struct ArtifactRuntime {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: HashMap<String, Executable>,
    }

    impl ArtifactRuntime {
        /// CPU PJRT client over the given artifacts directory.
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(ArtifactRuntime {
                client,
                dir: dir.as_ref().to_path_buf(),
                cache: HashMap::new(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Path of a named artifact.
        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.dir.join(format!("{name}.hlo.txt"))
        }

        /// True if the named artifact exists on disk.
        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        /// List artifact names available in the directory.
        pub fn list_artifacts(&self) -> Vec<String> {
            super::list_artifacts_in(&self.dir)
        }

        /// Load + compile (cached) an artifact by name.
        pub fn load(&mut self, name: &str) -> Result<&Executable> {
            if !self.cache.contains_key(name) {
                let path = self.artifact_path(name);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path not utf-8")?,
                )
                .with_context(|| format!("parse HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compile artifact '{name}'"))?;
                self.cache.insert(
                    name.to_string(),
                    Executable { name: name.to_string(), exe },
                );
            }
            Ok(&self.cache[name])
        }

        /// Convenience: load and run in one call.
        pub fn run(&mut self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            self.load(name)?.run_f32(inputs)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_impl {
    use anyhow::Result;
    use std::path::{Path, PathBuf};

    /// Stub executable — never constructed in a non-`pjrt` build.
    pub struct Executable {
        name: String,
    }

    impl Executable {
        pub fn name(&self) -> &str {
            &self.name
        }

        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!(
                "cannot execute artifact '{}': built without the `pjrt` feature",
                self.name
            )
        }
    }

    /// Directory bookkeeping works without PJRT; compilation/execution do not.
    pub struct ArtifactRuntime {
        dir: PathBuf,
    }

    impl ArtifactRuntime {
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            Ok(ArtifactRuntime { dir: dir.as_ref().to_path_buf() })
        }

        pub fn platform(&self) -> String {
            "unavailable (built without the `pjrt` feature)".to_string()
        }

        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.dir.join(format!("{name}.hlo.txt"))
        }

        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        pub fn list_artifacts(&self) -> Vec<String> {
            super::list_artifacts_in(&self.dir)
        }

        pub fn load(&mut self, name: &str) -> Result<&Executable> {
            anyhow::bail!(
                "cannot compile artifact '{name}': this binary was built without the \
                 `pjrt` feature (the offline image lacks the `xla` crate)"
            )
        }

        pub fn run(&mut self, name: &str, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            self.load(name)?;
            unreachable!("load always errors in the stub runtime")
        }
    }
}

pub use pjrt_impl::{ArtifactRuntime, Executable};

/// Shared directory listing for both implementations.
fn list_artifacts_in(dir: &Path) -> Vec<String> {
    let mut names = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let fname = e.file_name().to_string_lossy().to_string();
            if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                names.push(stem.to_string());
            }
        }
    }
    names.sort();
    names
}

/// Default artifacts directory: `$INTATTN_ARTIFACTS` or `artifacts/` under
/// the crate root / current directory.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("INTATTN_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Prefer the manifest-relative path (tests run from the crate root).
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest.exists() {
        return manifest;
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full PJRT round-trip tests live in rust/tests/runtime_artifacts.rs
    // (they need `make artifacts` to have run). Here: path logic only.

    #[test]
    fn artifact_paths_and_listing() {
        let dir = std::env::temp_dir().join("intattn_rt_test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(dir.join("alpha.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("beta.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("notes.md"), "x").unwrap();
        let rt = ArtifactRuntime::new(&dir).unwrap();
        assert!(rt.has_artifact("alpha"));
        assert!(!rt.has_artifact("gamma"));
        assert_eq!(rt.list_artifacts(), vec!["alpha".to_string(), "beta".to_string()]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailability() {
        let dir = std::env::temp_dir().join("intattn_rt_stub_test");
        let _ = std::fs::create_dir_all(&dir);
        let mut rt = ArtifactRuntime::new(&dir).unwrap();
        assert!(!PJRT_AVAILABLE);
        assert!(rt.platform().contains("unavailable"));
        let err = rt.run("whatever", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
