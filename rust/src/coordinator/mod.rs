//! The L3 serving coordinator: an edge-inference engine in the vLLM/Orca
//! mold, sized for on-device serving. Owns the event loop, request admission
//! (bounded queue → backpressure), continuous batching across prefill and
//! decode, per-request KV caches, and latency/throughput metrics.
//!
//! The paper's contribution (IntAttention) plugs in as the attention backend
//! of the model the engine serves — selected per-engine via
//! [`EngineOptions::attention`], so the serving benchmarks compare pipelines
//! under identical scheduling.

pub mod request;
pub mod metrics;
pub mod batcher;
pub mod prefix;
pub mod engine;
pub mod tcp;

pub use engine::{scheduler_panics, Engine, EngineHandle, EngineOptions};
pub use request::{
    CancelToken, FinishReason, Request, Response, StreamEvent, StreamRx, StreamTx, SubmitError,
    SubmitOptions,
};
