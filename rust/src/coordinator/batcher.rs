//! Dynamic batching policy: decides, each scheduling round, which queued
//! requests to admit into the active set (continuous batching, Orca-style)
//! under a token budget, and in what order (shortest-prompt-first buckets
//! reduce head-of-line blocking from long prefills on a single-core device).

use crate::coordinator::request::Request;
use std::collections::VecDeque;

/// Batching policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max requests decoding concurrently.
    pub max_active: usize,
    /// Max *new* prefill tokens admitted per scheduling round (bounds TTFT
    /// jitter for already-running decodes).
    pub prefill_token_budget: usize,
    /// Admit shorter prompts first within a round.
    pub shortest_first: bool,
    /// Prefill chunk size: prompts longer than this are prefilled in chunks
    /// of at most this many tokens (offset-causal masking over the KV
    /// states), bounding the latency spike a long prompt injects into the
    /// round. 0 disables chunking.
    pub prefill_chunk: usize,
    /// KV-memory budget in **pages** across all active sequences (the KV
    /// states allocate fixed-size pages of `INTATTN_KV_PAGE` rows from a
    /// recycling pool — see `crate::attention::state::PagedRows`). Each
    /// active sequence reserves its full projected prompt+generation
    /// footprint, `KvCache::pages_for_tokens`, so the bound holds through
    /// decode growth — and because page counts are exact allocated
    /// capacity (no hidden `Vec` growth slack), peak residency actually
    /// stays inside the budget, which the old byte accounting could miss
    /// by up to 2×. A request that would overflow the budget waits in the
    /// queue — and once one request defers, the rest of that round's
    /// admissions defer behind it (no intra-round leapfrogging); a request
    /// too big for the whole budget still runs when the engine drains. A
    /// finished request's pages return to the pool the round it retires,
    /// which is what lets the next queued request admit. 0 disables the
    /// bound.
    ///
    /// With prefix sharing on, an adopted prefix's pages are **charged
    /// once** — to the prefix index that pins them: each active request
    /// reserves its projection *minus* the pages it adopted, and the
    /// index's pinned pages join the reservation total. Under budget
    /// pressure the engine evicts cached-but-idle index entries before
    /// deferring a live request.
    pub max_kv_pages: usize,
    /// Copy-on-write prefix sharing across requests
    /// (`crate::coordinator::prefix`): hash prompt prefixes at aligned
    /// chunk boundaries, adopt the longest registered match by page
    /// reference, and quantize only the unshared suffix. Byte-invisible by
    /// construction (see the module docs); effective only when
    /// `prefill_chunk > 0`. Defaults from `INTATTN_PREFIX_SHARE`
    /// ([`crate::coordinator::prefix::default_prefix_share`]).
    pub prefix_share: bool,
    /// Prefill/decode interleaving gate (TGI's `waiting_served_ratio`):
    /// while decodes are in flight, hold new admissions back until the
    /// waiting set is at least `waiting_served_ratio` × the active set, so
    /// a busy decode batch is not stalled by a prefill for every lone
    /// straggler — prefill work amortizes over a worthwhile cohort. An idle
    /// engine always admits immediately. 0 disables the gate (admit
    /// greedily every round). Defaults from `INTATTN_WAITING_RATIO`.
    pub waiting_served_ratio: f32,
    /// Age valve for the ratio gate: a request that has waited this many
    /// scheduling rounds is admitted regardless of the ratio, so the gate
    /// bounds added queueing delay instead of starving stragglers.
    pub max_waiting_rounds: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_active: 8,
            prefill_token_budget: 2048,
            shortest_first: true,
            prefill_chunk: 256,
            max_kv_pages: 0,
            prefix_share: crate::coordinator::prefix::default_prefix_share(),
            waiting_served_ratio: crate::util::env::knobs().waiting_ratio,
            max_waiting_rounds: 8,
        }
    }
}

/// Select requests to admit from `queue` given `active` currently-running
/// requests. Removes the admitted requests from the queue and returns them.
/// Selection enforces the slot and prefill-token budgets; the engine then
/// charges each selected request's projected page footprint against
/// [`BatchPolicy::max_kv_pages`] (with head-of-line pinning for deferred
/// requests) before it actually joins the active set.
pub fn select_admissions(
    queue: &mut VecDeque<Request>,
    active: usize,
    policy: &BatchPolicy,
) -> Vec<Request> {
    let slots = policy.max_active.saturating_sub(active);
    if slots == 0 || queue.is_empty() {
        return Vec::new();
    }
    // Interleaving gate: with decodes in flight, defer prefills until the
    // waiting cohort is worth the stall (or a straggler has aged out).
    if policy.waiting_served_ratio > 0.0 && active > 0 {
        let cohort_ready =
            queue.len() as f32 >= policy.waiting_served_ratio * active as f32;
        let aged_out =
            queue.iter().any(|r| r.waited_rounds >= policy.max_waiting_rounds);
        if !cohort_ready && !aged_out {
            return Vec::new();
        }
    }
    // Candidate indices in admission order.
    let mut order: Vec<usize> = (0..queue.len()).collect();
    if policy.shortest_first {
        order.sort_by_key(|&i| queue[i].prompt.len());
    }
    let mut budget = policy.prefill_token_budget;
    let mut picked: Vec<usize> = Vec::new();
    for &i in &order {
        if picked.len() >= slots {
            break;
        }
        let len = queue[i].prompt.len();
        if len <= budget {
            budget -= len;
            picked.push(i);
        } else if picked.is_empty() && active == 0 {
            // Never starve: a prompt longer than the whole budget still runs
            // when nothing else is in flight.
            picked.push(i);
            break;
        }
    }
    // Remove picked indices from the queue (descending to keep indices valid).
    picked.sort_unstable();
    let mut out: Vec<Request> = Vec::with_capacity(picked.len());
    for &i in picked.iter().rev() {
        out.push(queue.remove(i).expect("index valid"));
    }
    out.reverse();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{CancelToken, StreamTx};
    use std::sync::atomic::AtomicUsize;
    use std::sync::{mpsc, Arc};
    use std::time::Instant;

    fn req(id: u64, plen: usize) -> Request {
        let (tx, _rx) = mpsc::channel();
        // Keeping the receiver alive is unnecessary for batcher tests.
        std::mem::forget(_rx);
        Request {
            id,
            prompt: vec![0; plen],
            gen_len: 1,
            temperature: 0.0,
            top_k: 1,
            arrived: Instant::now(),
            deadline: None,
            waited_rounds: 0,
            cancel: CancelToken::new(),
            stream: StreamTx::new(tx, Arc::new(AtomicUsize::new(0)), 0),
        }
    }

    fn q(reqs: Vec<Request>) -> VecDeque<Request> {
        reqs.into_iter().collect()
    }

    #[test]
    fn respects_max_active() {
        let mut queue = q(vec![req(1, 10), req(2, 10), req(3, 10)]);
        let policy = BatchPolicy { max_active: 2, ..Default::default() };
        let adm = select_admissions(&mut queue, 1, &policy);
        assert_eq!(adm.len(), 1);
        assert_eq!(queue.len(), 2);
    }

    #[test]
    fn respects_token_budget() {
        let mut queue = q(vec![req(1, 600), req(2, 600), req(3, 600)]);
        let policy = BatchPolicy { max_active: 8, prefill_token_budget: 1000, shortest_first: false, ..Default::default() };
        let adm = select_admissions(&mut queue, 0, &policy);
        assert_eq!(adm.len(), 1, "only one 600-token prompt fits in 1000");
    }

    #[test]
    fn shortest_first_ordering() {
        let mut queue = q(vec![req(1, 500), req(2, 50), req(3, 200)]);
        let policy = BatchPolicy { max_active: 2, prefill_token_budget: 10_000, shortest_first: true, ..Default::default() };
        let adm = select_admissions(&mut queue, 0, &policy);
        assert_eq!(adm.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(queue.front().unwrap().id, 1);
    }

    #[test]
    fn fifo_when_shortest_first_disabled() {
        let mut queue = q(vec![req(1, 500), req(2, 50)]);
        let policy = BatchPolicy { max_active: 1, prefill_token_budget: 10_000, shortest_first: false, ..Default::default() };
        let adm = select_admissions(&mut queue, 0, &policy);
        assert_eq!(adm[0].id, 1);
    }

    #[test]
    fn oversized_prompt_not_starved() {
        // Ratio gate disabled so this exercises the token budget alone.
        let policy = BatchPolicy {
            max_active: 4,
            prefill_token_budget: 1000,
            shortest_first: true,
            waiting_served_ratio: 0.0,
            ..Default::default()
        };
        let mut queue = q(vec![req(1, 5000)]);
        // Nothing active → must still admit.
        let adm = select_admissions(&mut queue, 0, &policy);
        assert_eq!(adm.len(), 1);
        // But with work in flight it waits.
        let mut queue = q(vec![req(1, 5000)]);
        let adm = select_admissions(&mut queue, 1, &policy);
        assert!(adm.is_empty());
    }

    #[test]
    fn ratio_gate_defers_until_cohort_is_worthwhile() {
        let policy = BatchPolicy {
            max_active: 8,
            waiting_served_ratio: 1.2,
            max_waiting_rounds: 1000,
            ..Default::default()
        };
        // 2 active, 1 waiting: 1 < 1.2 × 2 → hold the prefill back.
        let mut queue = q(vec![req(1, 10)]);
        assert!(select_admissions(&mut queue, 2, &policy).is_empty());
        assert_eq!(queue.len(), 1, "deferred request stays queued");
        // 2 active, 3 waiting: 3 ≥ 2.4 → the cohort admits together.
        let mut queue = q(vec![req(1, 10), req(2, 10), req(3, 10)]);
        assert_eq!(select_admissions(&mut queue, 2, &policy).len(), 3);
    }

    #[test]
    fn ratio_gate_age_valve_admits_stragglers() {
        let policy = BatchPolicy {
            max_active: 8,
            waiting_served_ratio: 4.0,
            max_waiting_rounds: 8,
            ..Default::default()
        };
        let mut old = req(1, 10);
        old.waited_rounds = 8;
        let mut queue = q(vec![old]);
        let adm = select_admissions(&mut queue, 2, &policy);
        assert_eq!(adm.len(), 1, "aged-out straggler bypasses the ratio");
    }

    #[test]
    fn ratio_gate_never_delays_an_idle_engine() {
        let policy = BatchPolicy {
            max_active: 8,
            waiting_served_ratio: 100.0,
            max_waiting_rounds: 1000,
            ..Default::default()
        };
        let mut queue = q(vec![req(1, 10)]);
        assert_eq!(select_admissions(&mut queue, 0, &policy).len(), 1);
    }

    #[test]
    fn ratio_gate_disabled_at_zero() {
        let policy = BatchPolicy {
            max_active: 8,
            waiting_served_ratio: 0.0,
            ..Default::default()
        };
        let mut queue = q(vec![req(1, 10)]);
        assert_eq!(select_admissions(&mut queue, 7, &policy).len(), 1);
    }

    #[test]
    fn empty_queue_returns_empty() {
        let mut queue: VecDeque<Request> = VecDeque::new();
        let adm = select_admissions(&mut queue, 0, &BatchPolicy::default());
        assert!(adm.is_empty());
    }
}
