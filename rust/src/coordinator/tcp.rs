//! TCP front-end for the serving engine: a dependency-free (no tokio)
//! wire protocol that maps submit/stream/cancel verbs onto
//! [`EngineHandle`], one OS thread per connection plus one forwarder
//! thread per in-flight request.
//!
//! ## Wire format
//!
//! Every message is a length-prefixed frame: a `u32` little-endian body
//! length followed by the body; the body's first byte is the verb. All
//! integers are little-endian. Client verbs:
//!
//! | verb | name | payload |
//! |---|---|---|
//! | `0x01` | SUBMIT | `u64 tag`, `u32 gen_len`, `u32 top_k`, `u32 temp_milli`, `u64 deadline_ms` (0 = none), `u32 stream_buffer` (0 = unbounded), `u32 n`, `n × u16` prompt tokens |
//! | `0x02` | CANCEL | `u64 tag` |
//!
//! Server verbs (one frame per [`StreamEvent`], same order as the stream):
//!
//! | verb | name | payload |
//! |---|---|---|
//! | `0x81` | QUEUED | `u64 tag`, `u64 id` |
//! | `0x82` | PREFILLING | `u64 tag`, `u64 ts_us` |
//! | `0x83` | TOKEN | `u64 tag`, `u32 index`, `u16 token`, `u64 ts_us` |
//! | `0x84` | FINAL | `u64 tag`, `u8 finish`, `u64 queue_us`, `u64 prefill_us`, `u64 decode_us`, `u64 total_us`, `u32 n`, `n × u16` tokens |
//! | `0x85` | REJECTED | `u64 tag`, `u8 code` |
//!
//! The `tag` is a client-chosen request correlator echoed on every server
//! frame, so one connection can interleave many streams. `finish` codes:
//! 0 Done, 1 Length, 2 Cancelled, 3 DeadlineExceeded, 4 Error. Reject
//! codes: 0 BadRequest, 1 QueueFull, 2 ShuttingDown.
//!
//! Lifecycle mapping: a client that disconnects (or whose socket write
//! fails) drops the forwarder's [`StreamRx`], which cancels the request —
//! the TCP hang-up is the same signal as an in-process receiver drop.
//! Exactly one terminal frame (FINAL or REJECTED) answers every SUBMIT.

use crate::coordinator::request::{FinishReason, StreamEvent, SubmitError, SubmitOptions};
use crate::coordinator::EngineHandle;
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// Upper bound on a frame body; larger prefixes are a protocol error (a
/// desynced or hostile peer), not an allocation request.
pub const MAX_FRAME: usize = 1 << 20;

pub const VERB_SUBMIT: u8 = 0x01;
pub const VERB_CANCEL: u8 = 0x02;
pub const VERB_QUEUED: u8 = 0x81;
pub const VERB_PREFILLING: u8 = 0x82;
pub const VERB_TOKEN: u8 = 0x83;
pub const VERB_FINAL: u8 = 0x84;
pub const VERB_REJECTED: u8 = 0x85;

/// A client→server message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ClientMsg {
    Submit {
        tag: u64,
        gen_len: u32,
        top_k: u32,
        /// Sampling temperature × 1000, keeping the wire integer-only
        /// (0 = greedy).
        temp_milli: u32,
        /// 0 = no deadline.
        deadline_ms: u64,
        /// 0 = unbounded stream buffer.
        stream_buffer: u32,
        prompt: Vec<u16>,
    },
    Cancel { tag: u64 },
}

/// A server→client message; one per [`StreamEvent`], plus REJECTED for
/// submits the engine refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServerMsg {
    Queued { tag: u64, id: u64 },
    Prefilling { tag: u64, ts_us: u64 },
    Token { tag: u64, index: u32, token: u16, ts_us: u64 },
    Final {
        tag: u64,
        finish: u8,
        queue_us: u64,
        prefill_us: u64,
        decode_us: u64,
        total_us: u64,
        tokens: Vec<u16>,
    },
    Rejected { tag: u64, code: u8 },
}

pub fn finish_code(f: FinishReason) -> u8 {
    match f {
        FinishReason::Done => 0,
        FinishReason::Length => 1,
        FinishReason::Cancelled => 2,
        FinishReason::DeadlineExceeded => 3,
        FinishReason::Error => 4,
    }
}

pub fn finish_from_code(c: u8) -> Option<FinishReason> {
    Some(match c {
        0 => FinishReason::Done,
        1 => FinishReason::Length,
        2 => FinishReason::Cancelled,
        3 => FinishReason::DeadlineExceeded,
        4 => FinishReason::Error,
        _ => return None,
    })
}

pub fn reject_code(e: SubmitError) -> u8 {
    match e {
        SubmitError::BadRequest => 0,
        SubmitError::QueueFull => 1,
        SubmitError::ShuttingDown => 2,
    }
}

/// Little-endian cursor over a frame body.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cur { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], &'static str> {
        let end = self.i.checked_add(n).ok_or("length overflow")?;
        if end > self.b.len() {
            return Err("frame truncated");
        }
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, &'static str> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, &'static str> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, &'static str> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, &'static str> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u16s(&mut self) -> Result<Vec<u16>, &'static str> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME / 2 {
            return Err("token list longer than the frame bound");
        }
        let raw = self.take(n * 2)?;
        Ok(raw.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
    }

    fn done(&self) -> Result<(), &'static str> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err("trailing bytes after message")
        }
    }
}

fn put_u16s(out: &mut Vec<u8>, tokens: &[u16]) {
    out.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
    for &t in tokens {
        out.extend_from_slice(&t.to_le_bytes());
    }
}

impl ClientMsg {
    /// Frame body (verb + payload, no length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            ClientMsg::Submit {
                tag,
                gen_len,
                top_k,
                temp_milli,
                deadline_ms,
                stream_buffer,
                prompt,
            } => {
                out.push(VERB_SUBMIT);
                out.extend_from_slice(&tag.to_le_bytes());
                out.extend_from_slice(&gen_len.to_le_bytes());
                out.extend_from_slice(&top_k.to_le_bytes());
                out.extend_from_slice(&temp_milli.to_le_bytes());
                out.extend_from_slice(&deadline_ms.to_le_bytes());
                out.extend_from_slice(&stream_buffer.to_le_bytes());
                put_u16s(&mut out, prompt);
            }
            ClientMsg::Cancel { tag } => {
                out.push(VERB_CANCEL);
                out.extend_from_slice(&tag.to_le_bytes());
            }
        }
        out
    }

    pub fn decode(body: &[u8]) -> Result<Self, &'static str> {
        let mut c = Cur::new(body);
        let msg = match c.u8()? {
            VERB_SUBMIT => ClientMsg::Submit {
                tag: c.u64()?,
                gen_len: c.u32()?,
                top_k: c.u32()?,
                temp_milli: c.u32()?,
                deadline_ms: c.u64()?,
                stream_buffer: c.u32()?,
                prompt: c.u16s()?,
            },
            VERB_CANCEL => ClientMsg::Cancel { tag: c.u64()? },
            _ => return Err("unknown client verb"),
        };
        c.done()?;
        Ok(msg)
    }
}

impl ServerMsg {
    /// The wire form of one stream event, tagged for the client.
    pub fn from_event(tag: u64, ev: StreamEvent) -> ServerMsg {
        match ev {
            StreamEvent::Queued { id } => ServerMsg::Queued { tag, id },
            StreamEvent::Prefilling { ts_us, .. } => ServerMsg::Prefilling { tag, ts_us },
            StreamEvent::Token { index, token, ts_us, .. } => {
                ServerMsg::Token { tag, index, token, ts_us }
            }
            StreamEvent::Final(r) => ServerMsg::Final {
                tag,
                finish: finish_code(r.finish),
                queue_us: r.queue_us,
                prefill_us: r.prefill_us,
                decode_us: r.decode_us,
                total_us: r.total_us,
                tokens: r.tokens,
            },
        }
    }

    /// Frame body (verb + payload, no length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            ServerMsg::Queued { tag, id } => {
                out.push(VERB_QUEUED);
                out.extend_from_slice(&tag.to_le_bytes());
                out.extend_from_slice(&id.to_le_bytes());
            }
            ServerMsg::Prefilling { tag, ts_us } => {
                out.push(VERB_PREFILLING);
                out.extend_from_slice(&tag.to_le_bytes());
                out.extend_from_slice(&ts_us.to_le_bytes());
            }
            ServerMsg::Token { tag, index, token, ts_us } => {
                out.push(VERB_TOKEN);
                out.extend_from_slice(&tag.to_le_bytes());
                out.extend_from_slice(&index.to_le_bytes());
                out.extend_from_slice(&token.to_le_bytes());
                out.extend_from_slice(&ts_us.to_le_bytes());
            }
            ServerMsg::Final { tag, finish, queue_us, prefill_us, decode_us, total_us, tokens } => {
                out.push(VERB_FINAL);
                out.extend_from_slice(&tag.to_le_bytes());
                out.push(*finish);
                out.extend_from_slice(&queue_us.to_le_bytes());
                out.extend_from_slice(&prefill_us.to_le_bytes());
                out.extend_from_slice(&decode_us.to_le_bytes());
                out.extend_from_slice(&total_us.to_le_bytes());
                put_u16s(&mut out, tokens);
            }
            ServerMsg::Rejected { tag, code } => {
                out.push(VERB_REJECTED);
                out.extend_from_slice(&tag.to_le_bytes());
                out.push(*code);
            }
        }
        out
    }

    pub fn decode(body: &[u8]) -> Result<Self, &'static str> {
        let mut c = Cur::new(body);
        let msg = match c.u8()? {
            VERB_QUEUED => ServerMsg::Queued { tag: c.u64()?, id: c.u64()? },
            VERB_PREFILLING => ServerMsg::Prefilling { tag: c.u64()?, ts_us: c.u64()? },
            VERB_TOKEN => ServerMsg::Token {
                tag: c.u64()?,
                index: c.u32()?,
                token: c.u16()?,
                ts_us: c.u64()?,
            },
            VERB_FINAL => ServerMsg::Final {
                tag: c.u64()?,
                finish: c.u8()?,
                queue_us: c.u64()?,
                prefill_us: c.u64()?,
                decode_us: c.u64()?,
                total_us: c.u64()?,
                tokens: c.u16s()?,
            },
            VERB_REJECTED => ServerMsg::Rejected { tag: c.u64()?, code: c.u8()? },
            _ => return Err("unknown server verb"),
        };
        c.done()?;
        Ok(msg)
    }

    /// The request tag this frame answers.
    pub fn tag(&self) -> u64 {
        match self {
            ServerMsg::Queued { tag, .. }
            | ServerMsg::Prefilling { tag, .. }
            | ServerMsg::Token { tag, .. }
            | ServerMsg::Final { tag, .. }
            | ServerMsg::Rejected { tag, .. } => *tag,
        }
    }

    /// True for the terminal frames (FINAL and REJECTED).
    pub fn is_terminal(&self) -> bool {
        matches!(self, ServerMsg::Final { .. } | ServerMsg::Rejected { .. })
    }
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> io::Result<()> {
    debug_assert!(body.len() <= MAX_FRAME);
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Read one length-prefixed frame (blocking until complete).
pub fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame length"));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(body)
}

/// Incremental frame reassembly over a byte stream. Unlike
/// [`read_frame`], a read that times out (socket read-timeout used to
/// poll a stop flag) never loses partially-received bytes: they stay
/// buffered until the frame completes.
pub struct FrameReader<R> {
    src: R,
    buf: Vec<u8>,
}

impl<R: Read> FrameReader<R> {
    pub fn new(src: R) -> Self {
        FrameReader { src, buf: Vec::new() }
    }

    /// The next complete frame body; `Ok(None)` when the peer closed the
    /// stream cleanly or `stop` was raised while idle between frames.
    pub fn next_frame(&mut self, stop: &AtomicBool) -> io::Result<Option<Vec<u8>>> {
        loop {
            if let Some(frame) = self.take_buffered()? {
                return Ok(Some(frame));
            }
            if stop.load(Ordering::SeqCst) {
                return Ok(None);
            }
            let mut chunk = [0u8; 4096];
            match self.src.read(&mut chunk) {
                Ok(0) => return Ok(None),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock
                            | io::ErrorKind::TimedOut
                            | io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e),
            }
        }
    }

    fn take_buffered(&mut self) -> io::Result<Option<Vec<u8>>> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
        if len == 0 || len > MAX_FRAME {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame length"));
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let frame = self.buf[4..4 + len].to_vec();
        self.buf.drain(..4 + len);
        Ok(Some(frame))
    }
}

/// How often blocked reads/accepts wake to check the stop flag.
const POLL: Duration = Duration::from_millis(50);

/// A running TCP front-end: accepts connections and serves the wire
/// protocol on top of a shared [`EngineHandle`]. The engine outlives the
/// server (the `Arc` lets the caller recover and `shutdown()` it after
/// [`TcpServer::stop`]).
pub struct TcpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral test port) and
    /// start accepting.
    pub fn spawn(engine: Arc<EngineHandle>, addr: &str) -> io::Result<TcpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_l = Arc::clone(&stop);
        let join = thread::Builder::new()
            .name("intattn-serve-accept".into())
            .spawn(move || {
                let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
                while !stop_l.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let engine = Arc::clone(&engine);
                            let stop_c = Arc::clone(&stop_l);
                            conns.push(thread::spawn(move || {
                                handle_conn(stream, engine, stop_c)
                            }));
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => thread::sleep(POLL),
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })
            .expect("spawn accept thread");
        Ok(TcpServer { addr: local, stop, join: Some(join) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wait for open connections to drain, and join the
    /// accept thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Serve one connection: read verbs, fan submits out to per-request
/// forwarder threads writing to the shared (mutexed) socket.
fn handle_conn(stream: TcpStream, engine: Arc<EngineHandle>, stop: Arc<AtomicBool>) {
    let Ok(write_half) = stream.try_clone() else { return };
    let out = Arc::new(Mutex::new(write_half));
    let _ = stream.set_read_timeout(Some(POLL));
    let mut reader = FrameReader::new(stream);
    let mut cancels: HashMap<u64, crate::coordinator::request::CancelToken> = HashMap::new();
    let mut forwarders: Vec<thread::JoinHandle<()>> = Vec::new();
    loop {
        let body = match reader.next_frame(&stop) {
            Ok(Some(b)) => b,
            Ok(None) | Err(_) => break,
        };
        let Ok(msg) = ClientMsg::decode(&body) else { break };
        match msg {
            ClientMsg::Submit {
                tag,
                gen_len,
                top_k,
                temp_milli,
                deadline_ms,
                stream_buffer,
                prompt,
            } => {
                let mut opts =
                    SubmitOptions::sampling(temp_milli as f32 / 1000.0, (top_k as usize).max(1))
                        .with_stream_buffer(stream_buffer as usize);
                if deadline_ms > 0 {
                    opts = opts.with_deadline(Duration::from_millis(deadline_ms));
                }
                match engine.submit(prompt, gen_len as usize, opts) {
                    Ok(rx) => {
                        cancels.insert(tag, rx.cancel_token());
                        let out = Arc::clone(&out);
                        forwarders.push(thread::spawn(move || forward_stream(rx, tag, out)));
                    }
                    Err(e) => {
                        let reject = ServerMsg::Rejected { tag, code: reject_code(e) };
                        if write_shared(&out, &reject).is_err() {
                            break;
                        }
                    }
                }
            }
            ClientMsg::Cancel { tag } => {
                if let Some(tok) = cancels.get(&tag) {
                    tok.cancel();
                }
            }
        }
    }
    // Reader gone (hang-up, stop, or protocol error). Forwarders terminate
    // on their own: the engine delivers every stream a Final, and a dead
    // socket fails their writes (dropping the StreamRx = cancel).
    for f in forwarders {
        let _ = f.join();
    }
}

/// Relay one request's stream to the socket until `Final` (or until the
/// socket dies — dropping the receiver then cancels the request).
fn forward_stream(
    mut rx: crate::coordinator::request::StreamRx,
    tag: u64,
    out: Arc<Mutex<TcpStream>>,
) {
    loop {
        let Ok(ev) = rx.recv() else { return };
        let done = matches!(ev, StreamEvent::Final(_));
        if write_shared(&out, &ServerMsg::from_event(tag, ev)).is_err() {
            return;
        }
        if done {
            return;
        }
    }
}

fn write_shared(out: &Arc<Mutex<TcpStream>>, msg: &ServerMsg) -> io::Result<()> {
    let mut w = out.lock().unwrap_or_else(|e| e.into_inner());
    write_frame(&mut *w, &msg.encode())
}

/// Drive one streamed request over TCP as a client: connect, SUBMIT, and
/// collect every frame for our tag through the terminal one. The shared
/// smoke-test path for `serve --client` and the integration tests.
pub fn run_client(
    addr: &str,
    prompt: &[u16],
    gen_len: usize,
    opts: SubmitOptions,
) -> io::Result<Vec<ServerMsg>> {
    let mut stream = TcpStream::connect(addr)?;
    let submit = ClientMsg::Submit {
        tag: 1,
        gen_len: gen_len as u32,
        top_k: opts.top_k as u32,
        temp_milli: (opts.temperature * 1000.0) as u32,
        deadline_ms: opts.deadline.map(|d| d.as_millis() as u64).unwrap_or(0),
        stream_buffer: opts.stream_buffer as u32,
        prompt: prompt.to_vec(),
    };
    write_frame(&mut stream, &submit.encode())?;
    let mut events = Vec::new();
    loop {
        let body = read_frame(&mut stream)?;
        let msg = ServerMsg::decode(&body)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
        let done = msg.is_terminal();
        events.push(msg);
        if done {
            return Ok(events);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_messages_roundtrip() {
        let msgs = [
            ClientMsg::Submit {
                tag: 7,
                gen_len: 16,
                top_k: 8,
                temp_milli: 700,
                deadline_ms: 250,
                stream_buffer: 64,
                prompt: vec![1, 2, 300, 65535],
            },
            ClientMsg::Cancel { tag: 7 },
        ];
        for m in msgs {
            let body = m.encode();
            assert_eq!(ClientMsg::decode(&body).unwrap(), m);
        }
    }

    #[test]
    fn server_messages_roundtrip() {
        let msgs = [
            ServerMsg::Queued { tag: 1, id: 42 },
            ServerMsg::Prefilling { tag: 1, ts_us: 123 },
            ServerMsg::Token { tag: 1, index: 3, token: 999, ts_us: 456 },
            ServerMsg::Final {
                tag: 1,
                finish: finish_code(FinishReason::Length),
                queue_us: 1,
                prefill_us: 2,
                decode_us: 3,
                total_us: 6,
                tokens: vec![4, 5, 6],
            },
            ServerMsg::Rejected { tag: 2, code: reject_code(SubmitError::QueueFull) },
        ];
        for m in msgs {
            let body = m.encode();
            let back = ServerMsg::decode(&body).unwrap();
            assert_eq!(back, m);
            assert_eq!(back.tag(), m.tag());
        }
        assert!(ServerMsg::Rejected { tag: 0, code: 0 }.is_terminal());
        assert!(!ServerMsg::Queued { tag: 0, id: 0 }.is_terminal());
    }

    #[test]
    fn finish_codes_roundtrip() {
        for f in [
            FinishReason::Done,
            FinishReason::Length,
            FinishReason::Cancelled,
            FinishReason::DeadlineExceeded,
            FinishReason::Error,
        ] {
            assert_eq!(finish_from_code(finish_code(f)), Some(f));
        }
        assert_eq!(finish_from_code(9), None);
    }

    #[test]
    fn decode_rejects_malformed_frames() {
        assert!(ClientMsg::decode(&[]).is_err(), "empty body");
        assert!(ClientMsg::decode(&[0x7f]).is_err(), "unknown verb");
        let mut body = ClientMsg::Cancel { tag: 3 }.encode();
        body.push(0); // trailing garbage
        assert!(ClientMsg::decode(&body).is_err());
        let body = ClientMsg::Submit {
            tag: 1,
            gen_len: 1,
            top_k: 1,
            temp_milli: 0,
            deadline_ms: 0,
            stream_buffer: 0,
            prompt: vec![1, 2, 3],
        }
        .encode();
        assert!(ClientMsg::decode(&body[..body.len() - 1]).is_err(), "truncated");
    }

    /// A reader that hands out its script one byte at a time with a fake
    /// timeout between bytes — the worst case a socket with a read
    /// timeout produces, which must never desync the framing.
    struct Trickle {
        data: Vec<u8>,
        at: usize,
        hiccup: bool,
    }

    impl Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if self.at >= self.data.len() {
                return Ok(0);
            }
            self.hiccup = !self.hiccup;
            if self.hiccup {
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "trickle"));
            }
            buf[0] = self.data[self.at];
            self.at += 1;
            Ok(1)
        }
    }

    #[test]
    fn frame_reader_reassembles_byte_at_a_time() {
        let m1 = ServerMsg::Token { tag: 9, index: 0, token: 17, ts_us: 5 };
        let m2 = ServerMsg::Rejected { tag: 9, code: 2 };
        let mut data = Vec::new();
        for m in [&m1, &m2] {
            let body = m.encode();
            data.extend_from_slice(&(body.len() as u32).to_le_bytes());
            data.extend_from_slice(&body);
        }
        let stop = AtomicBool::new(false);
        let mut fr = FrameReader::new(Trickle { data, at: 0, hiccup: false });
        let f1 = fr.next_frame(&stop).unwrap().expect("first frame");
        assert_eq!(ServerMsg::decode(&f1).unwrap(), m1);
        let f2 = fr.next_frame(&stop).unwrap().expect("second frame");
        assert_eq!(ServerMsg::decode(&f2).unwrap(), m2);
        assert!(fr.next_frame(&stop).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn frame_reader_rejects_oversized_prefix() {
        let mut data = Vec::new();
        data.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        data.extend_from_slice(&[0; 8]);
        let stop = AtomicBool::new(false);
        let mut fr = FrameReader::new(Trickle { data, at: 0, hiccup: false });
        assert!(fr.next_frame(&stop).is_err());
    }
}
