//! The serving engine: a scheduler thread running continuous batching over
//! the tiny LM, with bounded-queue admission (backpressure), per-token
//! streamed delivery, and metrics.
//!
//! ## Request lifecycle (streaming states)
//!
//! Every accepted submit returns a [`StreamRx`] over which the scheduler
//! narrates the request's life as [`StreamEvent`]s — the states below *are*
//! the events on the wire:
//!
//! ```text
//!            submit            admission            prefill done
//! (accepted) ──────► Queued ──────────► Prefilling ─────────────► Token #0
//!                      │                    │                        │
//!                      │                    │              batched decode rounds
//!                      │                    │                        ▼
//!                      │                    │                 Token #1 … #n ──► Final{Done|Length}
//!                      │                    │                        │
//!                      ▼                    ▼                        ▼
//!                   Final{Cancelled | DeadlineExceeded}    Final{Cancelled |
//!                    (swept from the wait queue)            DeadlineExceeded | Error}
//! ```
//!
//! * `Queued` is emitted by the handle the moment a submit is accepted;
//!   it is always the stream's first event.
//! * `Prefilling` is emitted when the request admits into the active set;
//!   its timestamp is the queueing delay. A request swept from the wait
//!   queue (cancel/deadline/drain) retires without ever reaching this
//!   state, so the event is absent from its stream.
//! * One `Token` event per decoded token, emitted **as each round's
//!   batched decode lands** (the first token is sampled at prefill
//!   completion): strictly sequential indexes, decode order, µs
//!   timestamps on the request's arrival clock.
//! * Exactly one terminal `Final` per accepted submit, whatever path the
//!   request takes, carrying the full [`Response`] (token sequence +
//!   timing breakdown derived from the same stamps as the events — see
//!   [`Response`]). Nothing follows `Final`.
//!
//! Terminal reasons:
//!
//! * **Done / Length** — ran to `gen_len`, or the context filled first
//!   (truncated, never padded).
//! * **Cancelled** — the client called [`CancelToken::cancel`], dropped its
//!   [`StreamRx`] (hang-up = implicit cancel), fell behind a bounded
//!   [`SubmitOptions::stream_buffer`] (a client that stopped reading is
//!   indistinguishable from one that vanished — the engine must not buffer
//!   without bound), or a drain/hard-stop answered work the engine will
//!   not run. Partial tokens are returned.
//! * **DeadlineExceeded** — the submit-relative deadline
//!   ([`SubmitOptions::deadline`]) passed; checked at every round boundary
//!   for queued and active requests alike.
//! * **Error** — the request's model step panicked. The panic is caught
//!   ([`std::panic::catch_unwind`]) and the poisoned request retired; the
//!   scheduler, the other in-flight requests and the prefix index survive.
//!
//! Cancellation/deadline/overflow checks run at round boundaries; a
//! retired request's [`KvCache`] drops the same round, returning its pages
//! to the process-wide pool immediately. Clients that only want the
//! terminal response call [`StreamRx::recv_all`] — the whole-`Response`
//! compatibility shim over the same stream.
//!
//! ## Panic isolation
//!
//! Prefill steps are caught per request, so a poisoned prefill touches
//! nothing but its own cache. The batched decode step is caught around the
//! whole batch; injected faults ([`crate::util::fault`]) fire at step entry
//! — before any cache mutation — and carry their victim's id, so only the
//! victim is poisoned and every other sequence decodes normally on the next
//! round. A non-attributable panic mid-batch leaves the batch's caches
//! indeterminate, so the whole batch retires as `Error` rather than decode
//! from poisoned KV. Shared prefix pages a poisoned donor registered stay
//! adoptable: index snapshots are complete page/scale sets refcounted
//! independently of the donor's cache, and only aligned, fully-computed
//! boundaries are ever registered.
//!
//! ## Graceful drain
//!
//! [`EngineHandle::shutdown`] (and handle drop) signals a drain: the
//! scheduler stops admitting, answers every queued request with a terminal
//! `Cancelled` response instead of dropping it on the floor, and finishes
//! the in-flight prefills/decodes. A hard-stop knob
//! ([`EngineOptions::drain_timeout`], default `INTATTN_DRAIN_TIMEOUT_MS`)
//! bounds the drain: once exceeded, still-running requests retire
//! `Cancelled` with their partial tokens. `shutdown` re-raises a scheduler
//! panic ([`std::panic::resume_unwind`]); a drop-path join failure is
//! logged and counted in [`scheduler_panics`] instead (never panic in
//! drop), so a crashed engine cannot masquerade as a clean exit either way.
//!
//! ## Scheduling
//!
//! Scheduling loop (one "round"):
//!   1. Drain the submit channel into the wait queue; reject on overflow.
//!      Then the lifecycle sweep: cancelled/expired requests (queued or
//!      active) retire with their terminal reason, and during a drain the
//!      whole wait queue answers `Cancelled`.
//!   2. Admit new requests per [`BatchPolicy`] (prefill phase; emits
//!      `Prefilling` and records TTFT). Admissions interleave into
//!      in-flight decode under the `waiting_served_ratio` gate
//!      ([`BatchPolicy::waiting_served_ratio`]): while decodes run, new
//!      prefills wait until the waiting set is worth the stall (or a
//!      straggler ages past [`BatchPolicy::max_waiting_rounds`]), so token
//!      streams keep flowing instead of hiccuping for every lone arrival.
//!      Admission also runs under the **KV page budget**: each candidate charges its
//!      projected footprint — [`KvCache::pages_for_tokens`] over prompt +
//!      full generation — against [`BatchPolicy::max_kv_pages`], and a
//!      request that would overflow waits (pinned head-of-line, so smaller
//!      arrivals cannot leapfrog it forever). Pages are the natural unit
//!      because KV residency *is* paged: fixed-size pages from a
//!      process-wide recycling pool
//!      ([`crate::attention::state::PagedRows`]), so the page count equals
//!      allocated capacity exactly — the old byte budget estimated payload
//!      from `len` and could undercount peak RSS by the `Vec` growth slack.
//!      With **prefix sharing** on ([`BatchPolicy::prefix_share`]), an
//!      admission first consults the [`PrefixIndex`]: if the prompt's
//!      longest aligned prefix is registered, the request **adopts** the
//!      snapshot's pages by copy-on-write reference and starts its prefill
//!      at the adopted position — and its budget charge drops by the
//!      adopted pages, so a shared prefix is charged once, by whichever
//!      request first computed it.
//!   3. Advance prefills (one chunk per request per round), then **one
//!      batched decode step** over every decoding request: the per-layer
//!      Q/K/V projections of the B active sequences stack into single
//!      `B×d_model` GEMMs, and each head's B attention products run as one
//!      grouped integer-GEMM launch over the B resident KV **page lists**
//!      ([`TinyLm::decode_step_batch`]) — instead of B memory-bound 1-row
//!      GEMM pairs per round. Per sequence the results are bit-identical to
//!      the sequential loop; only the kernel shapes change. Appends fill
//!      each state's tail page in place, so a long-running sequence never
//!      re-copies its history the way contiguous `Vec` growth did. Every
//!      token sampled this round — prefill-completion firsts and decode
//!      nexts alike — is emitted as a `Token` event before the round ends:
//!      clients observe tokens at decode cadence, not at request end.
//!   4. Retire finished requests, emitting their terminal `Final`. Dropping a
//!      retired request's [`KvCache`] returns its pages to the pool **that
//!      same round**, which is what lets the next KV-deferred request in
//!      the queue admit (and reuse those very pages); pages the prefix
//!      index still references stay alive for future adopters and are
//!      released when their entry is evicted. A request the context cuts
//!      off early is truncated (never padded) and finishes with
//!      [`FinishReason::Length`].
//!
//! ## Copy-on-write prefix sharing (ownership rules)
//!
//! The scheduler owns one [`PrefixIndex`] (built only when
//! `policy.prefix_share && policy.prefill_chunk > 0`). Each prefill chunk
//! that ends exactly on an aligned boundary (`lcm(page_rows,
//! prefill_chunk)` tokens) **registers** a snapshot: the prompt run so far
//! plus a [`KvCache::share_prefix`] of the live cache — page references,
//! not copies, paired with the integer states' running scales *at that
//! boundary* (that pairing is what makes the snapshot adoptable
//! byte-identically; see `crate::coordinator::prefix`). A request may adopt
//! at admission or **mid-prefill** (a later round may register a longer
//! prefix of the same prompt — trailing same-prompt requests upgrade to it,
//! which is how N simultaneous identical prompts converge onto one page
//! set). After adoption nobody owns shared pages exclusively: the donor,
//! the index entry and every adopter each hold references, every one of
//! them forks a shared page before mutating it (tail-page append at an
//! unaligned boundary, INT8 re-scale when a suffix row grows the running
//! abs-max), and the last holder returns the page to the pool. Sharing is
//! therefore *invisible*: outputs are byte-identical to unshared execution
//! (`decode_equivalence` + `serving_e2e` assert this), only the
//! `prefix_hits` / `shared_kv_pages` / `kv_cow_forks` metrics and the page
//! traffic change.
//!
//! Single scheduler thread: on the target class of devices (and this host)
//! compute is the bottleneck, not I/O, so the engine keeps the model on one
//! thread and exposes concurrency through batching — the same topology the
//! paper's measurement setup uses (worker threads inside the kernels, one
//! request loop). The kernel workers are the process-wide persistent
//! [`ParallelPool`](crate::util::threadpool::ParallelPool) (sized once from
//! `INTATTN_THREADS`, default: available parallelism) — the engine no
//! longer threads a `threads` knob through the model; every decode-round
//! launch dispatches onto already-parked workers in ~µs instead of
//! spawning OS threads. The batched decode is what gives those workers
//! useful work during decode: a single sequence's 1-row GEMM cannot be
//! split across workers, a batch of sequences can.

use crate::attention::{kv_page_rows, PipelineKind};
use crate::coordinator::batcher::{select_admissions, BatchPolicy};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::prefix::{PrefixIndex, PREFIX_INDEX_CAP};
use crate::coordinator::request::{
    CancelToken, FinishReason, Request, Response, StreamEvent, StreamRx, StreamTx, SubmitError,
    SubmitOptions,
};
use crate::model::lm::{sample_row, KvCache, TinyLm};
use crate::model::weights::Weights;
use crate::util::fault;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub attention: PipelineKind,
    pub policy: BatchPolicy,
    /// Bounded wait-queue depth; submits beyond this are rejected.
    pub max_queue: usize,
    /// Hard stop for the shutdown drain: once a drain has run this long,
    /// still-unfinished requests retire `Cancelled` with partial tokens
    /// instead of holding the shutdown hostage. `Duration::ZERO` waits
    /// forever. Defaults from `INTATTN_DRAIN_TIMEOUT_MS`.
    pub drain_timeout: Duration,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            attention: PipelineKind::IntAttention,
            policy: BatchPolicy::default(),
            max_queue: 64,
            drain_timeout: Duration::from_millis(crate::util::env::knobs().drain_timeout_ms),
        }
    }
}

/// Scheduler threads that terminated by panic, observed at handle drop
/// (process-wide, monotone). [`EngineHandle::shutdown`] re-raises the panic
/// instead of counting it here.
static SCHEDULER_PANICS: AtomicU64 = AtomicU64::new(0);

/// How many engine scheduler threads have died by panic (and were detected
/// on the handle-drop path, which must not itself panic). A supervisor can
/// watch this the way it watches the page-pool counters.
pub fn scheduler_panics() -> u64 {
    SCHEDULER_PANICS.load(Ordering::SeqCst)
}

/// A request in flight. Admission starts it in the prefill phase
/// (`prompt_pos < prompt.len()`); once the last prompt chunk is absorbed the
/// first token is sampled and it moves to the decode phase.
struct Active {
    req: Request,
    cache: KvCache,
    /// Prompt tokens already prefilled into the cache.
    prompt_pos: usize,
    /// Prompt tokens adopted from the prefix index (copy-on-write page
    /// references) rather than computed — the request's KV budget charge
    /// excludes their pages (a shared prefix is charged once, by the
    /// request that first computed it).
    adopted_rows: usize,
    generated: Vec<u16>,
    /// Set when the model's context fills before `gen_len` tokens: the
    /// request retires with what it actually generated
    /// ([`FinishReason::Length`]) — the tail is never padded.
    capped: bool,
    /// Set when this request's model step panicked: it is poisoned and
    /// retires with [`FinishReason::Error`] this round, partial tokens
    /// attached; nothing else shares its fate.
    failed: bool,
    /// Admission stamp (µs since arrival) — the `Prefilling` event's
    /// timestamp and the response's `queue_us`, one and the same.
    admitted_us: u64,
    /// First-token stamp (µs since arrival) — the `Token { index: 0 }`
    /// event's timestamp; `None` while still prefilling. The response's
    /// `prefill_us`/`decode_us` split derives from it at retirement.
    first_token_us: Option<u64>,
    /// Stamp of the most recent token (µs since arrival), for the
    /// engine-side inter-token latency histogram.
    last_token_us: u64,
    rng: crate::util::prng::Pcg64,
}

impl Active {
    fn prefilling(&self) -> bool {
        self.prompt_pos < self.req.prompt.len()
    }
}

/// Public handle: submit requests, read metrics, shut down.
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
    metrics: Metrics,
    queue_len: Arc<AtomicU64>,
    max_queue: usize,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    max_context: usize,
}

impl EngineHandle {
    /// Submit a generation request; returns the stream handle (event
    /// receiver + cancel lever). Sampling, deadline and stream-buffer
    /// parameters all ride on the [`SubmitOptions`] builder; exactly one
    /// terminal [`StreamEvent::Final`] arrives per accepted submit, and
    /// dropping the returned [`StreamRx`] before it cancels the request.
    pub fn submit(
        &self,
        prompt: Vec<u16>,
        gen_len: usize,
        opts: SubmitOptions,
    ) -> Result<StreamRx, SubmitError> {
        if self.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        // The prompt must fit and leave room for at least one generated
        // token. A `gen_len` that overruns the remaining context is NOT a
        // rejection: the request runs until the context fills and finishes
        // truncated with [`FinishReason::Length`].
        if prompt.is_empty() || prompt.len() >= self.max_context {
            self.metrics.on_reject();
            return Err(SubmitError::BadRequest);
        }
        // Admission control: bounded queue.
        if self.queue_len.load(Ordering::SeqCst) as usize >= self.max_queue {
            self.metrics.on_reject();
            return Err(SubmitError::QueueFull);
        }
        self.queue_len.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        let cancel = CancelToken::new();
        let pending = Arc::new(AtomicUsize::new(0));
        let stream = StreamTx::new(tx, Arc::clone(&pending), opts.stream_buffer);
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        // `Queued` is the stream's first event — emitted here, before the
        // scheduler can see the request, so it causally precedes every
        // scheduler-side event on the same channel.
        stream.send(StreamEvent::Queued { id });
        let req = Request {
            id,
            prompt,
            gen_len: gen_len.max(1),
            temperature: opts.temperature,
            top_k: opts.top_k.max(1),
            arrived: Instant::now(),
            deadline: opts.deadline,
            waited_rounds: 0,
            cancel: cancel.clone(),
            stream,
        };
        if self.tx.send(req).is_err() {
            // The scheduler thread is gone (it only exits by shutdown or
            // panic): roll back the queue-length charge — a leaked
            // increment would eventually wedge every later submit on a
            // phantom-full queue — and report the engine down rather than
            // hand out a receiver nothing will ever answer.
            self.queue_len.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitError::ShuttingDown);
        }
        self.metrics.on_submit();
        Ok(StreamRx::new(rx, cancel, pending))
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Signal a drain and join the scheduler: queued requests answer
    /// `Cancelled`, in-flight requests finish (bounded by
    /// [`EngineOptions::drain_timeout`]). A scheduler panic is re-raised
    /// here — a crashed engine must not masquerade as a clean shutdown.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            if let Err(payload) = j.join() {
                std::panic::resume_unwind(payload);
            }
        }
        self.metrics.snapshot()
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            // Drop must not panic (it may already be running during an
            // unwind): a scheduler panic on this path is logged and counted
            // instead of resumed — see [`scheduler_panics`].
            if j.join().is_err() {
                SCHEDULER_PANICS.fetch_add(1, Ordering::SeqCst);
                crate::log_error!("scheduler thread panicked (detected at handle drop)");
            }
        }
    }
}

/// Engine constructor.
pub struct Engine;

impl Engine {
    /// Start the scheduler thread and return a handle. The handle enforces
    /// `opts.max_queue` on every submit (bounded queue → backpressure).
    pub fn start(weights: Weights, opts: EngineOptions) -> EngineHandle {
        // First engine in the process arms the environment's fault plan (a
        // no-op unless `INTATTN_FAULT` is set; tests arm programmatically).
        fault::ensure_env_armed();
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Metrics::new();
        let queue_len = Arc::new(AtomicU64::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));
        let max_context = weights.cfg.max_seq;
        let max_queue = opts.max_queue;

        let m = metrics.clone();
        let ql = Arc::clone(&queue_len);
        let sd = Arc::clone(&shutdown);
        let join = std::thread::Builder::new()
            .name("intattn-scheduler".into())
            .spawn(move || scheduler_loop(weights, opts, rx, m, ql, sd))
            .expect("spawn scheduler");

        EngineHandle {
            tx,
            metrics,
            queue_len,
            max_queue,
            next_id: AtomicU64::new(1),
            shutdown,
            join: Some(join),
            max_context,
        }
    }
}

/// Answer a request that never ran (swept from the wait queue) with its
/// terminal `Final`: empty tokens, its whole life counted as queueing.
fn send_terminal(metrics: &Metrics, req: Request, finish: FinishReason) {
    let queue_us = req.arrived.elapsed().as_micros() as u64;
    let resp = Response {
        id: req.id,
        tokens: Vec::new(),
        finish,
        queue_us,
        prefill_us: 0,
        decode_us: 0,
        total_us: queue_us,
    };
    metrics.on_complete(&resp);
    req.stream.send(StreamEvent::Final(resp)); // receiver may have gone away
}

/// Retire an in-flight request with `finish` and its partial (or full)
/// output, emitting the stream's terminal `Final`. The µs timing fields are
/// derived here from the request's event stamps — admission, first token,
/// and the retirement stamp taken now, all on the arrival clock — so the
/// stream and the terminal breakdown agree by construction and
/// `queue_us + prefill_us + decode_us == total_us` holds exactly. Dropping
/// `a` — and with it the [`KvCache`] — returns every page the sequence held
/// to the process-wide pool this same round.
fn retire_active(metrics: &Metrics, a: Active, finish: FinishReason) {
    let total_us = a.req.arrived.elapsed().as_micros() as u64;
    let queue_us = a.admitted_us;
    let (prefill_us, decode_us) = match a.first_token_us {
        // Prefill completed: the first-token stamp splits the post-queue
        // life into prefill and decode.
        Some(first) => (first.saturating_sub(queue_us), total_us.saturating_sub(first)),
        // Cut mid-prefill: the whole post-queue life was prefill.
        None => (total_us.saturating_sub(queue_us), 0),
    };
    let resp = Response {
        id: a.req.id,
        finish,
        tokens: a.generated,
        queue_us,
        prefill_us,
        decode_us,
        total_us,
    };
    metrics.on_complete(&resp);
    // A failed send means the receiver is gone — the client's hang-up is an
    // implicit cancel, normally caught earlier via the CancelToken; at this
    // point the request is retiring anyway, so delivery is best-effort.
    a.req.stream.send(StreamEvent::Final(resp));
}

fn scheduler_loop(
    weights: Weights,
    opts: EngineOptions,
    rx: mpsc::Receiver<Request>,
    metrics: Metrics,
    queue_len: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
) {
    let mut lm = TinyLm::new(weights, opts.attention);
    let cfg = *lm.config();
    let mut waiting: VecDeque<Request> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    // Prefix-sharing index (None when disabled, or when prefill chunking is
    // off — without chunk boundaries a shared prefix could not be resumed
    // byte-identically; see `crate::coordinator::prefix`).
    let mut prefix_index: Option<PrefixIndex> = if opts.policy.prefix_share {
        PrefixIndex::new(kv_page_rows(), opts.policy.prefill_chunk, PREFIX_INDEX_CAP)
    } else {
        None
    };
    // Head-of-line guarantee for the KV budget: once a request is deferred
    // for KV memory, its id is pinned here and no other request may admit
    // ahead of it on any later round (shortest-first would otherwise let a
    // stream of small requests starve it forever).
    let mut kv_head: Option<u64> = None;
    // Set the round the shutdown flag is first observed; the drain's
    // hard-stop clock and the `drain_duration` metric both run from here.
    let mut drain_started: Option<Instant> = None;

    loop {
        fault::on_round();
        // (1) drain submissions.
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    queue_len.fetch_sub(1, Ordering::SeqCst);
                    waiting.push_back(req);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    if active.is_empty() && waiting.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        let draining = shutdown.load(Ordering::SeqCst);
        if draining && drain_started.is_none() {
            drain_started = Some(Instant::now());
        }

        // (1b) lifecycle sweep — wait queue: cancelled or expired requests
        // answer immediately, and during a drain *every* queued request
        // answers `Cancelled` instead of being dropped on the floor.
        if !waiting.is_empty() {
            let mut keep: VecDeque<Request> = VecDeque::with_capacity(waiting.len());
            for mut req in waiting.drain(..) {
                let finish = if req.cancel.is_cancelled() {
                    Some(FinishReason::Cancelled)
                } else if req.deadline_exceeded() {
                    Some(FinishReason::DeadlineExceeded)
                } else if draining {
                    Some(FinishReason::Cancelled)
                } else {
                    None
                };
                match finish {
                    Some(f) => send_terminal(&metrics, req, f),
                    None => {
                        // Age for the admission gate's straggler valve.
                        req.waited_rounds += 1;
                        keep.push_back(req);
                    }
                }
            }
            waiting = keep;
        }
        // (1c) lifecycle sweep — active set: a cancelled/expired request —
        // or one whose client stopped reading a bounded stream — retires
        // right now, partial tokens attached; dropping its cache returns
        // the pages to the pool this round (the freed budget is visible to
        // this very round's admissions).
        let mut i = 0;
        while i < active.len() {
            let finish = if active[i].req.cancel.is_cancelled() {
                Some(FinishReason::Cancelled)
            } else if active[i].req.deadline_exceeded() {
                Some(FinishReason::DeadlineExceeded)
            } else if active[i].req.stream.overflowed() {
                metrics.on_stream_overflow();
                Some(FinishReason::Cancelled)
            } else {
                None
            };
            if let Some(f) = finish {
                let a = active.swap_remove(i);
                retire_active(&metrics, a, f);
            } else {
                i += 1;
            }
        }

        if draining {
            if active.is_empty() && waiting.is_empty() {
                let us = drain_started.map_or(0, |t| t.elapsed().as_micros() as u64);
                metrics.on_drain(us);
                return;
            }
            // Hard stop: the drain has run past its budget — answer
            // everything still in flight `Cancelled` (partial tokens) and
            // exit rather than hold the shutdown hostage to a stuck step.
            if opts.drain_timeout != Duration::ZERO
                && drain_started.is_some_and(|t| t.elapsed() >= opts.drain_timeout)
            {
                crate::log_warn!(
                    "drain hard stop after {:?}: cancelling {} in-flight request(s)",
                    opts.drain_timeout,
                    active.len()
                );
                for a in active.drain(..) {
                    retire_active(&metrics, a, FinishReason::Cancelled);
                }
                let us = drain_started.map_or(0, |t| t.elapsed().as_micros() as u64);
                metrics.on_drain(us);
                return;
            }
        }
        if waiting.is_empty() && active.is_empty() {
            // Idle: block briefly for the next request to avoid spinning.
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(req) => {
                    queue_len.fetch_sub(1, Ordering::SeqCst);
                    waiting.push_back(req);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    continue;
                }
            }
        }

        // (2) admissions, under the KV page budget — none during a drain.
        // While a KV-deferred request is pinned as kv_head, it is the
        // *only* admission candidate: selecting others and then vetoing
        // them post-hoc would livelock under sustained load
        // (shortest-first may never re-select the pinned id while shorter
        // prompts keep arriving, and the veto would bounce every selected
        // request forever).
        let admitted: Vec<Request> = if draining {
            Vec::new()
        } else if let Some(id) = kv_head {
            if active.len() >= opts.policy.max_active {
                Vec::new()
            } else if let Some(pos) = waiting.iter().position(|r| r.id == id) {
                vec![waiting.remove(pos).expect("position valid")]
            } else {
                // Pinned id no longer queued (a sweep may have answered it,
                // and ids otherwise only leave the queue via admission) —
                // unpin and admit normally.
                kv_head = None;
                select_admissions(&mut waiting, active.len(), &opts.policy)
            }
        } else {
            select_admissions(&mut waiting, active.len(), &opts.policy)
        };
        // Reserve each active sequence's *projected* footprint in pages
        // (prompt + full generation, every layer/head/side rounded up to
        // whole pages — exactly what the paged states will allocate), not
        // just what its cache holds right now — otherwise concurrent
        // decodes grow past the budget after admission.
        // A projection can never exceed the model context: overrunning
        // requests are truncated at max_seq (FinishReason::Length).
        let projected_tokens =
            |req: &Request| (req.prompt.len() + req.gen_len).min(cfg.max_seq);
        let projected_pages = |req: &Request| KvCache::pages_for_tokens(projected_tokens(req), &cfg);
        // Shared prefix pages are charged once: every active request's
        // reservation excludes the pages it adopted by reference (adopted
        // lengths are page-aligned, so the subtraction removes exactly the
        // whole pages the adopter did not allocate).
        let mut kv_reserved: usize = active
            .iter()
            .map(|a| projected_pages(&a.req) - KvCache::pages_for_tokens(a.adopted_rows, &cfg))
            .sum();
        // Prefix-index pages count against the same physical budget: shared
        // prefix pages are charged **once** — to the index that pins them —
        // while every adopter's reservation excludes them. (Entry sums may
        // overlap chained snapshots of one prompt, which only overcharges —
        // the safe direction; the one uncovered window is pages adopted
        // from a since-evicted entry, which stay resident with their
        // adopters but charged to none until those adopters retire.)
        let pinned = |ix: &Option<PrefixIndex>| ix.as_ref().map_or(0, |i| i.pinned_pages());
        let mut deferred: Vec<Request> = Vec::new();
        for req in admitted {
            // Peek the longest adoptable prefix — a hash scan only; the CoW
            // cache is materialized after the request passes admission, so
            // deferred requests never pay for page-reference clones.
            let adopted_rows =
                prefix_index.as_ref().map_or(0, |ix| ix.match_len(&req.prompt, 0));
            let projected =
                projected_pages(&req) - KvCache::pages_for_tokens(adopted_rows, &cfg);
            // Under budget pressure, cached-but-idle prefixes yield first:
            // evict index entries (oldest first, sparing only the exact
            // entry this candidate is about to adopt — evicting it would
            // invalidate the peeked discount) before deferring a live
            // request. Skipped when eviction cannot change the outcome:
            // a candidate behind the kv_head pin defers regardless, and
            // with an empty active set the over-budget bypass admits
            // regardless — draining the cache would be pure waste.
            if opts.policy.max_kv_pages > 0
                && !active.is_empty()
                && !kv_head.is_some_and(|id| id != req.id)
            {
                while kv_reserved + pinned(&prefix_index) + projected > opts.policy.max_kv_pages
                    && prefix_index
                        .as_mut()
                        .is_some_and(|ix| ix.evict_oldest_excluding(&req.prompt[..adopted_rows]))
                {}
            }
            if kv_head.is_some_and(|id| id != req.id)
                || (opts.policy.max_kv_pages > 0
                    && kv_reserved + pinned(&prefix_index) + projected > opts.policy.max_kv_pages
                    && !active.is_empty())
            {
                // Over budget (or behind a previously KV-deferred request):
                // wait for running sequences to retire. The oldest deferred
                // request is pinned as `kv_head`, so later/smaller arrivals
                // cannot leapfrog it across rounds; a request too big for
                // the whole budget still runs once the active set drains.
                if kv_head.is_none() {
                    kv_head = Some(req.id);
                }
                deferred.push(req);
                continue;
            }
            if kv_head == Some(req.id) {
                kv_head = None;
            }
            kv_reserved += projected;
            let admitted_us = req.arrived.elapsed().as_micros() as u64;
            req.stream.send(StreamEvent::Prefilling { id: req.id, ts_us: admitted_us });
            // Materialize the adoption the projection was charged for
            // (nothing registers between the peek and here, and eviction
            // spared the candidate's own match, so the peeked length is
            // still valid — adopt_at re-verifies the tokens without
            // re-scanning the whole prompt chain).
            let cache = match prefix_index
                .as_ref()
                .and_then(|ix| ix.adopt_at(&req.prompt, adopted_rows))
            {
                Some((rows, cache)) => {
                    debug_assert_eq!(rows, adopted_rows, "peeked match must survive admission");
                    metrics.on_prefix_hit(rows, cache.pages());
                    cache
                }
                None => lm.new_cache(),
            };
            active.push(Active {
                cache,
                prompt_pos: adopted_rows,
                adopted_rows,
                generated: Vec::new(),
                capped: false,
                failed: false,
                admitted_us,
                first_token_us: None,
                last_token_us: admitted_us,
                rng: crate::util::prng::Pcg64::seed_from_u64(req.id ^ 0x5EED),
                req,
            });
        }
        // Put KV-deferred requests back at the front, preserving order.
        for req in deferred.into_iter().rev() {
            waiting.push_front(req);
        }
        metrics.on_active(active.len());

        // Round-local stream accounting: tokens delivered onto streams and
        // the inter-token gaps observed, folded into metrics once per round.
        let mut streamed: u64 = 0;
        let mut itl_gaps: Vec<u64> = Vec::new();

        // (3a) advance prefills: at most one chunk per request per round, so
        // a long prompt shares the round with concurrent decodes instead of
        // monopolizing it (chunked prefill over the offset-causal mask).
        // Each step is caught per request: a panic poisons only its own
        // request (the step mutates nothing but that request's cache).
        for a in active.iter_mut() {
            if !a.prefilling() || a.failed {
                continue;
            }
            // Mid-prefill adoption upgrade: a donor ahead of us (possibly in
            // this very round — requests are advanced in admission order)
            // may have registered a longer prefix of this prompt since our
            // last chunk. Our own computed rows [0, prompt_pos) are
            // byte-identical to the snapshot's (same tokens, same chunk
            // boundaries), so jumping the cache forward to the shared run
            // changes nothing observable — it just stops re-computing what
            // a sharer already paid for. This is how N simultaneous
            // identical prompts converge onto one set of prefix pages.
            let upgrade =
                prefix_index.as_ref().and_then(|ix| ix.adopt(&a.req.prompt, a.prompt_pos));
            if let Some((rows, cache)) = upgrade {
                // Incremental accounting on the same basis as the token
                // count: only pages for rows this request never computed
                // (beyond prompt_pos) count as "adopted instead of
                // allocated" — pages it built itself and is now swapping
                // for references were allocated either way.
                let new_pages = cache.pages() - KvCache::pages_for_tokens(a.prompt_pos, &cfg);
                metrics.on_prefix_hit(rows - a.prompt_pos, new_pages);
                a.cache = cache; // own pages drop back to the pool
                a.prompt_pos = rows;
                a.adopted_rows = rows;
            }
            let chunk = if opts.policy.prefill_chunk == 0 {
                a.req.prompt.len()
            } else {
                opts.policy.prefill_chunk.max(1)
            };
            let end = (a.prompt_pos + chunk).min(a.req.prompt.len());
            let step = std::panic::catch_unwind(AssertUnwindSafe(|| {
                fault::on_prefill_step(a.req.id);
                lm.forward(&a.req.prompt[a.prompt_pos..end], Some(&mut a.cache))
            }));
            let logits = match step {
                Ok(logits) => logits,
                Err(payload) => {
                    if payload.downcast_ref::<fault::Injected>().is_none() {
                        crate::log_error!("prefill step panicked; request {} poisoned", a.req.id);
                    }
                    a.failed = true;
                    continue;
                }
            };
            metrics.on_prefill_tokens(end - a.prompt_pos);
            a.prompt_pos = end;
            // Register a snapshot at every aligned chunk boundary: page
            // references plus the running scales that cover exactly the
            // rows prefilled so far (the byte-identity precondition for
            // later adopters). Only fully-computed boundaries register, so
            // a later panic can never strand a partial snapshot — donated
            // prefix pages stay adoptable after their donor dies.
            if let Some(ix) = prefix_index.as_mut() {
                if ix.aligned(a.prompt_pos) {
                    ix.register(&a.req.prompt[..a.prompt_pos], &a.cache);
                }
            }
            if !a.prefilling() {
                // Prefill complete: sample the first token and stream it —
                // its stamp is the request's TTFT.
                let first = sample_row(
                    logits.row(logits.rows() - 1),
                    a.req.temperature,
                    a.req.top_k,
                    &mut a.rng,
                );
                a.generated.push(first);
                let ts_us = a.req.arrived.elapsed().as_micros() as u64;
                a.first_token_us = Some(ts_us);
                a.last_token_us = ts_us;
                let ev = StreamEvent::Token { id: a.req.id, index: 0, token: first, ts_us };
                if a.req.stream.send(ev) {
                    streamed += 1;
                }
            }
        }
        // (3b) one *batched* decode step over every decoding request
        // (continuous batching): B sequences advance through a single
        // `decode_step_batch` call — stacked B×d_model projections, grouped
        // attention GEMMs over the B resident KV states — instead of B
        // separate 1-row GEMM pairs. Bit-identical per sequence to the old
        // sequential loop.
        for a in active.iter_mut() {
            // A decode at cache.len == max_seq − 1 is still valid (it embeds
            // the last position and fills the final KV slot); cap only once
            // the context is actually full.
            if !a.prefilling()
                && !a.failed
                && a.generated.len() < a.req.gen_len
                && a.cache.len >= cfg.max_seq
            {
                // Context exhausted before gen_len: truncate — never pad
                // with fabricated tokens — and retire as Length below.
                a.capped = true;
            }
        }
        let mut decoding: Vec<&mut Active> = active
            .iter_mut()
            .filter(|a| {
                !a.prefilling() && !a.capped && !a.failed && a.generated.len() < a.req.gen_len
            })
            .collect();
        if !decoding.is_empty() {
            let tokens: Vec<u16> =
                decoding.iter().map(|a| *a.generated.last().unwrap()).collect();
            let ids: Vec<u64> = decoding.iter().map(|a| a.req.id).collect();
            // The batch is caught as a whole. Injected decode faults fire
            // at step entry — before any cache mutation — and name their
            // victim, so only the victim is poisoned and the untouched rest
            // of the batch decodes next round. An unattributed panic leaves
            // the batch's caches indeterminate: everyone in it fails rather
            // than decode from poisoned KV.
            let step = std::panic::catch_unwind(AssertUnwindSafe(|| {
                for &id in &ids {
                    fault::on_decode_step(id);
                }
                let mut caches: Vec<&mut KvCache> =
                    decoding.iter_mut().map(|a| &mut a.cache).collect();
                lm.decode_step_batch(&tokens, &mut caches)
            }));
            match step {
                Ok(logits) => {
                    for (i, a) in decoding.iter_mut().enumerate() {
                        let next = sample_row(
                            logits.row(i),
                            a.req.temperature,
                            a.req.top_k,
                            &mut a.rng,
                        );
                        a.generated.push(next);
                        // Stream the token as this round's batched decode
                        // lands — clients observe decode cadence.
                        let ts_us = a.req.arrived.elapsed().as_micros() as u64;
                        itl_gaps.push(ts_us.saturating_sub(a.last_token_us));
                        a.last_token_us = ts_us;
                        let ev = StreamEvent::Token {
                            id: a.req.id,
                            index: (a.generated.len() - 1) as u32,
                            token: next,
                            ts_us,
                        };
                        if a.req.stream.send(ev) {
                            streamed += 1;
                        }
                    }
                }
                Err(payload) => {
                    let victim =
                        payload.downcast_ref::<fault::Injected>().and_then(|inj| inj.victim);
                    match victim {
                        Some(id) => {
                            for a in decoding.iter_mut() {
                                if a.req.id == id {
                                    a.failed = true;
                                }
                            }
                        }
                        None => {
                            if payload.downcast_ref::<fault::Injected>().is_none() {
                                crate::log_error!(
                                    "batched decode step panicked; {} sequence(s) poisoned",
                                    decoding.len()
                                );
                            }
                            for a in decoding.iter_mut() {
                                a.failed = true;
                            }
                        }
                    }
                }
            }
        }
        metrics.on_stream_round(streamed, &itl_gaps);
        // Sample KV usage at the round's high-water mark: after prefill
        // chunks AND the decode step grew the caches, before retirement
        // frees them (sampling pre-decode missed every sequence's final,
        // largest state).
        metrics.on_kv_bytes(active.iter().map(|a| a.cache.bytes()).sum());
        metrics.on_kv_pages(
            active.iter().map(|a| a.cache.pages()).sum(),
            active.iter().map(|a| a.cache.rows_stored()).sum(),
            active.iter().map(|a| a.cache.capacity_rows()).sum(),
        );

        // (4) retire finished (gen_len reached, cut off by the context, or
        // poisoned by a caught panic).
        let mut i = 0;
        while i < active.len() {
            let done = active[i].failed
                || active[i].capped
                || active[i].generated.len() >= active[i].req.gen_len;
            if done {
                let a = active.swap_remove(i);
                let finish = if a.failed {
                    FinishReason::Error
                } else if a.capped {
                    FinishReason::Length
                } else {
                    FinishReason::Done
                };
                // `a` (and its KvCache) drops inside retire_active: every
                // page the sequence held returns to the process-wide pool
                // this round, so the freed budget — and the pages
                // themselves — are available to the next admission.
                retire_active(&metrics, a, finish);
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn small_weights() -> Weights {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 64, mlp_mult: 2 };
        Weights::random(cfg, 11)
    }

    /// A handle whose scheduler is already gone (receiver dropped), for the
    /// submit/shutdown failure paths no live engine can deterministically
    /// produce.
    fn dead_handle(join: Option<std::thread::JoinHandle<()>>) -> EngineHandle {
        let (tx, _) = mpsc::channel();
        EngineHandle {
            tx,
            metrics: Metrics::new(),
            queue_len: Arc::new(AtomicU64::new(0)),
            max_queue: 4,
            next_id: AtomicU64::new(1),
            shutdown: Arc::new(AtomicBool::new(false)),
            join,
            max_context: 64,
        }
    }

    #[test]
    fn serves_a_request_end_to_end() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        let rx = h.submit(vec![1, 2, 3], 5, SubmitOptions::sampling(0.8, 8)).unwrap();
        let resp = rx.recv_all_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.total_us > 0);
        assert!(resp.ttft_us() <= resp.total_us);
        assert_eq!(
            resp.queue_us + resp.prefill_us + resp.decode_us,
            resp.total_us,
            "derived timings partition the end-to-end latency exactly"
        );
        let snap = h.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.finished_done, 1);
    }

    #[test]
    fn streams_tokens_in_order_and_final_agrees_with_event_stamps() {
        // The satellite invariant: `Final` is the single source of truth,
        // derived from the same stamps the stream events carry — drain the
        // whole stream and check they agree exactly.
        let h = Engine::start(small_weights(), EngineOptions::default());
        let mut rx = h.submit(vec![1, 2, 3, 4], 5, SubmitOptions::default()).unwrap();
        let mut events = Vec::new();
        loop {
            let ev = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            let done = matches!(ev, StreamEvent::Final(_));
            events.push(ev);
            if done {
                break;
            }
        }
        assert!(matches!(events[0], StreamEvent::Queued { .. }), "stream opens with Queued");
        let prefill_ts = match events[1] {
            StreamEvent::Prefilling { ts_us, .. } => ts_us,
            ref ev => panic!("expected Prefilling second, got {ev:?}"),
        };
        let tokens: Vec<(u32, u16, u64)> = events
            .iter()
            .filter_map(|ev| match ev {
                StreamEvent::Token { index, token, ts_us, .. } => Some((*index, *token, *ts_us)),
                _ => None,
            })
            .collect();
        let resp = match events.last().unwrap() {
            StreamEvent::Final(r) => r.clone(),
            ev => panic!("expected Final last, got {ev:?}"),
        };
        assert_eq!(tokens.len(), 5, "one Token event per generated token");
        for (i, &(index, token, ts)) in tokens.iter().enumerate() {
            assert_eq!(index as usize, i, "strictly sequential decode order");
            assert_eq!(token, resp.tokens[i], "streamed tokens match the Final");
            assert!(ts <= resp.total_us);
        }
        assert!(tokens.windows(2).all(|w| w[0].2 <= w[1].2), "non-decreasing stamps");
        assert_eq!(resp.queue_us, prefill_ts, "queue_us IS the Prefilling stamp");
        assert_eq!(resp.ttft_us(), tokens[0].2, "TTFT IS the first Token stamp");
        assert_eq!(resp.queue_us + resp.prefill_us + resp.decode_us, resp.total_us);
        assert!(
            matches!(rx.try_recv(), Err(std::sync::mpsc::TryRecvError::Disconnected)),
            "nothing follows Final"
        );
        h.shutdown();
    }

    #[test]
    fn drop_after_final_is_not_a_cancel() {
        // Satellite regression: the drop-cancel guard must not fire once
        // the terminal was received — no Cancelled double-terminal, no
        // spurious finished_cancelled increment.
        let h = Engine::start(small_weights(), EngineOptions::default());
        let mut rx = h.submit(vec![1, 2, 3], 3, SubmitOptions::default()).unwrap();
        let resp = rx.recv_final_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(resp.finish, FinishReason::Done);
        drop(rx);
        let snap = h.shutdown();
        assert_eq!(snap.completed, 1, "exactly one terminal");
        assert_eq!(snap.finished_done, 1);
        assert_eq!(snap.finished_cancelled, 0, "drop after Final must not count as a cancel");
    }

    #[test]
    fn bounded_stream_buffer_cancels_a_client_that_stopped_reading() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        // Buffer of 2 with an un-read stream: Queued + Prefilling + the
        // first Token overflow it, so the sweep cancels the request long
        // before its 30 tokens finish.
        let rx = h.submit(vec![1, 2, 3], 30, SubmitOptions::default().with_stream_buffer(2));
        let resp = rx.unwrap().recv_all_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(resp.tokens.len() < 30, "cancelled well before completion");
        let snap = h.shutdown();
        assert_eq!(snap.stream_overflow_cancels, 1);
        assert_eq!(snap.finished_cancelled, 1);
    }

    #[test]
    fn serves_concurrent_requests() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                h.submit(vec![1, 2, (i % 30) as u16 + 1], 4, SubmitOptions::sampling(0.5, 4))
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert_eq!(resp.tokens.len(), 4);
        }
        let snap = h.shutdown();
        assert_eq!(snap.completed, 6);
        assert!(snap.peak_active >= 2, "batching should overlap requests");
        assert!(snap.tokens_streamed > 0, "token events were delivered");
    }

    #[test]
    fn rejects_bad_requests() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        let opts = SubmitOptions::default();
        assert_eq!(h.submit(vec![], 4, opts).unwrap_err(), SubmitError::BadRequest);
        assert_eq!(
            h.submit(vec![1; 64], 1, opts).unwrap_err(),
            SubmitError::BadRequest,
            "prompt leaves no room to generate"
        );
        let snap = h.shutdown();
        assert_eq!(snap.rejected, 2);
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn context_overrun_truncates_with_length_finish() {
        // max_seq 64: a 60-token prompt with gen_len 10 has room for exactly
        // 5 generated tokens (one sampled off the prefill + decodes through
        // the last context slot). Regression: the engine used to pad the
        // missing tail by duplicating the last token and report all 10 as
        // generated.
        let h = Engine::start(small_weights(), EngineOptions::default());
        let rx = h.submit(vec![1; 60], 10, SubmitOptions::default()).unwrap();
        let resp = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.finish, FinishReason::Length);
        assert_eq!(resp.tokens.len(), 5, "truncated, not padded: {:?}", resp.tokens);
        // An in-budget request on the same engine finishes Done.
        let rx = h.submit(vec![1, 2, 3], 4, SubmitOptions::default()).unwrap();
        let resp = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.finish, FinishReason::Done);
        assert_eq!(resp.tokens.len(), 4);
        let snap = h.shutdown();
        // 4 real decode steps for the capped request + 3 for the Done one —
        // fabricated tokens must not inflate the decode metric.
        assert_eq!(snap.decode_tokens, 7);
        assert_eq!(snap.finished_length, 1);
        assert_eq!(snap.finished_done, 1);
    }

    #[test]
    fn backpressure_rejects_on_full_queue() {
        let opts = EngineOptions { max_queue: 2, ..Default::default() };
        let h = Engine::start(small_weights(), opts);
        // Flood faster than the scheduler can drain; expect ≥1 rejection.
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..40 {
            match h.submit(vec![1, 2, (i % 30) as u16 + 1], 2, SubmitOptions::default()) {
                Ok(rx) => receivers.push(rx),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(rejected > 0, "queue bound must trigger backpressure");
        for rx in receivers {
            let _ = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
        }
        h.shutdown();
    }

    #[test]
    fn kv_budget_defers_but_serves_eventually() {
        // A page budget that fits exactly one sequence's projection:
        // requests must serialize through the KV bound, not be rejected or
        // deadlocked. (Projection: 3 prompt + 4 gen = 7 tokens across 1
        // layer × 2 heads × K/V, each side ⌈7/page_rows⌉ pages.)
        let w = small_weights();
        let one_seq = KvCache::pages_for_tokens(7, &w.cfg);
        let opts = EngineOptions {
            policy: BatchPolicy { max_kv_pages: one_seq, ..Default::default() },
            ..Default::default()
        };
        let h = Engine::start(w, opts);
        let rxs: Vec<_> = (0..4)
            .map(|i| h.submit(vec![1, 2, (i + 1) as u16], 4, SubmitOptions::default()).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert_eq!(resp.tokens.len(), 4);
        }
        let snap = h.shutdown();
        assert_eq!(snap.completed, 4);
        assert!(snap.peak_kv_bytes > 0, "kv byte accounting must be recorded");
        assert!(snap.peak_kv_pages > 0, "kv page accounting must be recorded");
        assert!(
            snap.peak_kv_pages <= one_seq,
            "page budget must keep one sequence resident at a time: {} > {one_seq}",
            snap.peak_kv_pages
        );
        assert!(
            snap.kv_tail_utilization > 0.0 && snap.kv_tail_utilization <= 1.0,
            "utilization sample out of range: {}",
            snap.kv_tail_utilization
        );
    }

    #[test]
    fn chunked_prefill_preserves_greedy_output() {
        let w = small_weights();
        let prompt: Vec<u16> = (1..=10).collect();
        let run = |chunk: usize| {
            let opts = EngineOptions {
                attention: PipelineKind::Fp32,
                policy: BatchPolicy { prefill_chunk: chunk, ..Default::default() },
                ..Default::default()
            };
            let h = Engine::start(w.clone(), opts);
            let rx = h.submit(prompt.clone(), 5, SubmitOptions::default()).unwrap();
            let resp = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
            h.shutdown();
            resp.tokens
        };
        // FP32 row-wise math is independent of the chunking, so greedy
        // decoding must be bit-stable across chunk sizes.
        assert_eq!(run(0), run(3));
    }

    #[test]
    fn metrics_snapshot_coherent() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        let rx = h.submit(vec![5, 6, 7, 8], 3, SubmitOptions::default()).unwrap();
        let _ = rx.recv_all_timeout(std::time::Duration::from_secs(30)).unwrap();
        let snap = h.shutdown();
        assert_eq!(snap.prefill_tokens, 4);
        assert_eq!(snap.decode_tokens, 2);
        assert_eq!(snap.tokens_streamed, 3, "every generated token was streamed");
        assert!(snap.throughput_tok_s > 0.0);
        assert!(snap.render().contains("tok/s"));
    }

    #[test]
    fn cancel_token_retires_request_as_cancelled() {
        // A long chunked prefill (30 rounds for the 60-token prompt) keeps
        // the request in flight while the cancel lands; the engine must
        // retire it at a round boundary, not run it to completion.
        let opts = EngineOptions {
            policy: BatchPolicy { prefill_chunk: 2, ..Default::default() },
            ..Default::default()
        };
        let h = Engine::start(small_weights(), opts);
        let rx = h.submit(vec![1; 60], 2, SubmitOptions::default()).unwrap();
        rx.cancel();
        let resp = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(resp.tokens.len() < 2, "cancelled before completion");
        // The engine keeps serving after the cancellation.
        let rx = h.submit(vec![1, 2, 3], 3, SubmitOptions::default()).unwrap();
        let resp = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.finish, FinishReason::Done);
        let snap = h.shutdown();
        assert_eq!(snap.finished_cancelled, 1);
        assert_eq!(snap.finished_done, 1);
        assert_eq!(snap.completed, 2);
    }

    #[test]
    fn expired_deadline_yields_deadline_exceeded() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        // A zero deadline is already exceeded at the first lifecycle sweep,
        // before the request can admit — deterministic terminal reason.
        let expired = SubmitOptions::default().with_deadline(Duration::ZERO);
        let rx = h.submit(vec![1, 2, 3], 4, expired).unwrap();
        let resp = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.finish, FinishReason::DeadlineExceeded);
        assert!(resp.tokens.is_empty(), "never ran: no partial output");
        // A generous deadline does not trip.
        let generous = SubmitOptions::default().with_deadline(Duration::from_secs(3600));
        let rx = h.submit(vec![1, 2, 3], 4, generous).unwrap();
        let resp = rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.finish, FinishReason::Done);
        let snap = h.shutdown();
        assert_eq!(snap.finished_deadline, 1);
        assert_eq!(snap.finished_done, 1);
    }

    #[test]
    fn submit_rolls_back_queue_len_when_scheduler_is_gone() {
        // Regression: a send failure used to leave the queue-length charge
        // behind, so enough raced submits against a dead scheduler would
        // wedge the handle on a phantom-full queue.
        let h = dead_handle(None);
        for _ in 0..10 {
            let err = h.submit(vec![1, 2], 2, SubmitOptions::default()).unwrap_err();
            assert_eq!(err, SubmitError::ShuttingDown);
        }
        assert_eq!(h.queue_len.load(Ordering::SeqCst), 0, "charge rolled back");
        let snap = h.metrics();
        assert_eq!(snap.submitted, 0, "a failed submit is not a submit");
        assert_eq!(snap.rejected, 0, "shutdown is not a client error");
    }

    /// A thread that dies with a typed [`fault::Injected`] payload — stands
    /// in for a panicked scheduler, and lets the asserts identify the exact
    /// panic they re-raised/observed.
    fn panicking_thread() -> std::thread::JoinHandle<()> {
        std::thread::Builder::new()
            .spawn(|| {
                std::panic::panic_any(fault::Injected { site: fault::Site::Round, victim: None })
            })
            .unwrap()
    }

    #[test]
    fn shutdown_propagates_scheduler_panic() {
        // `let _ = j.join()` used to swallow this: a crashed engine looked
        // like a clean exit.
        let h = dead_handle(Some(panicking_thread()));
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| h.shutdown()));
        let payload = outcome.expect_err("shutdown must re-raise the scheduler panic");
        assert!(payload.downcast_ref::<fault::Injected>().is_some());
    }

    #[test]
    fn drop_counts_scheduler_panic_without_panicking() {
        let before = scheduler_panics();
        drop(dead_handle(Some(panicking_thread())));
        // `>=`: other tests may exercise this path concurrently.
        assert!(scheduler_panics() >= before + 1, "drop must flag the crashed scheduler");
    }
}
