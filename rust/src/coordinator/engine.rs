//! The serving engine: a scheduler thread running continuous batching over
//! the tiny LM, with bounded-queue admission (backpressure) and metrics.
//!
//! Scheduling loop (one "round"):
//!   1. Drain the submit channel into the wait queue; reject on overflow.
//!   2. Admit new requests per [`BatchPolicy`] (prefill phase; records TTFT).
//!   3. One decode step for every active request (continuous batching).
//!   4. Retire finished requests, replying on their channels.
//!
//! Single scheduler thread: on the target class of devices (and this host)
//! compute is the bottleneck, not I/O, so the engine keeps the model on one
//! thread and exposes concurrency through batching — the same topology the
//! paper's measurement setup uses (8 worker threads inside the kernels, one
//! request loop).

use crate::attention::PipelineKind;
use crate::coordinator::batcher::{select_admissions, BatchPolicy};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::request::{Request, Response, SubmitError};
use crate::model::lm::{sample_row, KvCache, TinyLm};
use crate::model::weights::Weights;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub attention: PipelineKind,
    pub policy: BatchPolicy,
    /// Bounded wait-queue depth; submits beyond this are rejected.
    pub max_queue: usize,
    /// GEMM threads inside the model.
    pub threads: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            attention: PipelineKind::IntAttention,
            policy: BatchPolicy::default(),
            max_queue: 64,
            threads: 1,
        }
    }
}

/// A request in flight.
struct Active {
    req: Request,
    cache: KvCache,
    generated: Vec<u16>,
    queue_us: u64,
    prefill_us: u64,
    decode_started: Instant,
    rng: crate::util::prng::Pcg64,
}

/// Public handle: submit requests, read metrics, shut down.
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
    metrics: Metrics,
    queue_len: Arc<AtomicU64>,
    max_queue: usize,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    max_context: usize,
}

impl EngineHandle {
    /// Submit a generation request; returns the response channel.
    pub fn submit(
        &self,
        prompt: Vec<u16>,
        gen_len: usize,
        temperature: f32,
        top_k: usize,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        if self.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        if prompt.is_empty() || prompt.len() + gen_len > self.max_context {
            self.metrics.on_reject();
            return Err(SubmitError::BadRequest);
        }
        // Admission control: bounded queue.
        if self.queue_len.load(Ordering::SeqCst) as usize >= self.max_queue {
            self.metrics.on_reject();
            return Err(SubmitError::QueueFull);
        }
        self.queue_len.fetch_add(1, Ordering::SeqCst);
        self.metrics.on_submit();
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::SeqCst),
            prompt,
            gen_len: gen_len.max(1),
            temperature,
            top_k: top_k.max(1),
            arrived: Instant::now(),
            reply: tx,
        };
        self.tx.send(req).map_err(|_| SubmitError::ShuttingDown)?;
        Ok(rx)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Signal shutdown and join the scheduler (drains in-flight work).
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Engine constructor.
pub struct Engine;

impl Engine {
    /// Start the scheduler thread and return a handle.
    pub fn start(weights: Weights, opts: EngineOptions) -> EngineHandle {
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Metrics::new();
        let queue_len = Arc::new(AtomicU64::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));
        let max_context = weights.cfg.max_seq;

        let m = metrics.clone();
        let ql = Arc::clone(&queue_len);
        let sd = Arc::clone(&shutdown);
        let join = std::thread::Builder::new()
            .name("intattn-scheduler".into())
            .spawn(move || scheduler_loop(weights, opts, rx, m, ql, sd))
            .expect("spawn scheduler");

        EngineHandle {
            tx,
            metrics,
            queue_len,
            max_queue: 1_000_000, // real bound enforced below via opts clone
            next_id: AtomicU64::new(1),
            shutdown,
            join: Some(join),
            max_context,
        }
        // NB: max_queue is overwritten by `start_with_bound` callers; see
        // `Engine::start_bounded`.
    }

    /// Start with the options' queue bound enforced on submit.
    pub fn start_bounded(weights: Weights, opts: EngineOptions) -> EngineHandle {
        let max_queue = opts.max_queue;
        let mut h = Self::start(weights, opts);
        h.max_queue = max_queue;
        h
    }
}

fn scheduler_loop(
    weights: Weights,
    opts: EngineOptions,
    rx: mpsc::Receiver<Request>,
    metrics: Metrics,
    queue_len: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
) {
    let mut lm = TinyLm::new(weights, opts.attention);
    lm.threads = opts.threads;
    let cfg = *lm.config();
    let mut waiting: VecDeque<Request> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();

    loop {
        // (1) drain submissions.
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    queue_len.fetch_sub(1, Ordering::SeqCst);
                    waiting.push_back(req);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    if active.is_empty() && waiting.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        if shutdown.load(Ordering::SeqCst) && active.is_empty() && waiting.is_empty() {
            return;
        }
        if waiting.is_empty() && active.is_empty() {
            // Idle: block briefly for the next request to avoid spinning.
            match rx.recv_timeout(std::time::Duration::from_millis(5)) {
                Ok(req) => {
                    queue_len.fetch_sub(1, Ordering::SeqCst);
                    waiting.push_back(req);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    continue;
                }
            }
        }

        // (2) admissions → prefill.
        let admitted = select_admissions(&mut waiting, active.len(), &opts.policy);
        for req in admitted {
            let queue_us = req.arrived.elapsed().as_micros() as u64;
            let t0 = Instant::now();
            let mut cache = KvCache::new(cfg.n_layers, cfg.d_model);
            let logits = lm.forward(&req.prompt, Some(&mut cache));
            metrics.on_prefill_tokens(req.prompt.len());
            let mut rng = crate::util::prng::Pcg64::seed_from_u64(req.id ^ 0x5EED);
            let first = sample_row(
                logits.row(logits.rows() - 1),
                req.temperature,
                req.top_k,
                &mut rng,
            );
            let prefill_us = t0.elapsed().as_micros() as u64;
            active.push(Active {
                req,
                cache,
                generated: vec![first],
                queue_us,
                prefill_us,
                decode_started: Instant::now(),
                rng,
            });
        }
        metrics.on_active(active.len());

        // (3) one decode step per active request (continuous batching).
        for a in active.iter_mut() {
            if a.generated.len() >= a.req.gen_len {
                continue;
            }
            let last = *a.generated.last().unwrap();
            if a.cache.len + 1 >= cfg.max_seq {
                // Context exhausted: stop early.
                a.generated.resize(a.req.gen_len, last);
                continue;
            }
            let logits = lm.decode_step(last, &mut a.cache);
            let next = sample_row(logits.row(0), a.req.temperature, a.req.top_k, &mut a.rng);
            a.generated.push(next);
        }

        // (4) retire finished.
        let mut i = 0;
        while i < active.len() {
            if active[i].generated.len() >= active[i].req.gen_len {
                let a = active.swap_remove(i);
                let decode_us = a.decode_started.elapsed().as_micros() as u64;
                let total_us = a.req.arrived.elapsed().as_micros() as u64;
                let resp = Response {
                    id: a.req.id,
                    tokens: a.generated,
                    queue_us: a.queue_us,
                    prefill_us: a.prefill_us,
                    decode_us,
                    total_us,
                };
                metrics.on_complete(&resp);
                let _ = a.req.reply.send(resp); // receiver may have gone away
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn small_weights() -> Weights {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 64, mlp_mult: 2 };
        Weights::random(cfg, 11)
    }

    #[test]
    fn serves_a_request_end_to_end() {
        let h = Engine::start_bounded(small_weights(), EngineOptions::default());
        let rx = h.submit(vec![1, 2, 3], 5, 0.8, 8).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.total_us > 0);
        assert!(resp.ttft_us() <= resp.total_us + 1000);
        let snap = h.shutdown();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn serves_concurrent_requests() {
        let h = Engine::start_bounded(small_weights(), EngineOptions::default());
        let rxs: Vec<_> = (0..6)
            .map(|i| h.submit(vec![1, 2, (i % 30) as u16 + 1], 4, 0.5, 4).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert_eq!(resp.tokens.len(), 4);
        }
        let snap = h.shutdown();
        assert_eq!(snap.completed, 6);
        assert!(snap.peak_active >= 2, "batching should overlap requests");
    }

    #[test]
    fn rejects_bad_requests() {
        let h = Engine::start_bounded(small_weights(), EngineOptions::default());
        assert_eq!(h.submit(vec![], 4, 0.0, 1).unwrap_err(), SubmitError::BadRequest);
        assert_eq!(
            h.submit(vec![1; 60], 10, 0.0, 1).unwrap_err(),
            SubmitError::BadRequest,
            "prompt+gen beyond max context"
        );
        let snap = h.shutdown();
        assert_eq!(snap.rejected, 2);
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn backpressure_rejects_on_full_queue() {
        let opts = EngineOptions { max_queue: 2, ..Default::default() };
        let h = Engine::start_bounded(small_weights(), opts);
        // Flood faster than the scheduler can drain; expect ≥1 rejection.
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..40 {
            match h.submit(vec![1, 2, (i % 30) as u16 + 1], 2, 0.0, 1) {
                Ok(rx) => receivers.push(rx),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(rejected > 0, "queue bound must trigger backpressure");
        for rx in receivers {
            let _ = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        }
        h.shutdown();
    }

    #[test]
    fn metrics_snapshot_coherent() {
        let h = Engine::start_bounded(small_weights(), EngineOptions::default());
        let rx = h.submit(vec![5, 6, 7, 8], 3, 0.0, 1).unwrap();
        let _ = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let snap = h.shutdown();
        assert_eq!(snap.prefill_tokens, 4);
        assert_eq!(snap.decode_tokens, 2);
        assert!(snap.throughput_tok_s > 0.0);
        assert!(snap.render().contains("tok/s"));
    }
}
