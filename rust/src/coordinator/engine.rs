//! The serving engine: a scheduler thread running continuous batching over
//! the tiny LM, with bounded-queue admission (backpressure) and metrics.
//!
//! Scheduling loop (one "round"):
//!   1. Drain the submit channel into the wait queue; reject on overflow.
//!   2. Admit new requests per [`BatchPolicy`] (prefill phase; records
//!      TTFT), under the **KV page budget**: each candidate charges its
//!      projected footprint — [`KvCache::pages_for_tokens`] over prompt +
//!      full generation — against [`BatchPolicy::max_kv_pages`], and a
//!      request that would overflow waits (pinned head-of-line, so smaller
//!      arrivals cannot leapfrog it forever). Pages are the natural unit
//!      because KV residency *is* paged: fixed-size pages from a
//!      process-wide recycling pool
//!      ([`crate::attention::state::PagedRows`]), so the page count equals
//!      allocated capacity exactly — the old byte budget estimated payload
//!      from `len` and could undercount peak RSS by the `Vec` growth slack.
//!      With **prefix sharing** on ([`BatchPolicy::prefix_share`]), an
//!      admission first consults the [`PrefixIndex`]: if the prompt's
//!      longest aligned prefix is registered, the request **adopts** the
//!      snapshot's pages by copy-on-write reference and starts its prefill
//!      at the adopted position — and its budget charge drops by the
//!      adopted pages, so a shared prefix is charged once, by whichever
//!      request first computed it.
//!   3. Advance prefills (one chunk per request per round), then **one
//!      batched decode step** over every decoding request: the per-layer
//!      Q/K/V projections of the B active sequences stack into single
//!      `B×d_model` GEMMs, and each head's B attention products run as one
//!      grouped integer-GEMM launch over the B resident KV **page lists**
//!      ([`TinyLm::decode_step_batch`]) — instead of B memory-bound 1-row
//!      GEMM pairs per round. Per sequence the results are bit-identical to
//!      the sequential loop; only the kernel shapes change. Appends fill
//!      each state's tail page in place, so a long-running sequence never
//!      re-copies its history the way contiguous `Vec` growth did.
//!   4. Retire finished requests, replying on their channels. Dropping a
//!      retired request's [`KvCache`] returns its pages to the pool **that
//!      same round**, which is what lets the next KV-deferred request in
//!      the queue admit (and reuse those very pages); pages the prefix
//!      index still references stay alive for future adopters and are
//!      released when their entry is evicted. A request the context cuts
//!      off early is truncated (never padded) and finishes with
//!      [`FinishReason::Length`].
//!
//! ## Copy-on-write prefix sharing (ownership rules)
//!
//! The scheduler owns one [`PrefixIndex`] (built only when
//! `policy.prefix_share && policy.prefill_chunk > 0`). Each prefill chunk
//! that ends exactly on an aligned boundary (`lcm(page_rows,
//! prefill_chunk)` tokens) **registers** a snapshot: the prompt run so far
//! plus a [`KvCache::share_prefix`] of the live cache — page references,
//! not copies, paired with the integer states' running scales *at that
//! boundary* (that pairing is what makes the snapshot adoptable
//! byte-identically; see `crate::coordinator::prefix`). A request may adopt
//! at admission or **mid-prefill** (a later round may register a longer
//! prefix of the same prompt — trailing same-prompt requests upgrade to it,
//! which is how N simultaneous identical prompts converge onto one page
//! set). After adoption nobody owns shared pages exclusively: the donor,
//! the index entry and every adopter each hold references, every one of
//! them forks a shared page before mutating it (tail-page append at an
//! unaligned boundary, INT8 re-scale when a suffix row grows the running
//! abs-max), and the last holder returns the page to the pool. Sharing is
//! therefore *invisible*: outputs are byte-identical to unshared execution
//! (`decode_equivalence` + `serving_e2e` assert this), only the
//! `prefix_hits` / `shared_kv_pages` / `kv_cow_forks` metrics and the page
//! traffic change.
//!
//! Single scheduler thread: on the target class of devices (and this host)
//! compute is the bottleneck, not I/O, so the engine keeps the model on one
//! thread and exposes concurrency through batching — the same topology the
//! paper's measurement setup uses (worker threads inside the kernels, one
//! request loop). The kernel workers are the process-wide persistent
//! [`ParallelPool`](crate::util::threadpool::ParallelPool) (sized once from
//! `INTATTN_THREADS`, default: available parallelism) — the engine no
//! longer threads a `threads` knob through the model; every decode-round
//! launch dispatches onto already-parked workers in ~µs instead of
//! spawning OS threads. The batched decode is what gives those workers
//! useful work during decode: a single sequence's 1-row GEMM cannot be
//! split across workers, a batch of sequences can.

use crate::attention::{kv_page_rows, PipelineKind};
use crate::coordinator::batcher::{select_admissions, BatchPolicy};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::prefix::{PrefixIndex, PREFIX_INDEX_CAP};
use crate::coordinator::request::{FinishReason, Request, Response, SubmitError};
use crate::model::lm::{sample_row, KvCache, TinyLm};
use crate::model::weights::Weights;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub attention: PipelineKind,
    pub policy: BatchPolicy,
    /// Bounded wait-queue depth; submits beyond this are rejected.
    pub max_queue: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            attention: PipelineKind::IntAttention,
            policy: BatchPolicy::default(),
            max_queue: 64,
        }
    }
}

/// A request in flight. Admission starts it in the prefill phase
/// (`prompt_pos < prompt.len()`); once the last prompt chunk is absorbed the
/// first token is sampled and it moves to the decode phase.
struct Active {
    req: Request,
    cache: KvCache,
    /// Prompt tokens already prefilled into the cache.
    prompt_pos: usize,
    /// Prompt tokens adopted from the prefix index (copy-on-write page
    /// references) rather than computed — the request's KV budget charge
    /// excludes their pages (a shared prefix is charged once, by the
    /// request that first computed it).
    adopted_rows: usize,
    generated: Vec<u16>,
    /// Set when the model's context fills before `gen_len` tokens: the
    /// request retires with what it actually generated
    /// ([`FinishReason::Length`]) — the tail is never padded.
    capped: bool,
    queue_us: u64,
    prefill_started: Instant,
    /// Set when the prefill phase completes (admission → first token).
    prefill_us: u64,
    decode_started: Instant,
    rng: crate::util::prng::Pcg64,
}

impl Active {
    fn prefilling(&self) -> bool {
        self.prompt_pos < self.req.prompt.len()
    }
}

/// Public handle: submit requests, read metrics, shut down.
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
    metrics: Metrics,
    queue_len: Arc<AtomicU64>,
    max_queue: usize,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    max_context: usize,
}

impl EngineHandle {
    /// Submit a generation request; returns the response channel.
    pub fn submit(
        &self,
        prompt: Vec<u16>,
        gen_len: usize,
        temperature: f32,
        top_k: usize,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        if self.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        // The prompt must fit and leave room for at least one generated
        // token. A `gen_len` that overruns the remaining context is NOT a
        // rejection: the request runs until the context fills and finishes
        // truncated with [`FinishReason::Length`].
        if prompt.is_empty() || prompt.len() >= self.max_context {
            self.metrics.on_reject();
            return Err(SubmitError::BadRequest);
        }
        // Admission control: bounded queue.
        if self.queue_len.load(Ordering::SeqCst) as usize >= self.max_queue {
            self.metrics.on_reject();
            return Err(SubmitError::QueueFull);
        }
        self.queue_len.fetch_add(1, Ordering::SeqCst);
        self.metrics.on_submit();
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::SeqCst),
            prompt,
            gen_len: gen_len.max(1),
            temperature,
            top_k: top_k.max(1),
            arrived: Instant::now(),
            reply: tx,
        };
        self.tx.send(req).map_err(|_| SubmitError::ShuttingDown)?;
        Ok(rx)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Signal shutdown and join the scheduler (drains in-flight work).
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Engine constructor.
pub struct Engine;

impl Engine {
    /// Start the scheduler thread and return a handle. The handle enforces
    /// `opts.max_queue` on every submit (bounded queue → backpressure).
    pub fn start(weights: Weights, opts: EngineOptions) -> EngineHandle {
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Metrics::new();
        let queue_len = Arc::new(AtomicU64::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));
        let max_context = weights.cfg.max_seq;
        let max_queue = opts.max_queue;

        let m = metrics.clone();
        let ql = Arc::clone(&queue_len);
        let sd = Arc::clone(&shutdown);
        let join = std::thread::Builder::new()
            .name("intattn-scheduler".into())
            .spawn(move || scheduler_loop(weights, opts, rx, m, ql, sd))
            .expect("spawn scheduler");

        EngineHandle {
            tx,
            metrics,
            queue_len,
            max_queue,
            next_id: AtomicU64::new(1),
            shutdown,
            join: Some(join),
            max_context,
        }
    }

    /// Deprecated alias of [`Engine::start`]. Historically `start` hardcoded
    /// an effectively unbounded queue (1 M entries) and only this entry
    /// point applied `opts.max_queue`; `start` now enforces the bound
    /// itself, so the two are identical.
    #[deprecated(note = "Engine::start now enforces opts.max_queue; call it directly")]
    pub fn start_bounded(weights: Weights, opts: EngineOptions) -> EngineHandle {
        Self::start(weights, opts)
    }
}

fn scheduler_loop(
    weights: Weights,
    opts: EngineOptions,
    rx: mpsc::Receiver<Request>,
    metrics: Metrics,
    queue_len: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
) {
    let mut lm = TinyLm::new(weights, opts.attention);
    let cfg = *lm.config();
    let mut waiting: VecDeque<Request> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    // Prefix-sharing index (None when disabled, or when prefill chunking is
    // off — without chunk boundaries a shared prefix could not be resumed
    // byte-identically; see `crate::coordinator::prefix`).
    let mut prefix_index: Option<PrefixIndex> = if opts.policy.prefix_share {
        PrefixIndex::new(kv_page_rows(), opts.policy.prefill_chunk, PREFIX_INDEX_CAP)
    } else {
        None
    };
    // Head-of-line guarantee for the KV budget: once a request is deferred
    // for KV memory, its id is pinned here and no other request may admit
    // ahead of it on any later round (shortest-first would otherwise let a
    // stream of small requests starve it forever).
    let mut kv_head: Option<u64> = None;

    loop {
        // (1) drain submissions.
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    queue_len.fetch_sub(1, Ordering::SeqCst);
                    waiting.push_back(req);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    if active.is_empty() && waiting.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        if shutdown.load(Ordering::SeqCst) && active.is_empty() && waiting.is_empty() {
            return;
        }
        if waiting.is_empty() && active.is_empty() {
            // Idle: block briefly for the next request to avoid spinning.
            match rx.recv_timeout(std::time::Duration::from_millis(5)) {
                Ok(req) => {
                    queue_len.fetch_sub(1, Ordering::SeqCst);
                    waiting.push_back(req);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    continue;
                }
            }
        }

        // (2) admissions, under the KV page budget. While a KV-deferred
        // request is pinned as kv_head, it is the *only* admission
        // candidate: selecting others and then vetoing them post-hoc would
        // livelock under sustained load (shortest-first may never re-select
        // the pinned id while shorter prompts keep arriving, and the veto
        // would bounce every selected request forever).
        let admitted: Vec<Request> = if let Some(id) = kv_head {
            if active.len() >= opts.policy.max_active {
                Vec::new()
            } else if let Some(pos) = waiting.iter().position(|r| r.id == id) {
                vec![waiting.remove(pos).expect("position valid")]
            } else {
                // Pinned id no longer queued (defensive; ids only leave the
                // queue via admission) — unpin and admit normally.
                kv_head = None;
                select_admissions(&mut waiting, active.len(), &opts.policy)
            }
        } else {
            select_admissions(&mut waiting, active.len(), &opts.policy)
        };
        // Reserve each active sequence's *projected* footprint in pages
        // (prompt + full generation, every layer/head/side rounded up to
        // whole pages — exactly what the paged states will allocate), not
        // just what its cache holds right now — otherwise concurrent
        // decodes grow past the budget after admission.
        // A projection can never exceed the model context: overrunning
        // requests are truncated at max_seq (FinishReason::Length).
        let projected_tokens =
            |req: &Request| (req.prompt.len() + req.gen_len).min(cfg.max_seq);
        let projected_pages = |req: &Request| KvCache::pages_for_tokens(projected_tokens(req), &cfg);
        // Shared prefix pages are charged once: every active request's
        // reservation excludes the pages it adopted by reference (adopted
        // lengths are page-aligned, so the subtraction removes exactly the
        // whole pages the adopter did not allocate).
        let mut kv_reserved: usize = active
            .iter()
            .map(|a| projected_pages(&a.req) - KvCache::pages_for_tokens(a.adopted_rows, &cfg))
            .sum();
        // Prefix-index pages count against the same physical budget: shared
        // prefix pages are charged **once** — to the index that pins them —
        // while every adopter's reservation excludes them. (Entry sums may
        // overlap chained snapshots of one prompt, which only overcharges —
        // the safe direction; the one uncovered window is pages adopted
        // from a since-evicted entry, which stay resident with their
        // adopters but charged to none until those adopters retire.)
        let pinned = |ix: &Option<PrefixIndex>| ix.as_ref().map_or(0, |i| i.pinned_pages());
        let mut deferred: Vec<Request> = Vec::new();
        for req in admitted {
            // Peek the longest adoptable prefix — a hash scan only; the CoW
            // cache is materialized after the request passes admission, so
            // deferred requests never pay for page-reference clones.
            let adopted_rows =
                prefix_index.as_ref().map_or(0, |ix| ix.match_len(&req.prompt, 0));
            let projected =
                projected_pages(&req) - KvCache::pages_for_tokens(adopted_rows, &cfg);
            // Under budget pressure, cached-but-idle prefixes yield first:
            // evict index entries (oldest first, sparing only the exact
            // entry this candidate is about to adopt — evicting it would
            // invalidate the peeked discount) before deferring a live
            // request. Skipped when eviction cannot change the outcome:
            // a candidate behind the kv_head pin defers regardless, and
            // with an empty active set the over-budget bypass admits
            // regardless — draining the cache would be pure waste.
            if opts.policy.max_kv_pages > 0
                && !active.is_empty()
                && !kv_head.is_some_and(|id| id != req.id)
            {
                while kv_reserved + pinned(&prefix_index) + projected > opts.policy.max_kv_pages
                    && prefix_index
                        .as_mut()
                        .is_some_and(|ix| ix.evict_oldest_excluding(&req.prompt[..adopted_rows]))
                {}
            }
            if kv_head.is_some_and(|id| id != req.id)
                || (opts.policy.max_kv_pages > 0
                    && kv_reserved + pinned(&prefix_index) + projected > opts.policy.max_kv_pages
                    && !active.is_empty())
            {
                // Over budget (or behind a previously KV-deferred request):
                // wait for running sequences to retire. The oldest deferred
                // request is pinned as `kv_head`, so later/smaller arrivals
                // cannot leapfrog it across rounds; a request too big for
                // the whole budget still runs once the active set drains.
                if kv_head.is_none() {
                    kv_head = Some(req.id);
                }
                deferred.push(req);
                continue;
            }
            if kv_head == Some(req.id) {
                kv_head = None;
            }
            kv_reserved += projected;
            // Materialize the adoption the projection was charged for
            // (nothing registers between the peek and here, and eviction
            // spared the candidate's own match, so the peeked length is
            // still valid — adopt_at re-verifies the tokens without
            // re-scanning the whole prompt chain).
            let cache = match prefix_index
                .as_ref()
                .and_then(|ix| ix.adopt_at(&req.prompt, adopted_rows))
            {
                Some((rows, cache)) => {
                    debug_assert_eq!(rows, adopted_rows, "peeked match must survive admission");
                    metrics.on_prefix_hit(rows, cache.pages());
                    cache
                }
                None => lm.new_cache(),
            };
            let queue_us = req.arrived.elapsed().as_micros() as u64;
            active.push(Active {
                cache,
                prompt_pos: adopted_rows,
                adopted_rows,
                generated: Vec::new(),
                capped: false,
                queue_us,
                prefill_started: Instant::now(),
                prefill_us: 0,
                decode_started: Instant::now(),
                rng: crate::util::prng::Pcg64::seed_from_u64(req.id ^ 0x5EED),
                req,
            });
        }
        // Put KV-deferred requests back at the front, preserving order.
        for req in deferred.into_iter().rev() {
            waiting.push_front(req);
        }
        metrics.on_active(active.len());

        // (3a) advance prefills: at most one chunk per request per round, so
        // a long prompt shares the round with concurrent decodes instead of
        // monopolizing it (chunked prefill over the offset-causal mask).
        for a in active.iter_mut() {
            if !a.prefilling() {
                continue;
            }
            // Mid-prefill adoption upgrade: a donor ahead of us (possibly in
            // this very round — requests are advanced in admission order)
            // may have registered a longer prefix of this prompt since our
            // last chunk. Our own computed rows [0, prompt_pos) are
            // byte-identical to the snapshot's (same tokens, same chunk
            // boundaries), so jumping the cache forward to the shared run
            // changes nothing observable — it just stops re-computing what
            // a sharer already paid for. This is how N simultaneous
            // identical prompts converge onto one set of prefix pages.
            let upgrade =
                prefix_index.as_ref().and_then(|ix| ix.adopt(&a.req.prompt, a.prompt_pos));
            if let Some((rows, cache)) = upgrade {
                // Incremental accounting on the same basis as the token
                // count: only pages for rows this request never computed
                // (beyond prompt_pos) count as "adopted instead of
                // allocated" — pages it built itself and is now swapping
                // for references were allocated either way.
                let new_pages = cache.pages() - KvCache::pages_for_tokens(a.prompt_pos, &cfg);
                metrics.on_prefix_hit(rows - a.prompt_pos, new_pages);
                a.cache = cache; // own pages drop back to the pool
                a.prompt_pos = rows;
                a.adopted_rows = rows;
            }
            let chunk = if opts.policy.prefill_chunk == 0 {
                a.req.prompt.len()
            } else {
                opts.policy.prefill_chunk.max(1)
            };
            let end = (a.prompt_pos + chunk).min(a.req.prompt.len());
            let logits = lm.forward(&a.req.prompt[a.prompt_pos..end], Some(&mut a.cache));
            metrics.on_prefill_tokens(end - a.prompt_pos);
            a.prompt_pos = end;
            // Register a snapshot at every aligned chunk boundary: page
            // references plus the running scales that cover exactly the
            // rows prefilled so far (the byte-identity precondition for
            // later adopters).
            if let Some(ix) = prefix_index.as_mut() {
                if ix.aligned(a.prompt_pos) {
                    ix.register(&a.req.prompt[..a.prompt_pos], &a.cache);
                }
            }
            if !a.prefilling() {
                // Prefill complete: sample the first token.
                let first = sample_row(
                    logits.row(logits.rows() - 1),
                    a.req.temperature,
                    a.req.top_k,
                    &mut a.rng,
                );
                a.generated.push(first);
                a.prefill_us = a.prefill_started.elapsed().as_micros() as u64;
                a.decode_started = Instant::now();
            }
        }
        // (3b) one *batched* decode step over every decoding request
        // (continuous batching): B sequences advance through a single
        // `decode_step_batch` call — stacked B×d_model projections, grouped
        // attention GEMMs over the B resident KV states — instead of B
        // separate 1-row GEMM pairs. Bit-identical per sequence to the old
        // sequential loop.
        for a in active.iter_mut() {
            // A decode at cache.len == max_seq − 1 is still valid (it embeds
            // the last position and fills the final KV slot); cap only once
            // the context is actually full.
            if !a.prefilling()
                && a.generated.len() < a.req.gen_len
                && a.cache.len >= cfg.max_seq
            {
                // Context exhausted before gen_len: truncate — never pad
                // with fabricated tokens — and retire as Length below.
                a.capped = true;
            }
        }
        let mut decoding: Vec<&mut Active> = active
            .iter_mut()
            .filter(|a| !a.prefilling() && !a.capped && a.generated.len() < a.req.gen_len)
            .collect();
        if !decoding.is_empty() {
            let tokens: Vec<u16> =
                decoding.iter().map(|a| *a.generated.last().unwrap()).collect();
            let logits = {
                let mut caches: Vec<&mut KvCache> =
                    decoding.iter_mut().map(|a| &mut a.cache).collect();
                lm.decode_step_batch(&tokens, &mut caches)
            };
            for (i, a) in decoding.iter_mut().enumerate() {
                let next =
                    sample_row(logits.row(i), a.req.temperature, a.req.top_k, &mut a.rng);
                a.generated.push(next);
            }
        }
        // Sample KV usage at the round's high-water mark: after prefill
        // chunks AND the decode step grew the caches, before retirement
        // frees them (sampling pre-decode missed every sequence's final,
        // largest state).
        metrics.on_kv_bytes(active.iter().map(|a| a.cache.bytes()).sum());
        metrics.on_kv_pages(
            active.iter().map(|a| a.cache.pages()).sum(),
            active.iter().map(|a| a.cache.rows_stored()).sum(),
            active.iter().map(|a| a.cache.capacity_rows()).sum(),
        );

        // (4) retire finished (gen_len reached, or cut off by the context).
        let mut i = 0;
        while i < active.len() {
            let done = active[i].generated.len() >= active[i].req.gen_len || active[i].capped;
            if done {
                let a = active.swap_remove(i);
                let decode_us = a.decode_started.elapsed().as_micros() as u64;
                let total_us = a.req.arrived.elapsed().as_micros() as u64;
                let resp = Response {
                    id: a.req.id,
                    finish: if a.capped { FinishReason::Length } else { FinishReason::Done },
                    tokens: a.generated,
                    queue_us: a.queue_us,
                    prefill_us: a.prefill_us,
                    decode_us,
                    total_us,
                };
                metrics.on_complete(&resp);
                let _ = a.req.reply.send(resp); // receiver may have gone away
                // `a` (and its KvCache) drops here: every page the sequence
                // held returns to the process-wide pool this round, so the
                // freed budget — and the pages themselves — are available
                // to the next admission.
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn small_weights() -> Weights {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 64, mlp_mult: 2 };
        Weights::random(cfg, 11)
    }

    #[test]
    fn serves_a_request_end_to_end() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        let rx = h.submit(vec![1, 2, 3], 5, 0.8, 8).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.total_us > 0);
        assert!(resp.ttft_us() <= resp.total_us + 1000);
        let snap = h.shutdown();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn serves_concurrent_requests() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        let rxs: Vec<_> = (0..6)
            .map(|i| h.submit(vec![1, 2, (i % 30) as u16 + 1], 4, 0.5, 4).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert_eq!(resp.tokens.len(), 4);
        }
        let snap = h.shutdown();
        assert_eq!(snap.completed, 6);
        assert!(snap.peak_active >= 2, "batching should overlap requests");
    }

    #[test]
    fn rejects_bad_requests() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        assert_eq!(h.submit(vec![], 4, 0.0, 1).unwrap_err(), SubmitError::BadRequest);
        assert_eq!(
            h.submit(vec![1; 64], 1, 0.0, 1).unwrap_err(),
            SubmitError::BadRequest,
            "prompt leaves no room to generate"
        );
        let snap = h.shutdown();
        assert_eq!(snap.rejected, 2);
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn context_overrun_truncates_with_length_finish() {
        // max_seq 64: a 60-token prompt with gen_len 10 has room for exactly
        // 5 generated tokens (one sampled off the prefill + decodes through
        // the last context slot). Regression: the engine used to pad the
        // missing tail by duplicating the last token and report all 10 as
        // generated.
        let h = Engine::start(small_weights(), EngineOptions::default());
        let rx = h.submit(vec![1; 60], 10, 0.0, 1).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.finish, FinishReason::Length);
        assert_eq!(resp.tokens.len(), 5, "truncated, not padded: {:?}", resp.tokens);
        // An in-budget request on the same engine finishes Done.
        let rx = h.submit(vec![1, 2, 3], 4, 0.0, 1).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.finish, FinishReason::Done);
        assert_eq!(resp.tokens.len(), 4);
        let snap = h.shutdown();
        // 4 real decode steps for the capped request + 3 for the Done one —
        // fabricated tokens must not inflate the decode metric.
        assert_eq!(snap.decode_tokens, 7);
    }

    #[test]
    #[allow(deprecated)]
    fn start_bounded_alias_still_enforces_bound() {
        let opts = EngineOptions { max_queue: 1, ..Default::default() };
        let h = Engine::start_bounded(small_weights(), opts);
        let mut saw_full = false;
        let mut receivers = Vec::new();
        for _ in 0..20 {
            match h.submit(vec![1, 2], 2, 0.0, 1) {
                Ok(rx) => receivers.push(rx),
                Err(SubmitError::QueueFull) => saw_full = true,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saw_full, "deprecated alias must keep the queue bound");
        for rx in receivers {
            let _ = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        }
        h.shutdown();
    }

    #[test]
    fn backpressure_rejects_on_full_queue() {
        let opts = EngineOptions { max_queue: 2, ..Default::default() };
        let h = Engine::start(small_weights(), opts);
        // Flood faster than the scheduler can drain; expect ≥1 rejection.
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..40 {
            match h.submit(vec![1, 2, (i % 30) as u16 + 1], 2, 0.0, 1) {
                Ok(rx) => receivers.push(rx),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(rejected > 0, "queue bound must trigger backpressure");
        for rx in receivers {
            let _ = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        }
        h.shutdown();
    }

    #[test]
    fn kv_budget_defers_but_serves_eventually() {
        // A page budget that fits exactly one sequence's projection:
        // requests must serialize through the KV bound, not be rejected or
        // deadlocked. (Projection: 3 prompt + 4 gen = 7 tokens across 1
        // layer × 2 heads × K/V, each side ⌈7/page_rows⌉ pages.)
        let w = small_weights();
        let one_seq = KvCache::pages_for_tokens(7, &w.cfg);
        let opts = EngineOptions {
            policy: BatchPolicy { max_kv_pages: one_seq, ..Default::default() },
            ..Default::default()
        };
        let h = Engine::start(w, opts);
        let rxs: Vec<_> = (0..4)
            .map(|i| h.submit(vec![1, 2, (i + 1) as u16], 4, 0.0, 1).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert_eq!(resp.tokens.len(), 4);
        }
        let snap = h.shutdown();
        assert_eq!(snap.completed, 4);
        assert!(snap.peak_kv_bytes > 0, "kv byte accounting must be recorded");
        assert!(snap.peak_kv_pages > 0, "kv page accounting must be recorded");
        assert!(
            snap.peak_kv_pages <= one_seq,
            "page budget must keep one sequence resident at a time: {} > {one_seq}",
            snap.peak_kv_pages
        );
        assert!(
            snap.kv_tail_utilization > 0.0 && snap.kv_tail_utilization <= 1.0,
            "utilization sample out of range: {}",
            snap.kv_tail_utilization
        );
    }

    #[test]
    fn chunked_prefill_preserves_greedy_output() {
        let w = small_weights();
        let prompt: Vec<u16> = (1..=10).collect();
        let run = |chunk: usize| {
            let opts = EngineOptions {
                attention: PipelineKind::Fp32,
                policy: BatchPolicy { prefill_chunk: chunk, ..Default::default() },
                ..Default::default()
            };
            let h = Engine::start(w.clone(), opts);
            let rx = h.submit(prompt.clone(), 5, 0.0, 1).unwrap();
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            h.shutdown();
            resp.tokens
        };
        // FP32 row-wise math is independent of the chunking, so greedy
        // decoding must be bit-stable across chunk sizes.
        assert_eq!(run(0), run(3));
    }

    #[test]
    fn metrics_snapshot_coherent() {
        let h = Engine::start(small_weights(), EngineOptions::default());
        let rx = h.submit(vec![5, 6, 7, 8], 3, 0.0, 1).unwrap();
        let _ = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let snap = h.shutdown();
        assert_eq!(snap.prefill_tokens, 4);
        assert_eq!(snap.decode_tokens, 2);
        assert!(snap.throughput_tok_s > 0.0);
        assert!(snap.render().contains("tok/s"));
    }
}
