//! Request/response types of the serving engine, plus the client-side
//! lifecycle levers: per-request cancellation ([`CancelToken`]), optional
//! submit-relative deadlines ([`SubmitOptions`]), and a receiver wrapper
//! ([`ResponseRx`]) whose drop is an implicit cancel — a client that hangs
//! up stops burning KV pages and decode rounds.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Cooperative cancellation flag, shared between a client and the scheduler
/// (checked at round boundaries). Cloning shares the flag.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation. Idempotent; the scheduler retires the request
    /// with [`FinishReason::Cancelled`] at the next round boundary.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// A generation request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// Prompt tokens (byte-level).
    pub prompt: Vec<u16>,
    /// Number of tokens to generate.
    pub gen_len: usize,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Top-k truncation.
    pub top_k: usize,
    /// Enqueue timestamp (set by the engine).
    pub arrived: Instant,
    /// Optional deadline, relative to `arrived`: once exceeded the request
    /// retires with [`FinishReason::DeadlineExceeded`] and whatever tokens
    /// it generated so far.
    pub deadline: Option<Duration>,
    /// Cancellation flag shared with the submitting client.
    pub cancel: CancelToken,
    /// Completion channel.
    pub reply: mpsc::Sender<Response>,
}

impl Request {
    /// Whether the request's deadline (if any) has passed.
    pub fn deadline_exceeded(&self) -> bool {
        self.deadline.is_some_and(|d| self.arrived.elapsed() >= d)
    }
}

/// Why a generation stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The requested `gen_len` tokens were generated.
    Done,
    /// The model's context filled up first: `tokens` holds only what was
    /// actually generated (truncated — never padded with fabricated tokens).
    Length,
    /// Cancelled — explicitly via [`CancelToken::cancel`], implicitly by the
    /// client dropping its [`ResponseRx`], or by an engine drain/hard stop
    /// answering work it will not run. `tokens` holds any partial output.
    Cancelled,
    /// The submit-relative deadline passed before the request finished.
    /// `tokens` holds any partial output.
    DeadlineExceeded,
    /// The request's model step panicked (it is poisoned and retired); the
    /// engine and every other in-flight request keep running.
    Error,
}

impl FinishReason {
    /// Whether the request ran to a successful completion (`Done`/`Length`)
    /// as opposed to an aborted lifecycle.
    pub fn is_ok(self) -> bool {
        matches!(self, FinishReason::Done | FinishReason::Length)
    }
}

/// Completed generation with timing breakdown.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u16>,
    /// Whether the request ran to `gen_len` or was cut off by the context.
    pub finish: FinishReason,
    /// Time from arrival to scheduling (queueing delay), µs.
    pub queue_us: u64,
    /// Prefill (time-to-first-token minus queueing), µs.
    pub prefill_us: u64,
    /// Total decode time, µs.
    pub decode_us: u64,
    /// End-to-end latency, µs.
    pub total_us: u64,
}

impl Response {
    /// Time-to-first-token (the paper's TTFT motivation, §1): queue + prefill.
    pub fn ttft_us(&self) -> u64 {
        self.queue_us + self.prefill_us
    }

    /// Mean inter-token latency during decode.
    pub fn decode_per_token_us(&self) -> f64 {
        if self.tokens.len() <= 1 {
            0.0
        } else {
            self.decode_us as f64 / (self.tokens.len() - 1) as f64
        }
    }
}

/// Per-submit options beyond the prompt/sampling parameters.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    /// Deadline relative to the submit instant; `None` = no deadline.
    pub deadline: Option<Duration>,
}

/// The client's end of a request: a [`Response`] receiver tied to the
/// request's [`CancelToken`]. Dropping it without [`ResponseRx::detach`]
/// cancels the request — a vanished client must not keep decoding (the
/// scheduler would otherwise burn rounds and KV pages on output nobody can
/// ever read). Exactly one terminal [`Response`] arrives per request.
#[derive(Debug)]
pub struct ResponseRx {
    /// `None` only after [`ResponseRx::detach`] consumed the receiver.
    rx: Option<mpsc::Receiver<Response>>,
    cancel: CancelToken,
}

impl ResponseRx {
    pub(crate) fn new(rx: mpsc::Receiver<Response>, cancel: CancelToken) -> Self {
        ResponseRx { rx: Some(rx), cancel }
    }

    fn rx(&self) -> &mpsc::Receiver<Response> {
        self.rx.as_ref().expect("receiver present until detach consumes self")
    }

    /// Block for the terminal response.
    pub fn recv(&self) -> Result<Response, mpsc::RecvError> {
        self.rx().recv()
    }

    /// Block for the terminal response with a timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Response, mpsc::RecvTimeoutError> {
        self.rx().recv_timeout(timeout)
    }

    /// Non-blocking poll for the terminal response.
    pub fn try_recv(&self) -> Result<Response, mpsc::TryRecvError> {
        self.rx().try_recv()
    }

    /// Cancel the request (keeping the receiver: the terminal
    /// [`FinishReason::Cancelled`] response still arrives).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clone of the request's cancel token, e.g. to cancel from another
    /// thread while this handle blocks in [`ResponseRx::recv`].
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Opt out of drop-cancels: take the raw receiver and let the request
    /// run to completion even if the receiver is later dropped (fire-and-
    /// forget submission).
    pub fn detach(mut self) -> mpsc::Receiver<Response> {
        self.rx.take().expect("receiver present until detach consumes self")
    }
}

impl Drop for ResponseRx {
    fn drop(&mut self) {
        // Hang-up = implicit cancel; `detach` took `rx` and opted out.
        if self.rx.is_some() {
            self.cancel.cancel();
        }
    }
}

/// Why a submit was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
    BadRequest,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitError::QueueFull => "queue full (backpressure)",
            SubmitError::ShuttingDown => "engine is shutting down",
            SubmitError::BadRequest => "prompt empty or exceeds max context",
        })
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_is_queue_plus_prefill() {
        let (tx, _rx) = mpsc::channel();
        let _req = Request {
            id: 1,
            prompt: vec![1],
            gen_len: 4,
            temperature: 0.0,
            top_k: 1,
            arrived: Instant::now(),
            deadline: None,
            cancel: CancelToken::new(),
            reply: tx,
        };
        let r = Response {
            id: 1,
            tokens: vec![1, 2, 3],
            finish: FinishReason::Done,
            queue_us: 100,
            prefill_us: 400,
            decode_us: 600,
            total_us: 1100,
        };
        assert_eq!(r.ttft_us(), 500);
        assert!((r.decode_per_token_us() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn single_token_decode_rate_is_zero() {
        let r = Response {
            id: 1,
            tokens: vec![9],
            finish: FinishReason::Length,
            queue_us: 0,
            prefill_us: 1,
            decode_us: 0,
            total_us: 1,
        };
        assert_eq!(r.decode_per_token_us(), 0.0);
    }

    #[test]
    fn cancel_token_is_shared_across_clones() {
        let t = CancelToken::new();
        let u = t.clone();
        assert!(!t.is_cancelled());
        u.cancel();
        assert!(t.is_cancelled());
        t.cancel(); // idempotent
        assert!(u.is_cancelled());
    }

    #[test]
    fn deadline_exceeded_checks_against_arrival() {
        let (tx, _rx) = mpsc::channel();
        let mut req = Request {
            id: 1,
            prompt: vec![1],
            gen_len: 1,
            temperature: 0.0,
            top_k: 1,
            arrived: Instant::now(),
            deadline: None,
            cancel: CancelToken::new(),
            reply: tx,
        };
        assert!(!req.deadline_exceeded(), "no deadline never expires");
        req.deadline = Some(Duration::from_secs(3600));
        assert!(!req.deadline_exceeded());
        req.deadline = Some(Duration::ZERO);
        assert!(req.deadline_exceeded());
    }

    #[test]
    fn dropping_response_rx_cancels_detached_does_not() {
        let (tx, rx) = mpsc::channel::<Response>();
        let token = CancelToken::new();
        drop(ResponseRx::new(rx, token.clone()));
        assert!(token.is_cancelled(), "hang-up is an implicit cancel");
        drop(tx);

        let (tx, rx) = mpsc::channel::<Response>();
        let token = CancelToken::new();
        let raw = ResponseRx::new(rx, token.clone()).detach();
        assert!(!token.is_cancelled(), "detach opts out of drop-cancel");
        drop(raw);
        drop(tx);
    }

    #[test]
    fn finish_reason_ok_split() {
        assert!(FinishReason::Done.is_ok());
        assert!(FinishReason::Length.is_ok());
        assert!(!FinishReason::Cancelled.is_ok());
        assert!(!FinishReason::DeadlineExceeded.is_ok());
        assert!(!FinishReason::Error.is_ok());
    }
}
