//! Request/response types of the serving engine.

use std::sync::mpsc;
use std::time::Instant;

/// A generation request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// Prompt tokens (byte-level).
    pub prompt: Vec<u16>,
    /// Number of tokens to generate.
    pub gen_len: usize,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Top-k truncation.
    pub top_k: usize,
    /// Enqueue timestamp (set by the engine).
    pub arrived: Instant,
    /// Completion channel.
    pub reply: mpsc::Sender<Response>,
}

/// Why a generation stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The requested `gen_len` tokens were generated.
    Done,
    /// The model's context filled up first: `tokens` holds only what was
    /// actually generated (truncated — never padded with fabricated tokens).
    Length,
}

/// Completed generation with timing breakdown.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u16>,
    /// Whether the request ran to `gen_len` or was cut off by the context.
    pub finish: FinishReason,
    /// Time from arrival to scheduling (queueing delay), µs.
    pub queue_us: u64,
    /// Prefill (time-to-first-token minus queueing), µs.
    pub prefill_us: u64,
    /// Total decode time, µs.
    pub decode_us: u64,
    /// End-to-end latency, µs.
    pub total_us: u64,
}

impl Response {
    /// Time-to-first-token (the paper's TTFT motivation, §1): queue + prefill.
    pub fn ttft_us(&self) -> u64 {
        self.queue_us + self.prefill_us
    }

    /// Mean inter-token latency during decode.
    pub fn decode_per_token_us(&self) -> f64 {
        if self.tokens.len() <= 1 {
            0.0
        } else {
            self.decode_us as f64 / (self.tokens.len() - 1) as f64
        }
    }
}

/// Why a submit was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
    BadRequest,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitError::QueueFull => "queue full (backpressure)",
            SubmitError::ShuttingDown => "engine is shutting down",
            SubmitError::BadRequest => "prompt empty or exceeds max context",
        })
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_is_queue_plus_prefill() {
        let (tx, _rx) = mpsc::channel();
        let _req = Request {
            id: 1,
            prompt: vec![1],
            gen_len: 4,
            temperature: 0.0,
            top_k: 1,
            arrived: Instant::now(),
            reply: tx,
        };
        let r = Response {
            id: 1,
            tokens: vec![1, 2, 3],
            finish: FinishReason::Done,
            queue_us: 100,
            prefill_us: 400,
            decode_us: 600,
            total_us: 1100,
        };
        assert_eq!(r.ttft_us(), 500);
        assert!((r.decode_per_token_us() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn single_token_decode_rate_is_zero() {
        let r = Response {
            id: 1,
            tokens: vec![9],
            finish: FinishReason::Length,
            queue_us: 0,
            prefill_us: 1,
            decode_us: 0,
            total_us: 1,
        };
        assert_eq!(r.decode_per_token_us(), 0.0);
    }
}
