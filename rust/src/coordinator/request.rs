//! Request types and the streaming client API of the serving engine.
//!
//! A submit returns a [`StreamRx`]: a per-request event stream over which
//! the scheduler delivers [`StreamEvent`]s as they happen — `Queued` at
//! accept, `Prefilling` at admission, one `Token` per decoded token as each
//! round's batched decode lands, and exactly one terminal `Final` carrying
//! the whole [`Response`]. Clients that only want the terminal response use
//! the [`StreamRx::recv_all`] compatibility shim.
//!
//! Lifecycle levers ride on the stream: per-request cancellation
//! ([`CancelToken`]), submit-relative deadlines and a bounded stream buffer
//! ([`SubmitOptions`]), and drop-of-receiver = implicit cancel — a client
//! that hangs up stops burning KV pages and decode rounds.
//!
//! All event timestamps (`ts_us`) are µs since the request arrived, stamped
//! on one monotonic clock by the scheduler. The µs fields of the terminal
//! [`Response`] are *derived from the same stamps* (see [`Response`]), so
//! `queue_us + prefill_us + decode_us == total_us` holds exactly and the
//! stream and the terminal timings can never drift apart.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Cooperative cancellation flag, shared between a client and the scheduler
/// (checked at round boundaries). Cloning shares the flag.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation. Idempotent; the scheduler retires the request
    /// with [`FinishReason::Cancelled`] at the next round boundary.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// A generation request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// Prompt tokens (byte-level).
    pub prompt: Vec<u16>,
    /// Number of tokens to generate.
    pub gen_len: usize,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Top-k truncation.
    pub top_k: usize,
    /// Enqueue timestamp (set by the engine).
    pub arrived: Instant,
    /// Optional deadline, relative to `arrived`: once exceeded the request
    /// retires with [`FinishReason::DeadlineExceeded`] and whatever tokens
    /// it generated so far.
    pub deadline: Option<Duration>,
    /// Scheduler rounds this request has spent in the wait queue (maintained
    /// by the scheduler; the admission gate's age valve reads it).
    pub waited_rounds: u64,
    /// Cancellation flag shared with the submitting client.
    pub cancel: CancelToken,
    /// Event stream back to the client.
    pub stream: StreamTx,
}

impl Request {
    /// Whether the request's deadline (if any) has passed.
    pub fn deadline_exceeded(&self) -> bool {
        self.deadline.is_some_and(|d| self.arrived.elapsed() >= d)
    }
}

/// Why a generation stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The requested `gen_len` tokens were generated.
    Done,
    /// The model's context filled up first: `tokens` holds only what was
    /// actually generated (truncated — never padded with fabricated tokens).
    Length,
    /// Cancelled — explicitly via [`CancelToken::cancel`], implicitly by the
    /// client dropping its [`StreamRx`] or falling behind a bounded stream
    /// buffer, or by an engine drain/hard stop answering work it will not
    /// run. `tokens` holds any partial output.
    Cancelled,
    /// The submit-relative deadline passed before the request finished.
    /// `tokens` holds any partial output.
    DeadlineExceeded,
    /// The request's model step panicked (it is poisoned and retired); the
    /// engine and every other in-flight request keep running.
    Error,
}

impl FinishReason {
    /// Whether the request ran to a successful completion (`Done`/`Length`)
    /// as opposed to an aborted lifecycle.
    pub fn is_ok(self) -> bool {
        matches!(self, FinishReason::Done | FinishReason::Length)
    }
}

/// Completed generation with timing breakdown.
///
/// The µs fields are derived from the request's stream timestamps — three
/// stamps on one monotonic clock (admission, first token, retirement), so:
///
/// - `queue_us` = arrival → admission (→ retirement if never admitted),
/// - `prefill_us` = admission → first token (→ retirement if the request
///   was cut mid-prefill),
/// - `decode_us` = first token → retirement (0 if no token was produced),
/// - `total_us` ≡ `queue_us + prefill_us + decode_us`, exactly.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u16>,
    /// Whether the request ran to `gen_len` or was cut off by the context.
    pub finish: FinishReason,
    /// Time from arrival to scheduling (queueing delay), µs.
    pub queue_us: u64,
    /// Prefill (time-to-first-token minus queueing), µs.
    pub prefill_us: u64,
    /// Total decode time, µs.
    pub decode_us: u64,
    /// End-to-end latency, µs.
    pub total_us: u64,
}

impl Response {
    /// Time-to-first-token (the paper's TTFT motivation, §1): queue + prefill.
    pub fn ttft_us(&self) -> u64 {
        self.queue_us + self.prefill_us
    }

    /// Mean inter-token latency during decode.
    pub fn decode_per_token_us(&self) -> f64 {
        if self.tokens.len() <= 1 {
            0.0
        } else {
            self.decode_us as f64 / (self.tokens.len() - 1) as f64
        }
    }
}

/// One event on a request's stream. Timestamps are µs since the request's
/// arrival, stamped by the scheduler on the arrival clock.
///
/// Per accepted submit the stream is exactly:
/// `Queued (Prefilling (Token)*)? Final` — `Prefilling` is absent when the
/// request retires straight from the wait queue, `Token`s carry strictly
/// sequential `index`es (0, 1, 2, …) in decode order, and nothing follows
/// `Final`.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// Accepted by the engine handle; always the first event.
    Queued { id: u64 },
    /// Admitted into the active set; prefill starts. `ts_us` is the
    /// queueing delay.
    Prefilling { id: u64, ts_us: u64 },
    /// One decoded token, in decode order. `index` 0 is the token sampled
    /// when prefill completes; its `ts_us` is the request's TTFT.
    Token { id: u64, index: u32, token: u16, ts_us: u64 },
    /// Terminal event: exactly one per accepted submit, carrying the full
    /// token sequence and the derived timing breakdown.
    Final(Response),
}

impl StreamEvent {
    /// The id of the request this event belongs to.
    pub fn id(&self) -> u64 {
        match self {
            StreamEvent::Queued { id }
            | StreamEvent::Prefilling { id, .. }
            | StreamEvent::Token { id, .. } => *id,
            StreamEvent::Final(resp) => resp.id,
        }
    }
}

/// Per-submit options: sampling parameters plus the lifecycle levers.
///
/// ```
/// # use std::time::Duration;
/// # use intattention::coordinator::SubmitOptions;
/// let opts = SubmitOptions::default() // greedy
///     .with_deadline(Duration::from_millis(500))
///     .with_stream_buffer(64);
/// let sampled = SubmitOptions::sampling(0.7, 16);
/// # let _ = (opts, sampled);
/// ```
#[derive(Clone, Copy, Debug)]
pub struct SubmitOptions {
    /// Sampling temperature; 0 = greedy (the default).
    pub temperature: f32,
    /// Top-k truncation (clamped to ≥ 1 at submit).
    pub top_k: usize,
    /// Deadline relative to the submit instant; `None` = no deadline.
    pub deadline: Option<Duration>,
    /// Bound on un-consumed stream events before the scheduler treats the
    /// client as gone and cancels the request; 0 = unbounded (the default).
    pub stream_buffer: usize,
}

impl Default for SubmitOptions {
    fn default() -> Self {
        SubmitOptions { temperature: 0.0, top_k: 1, deadline: None, stream_buffer: 0 }
    }
}

impl SubmitOptions {
    /// Greedy decoding, no deadline, unbounded stream.
    pub fn new() -> Self {
        Self::default()
    }

    /// Shorthand for temperature/top-k sampling.
    pub fn sampling(temperature: f32, top_k: usize) -> Self {
        Self::default().with_temperature(temperature).with_top_k(top_k)
    }

    pub fn with_temperature(mut self, temperature: f32) -> Self {
        self.temperature = temperature;
        self
    }

    pub fn with_top_k(mut self, top_k: usize) -> Self {
        self.top_k = top_k;
        self
    }

    /// Retire with [`FinishReason::DeadlineExceeded`] (and partial output)
    /// once `deadline` has passed since submit.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Bound the stream buffer: if more than `events` sent events sit
    /// un-received, the scheduler cancels the request rather than buffer
    /// without limit for a client that stopped reading. 0 = unbounded.
    pub fn with_stream_buffer(mut self, events: usize) -> Self {
        self.stream_buffer = events;
        self
    }
}

/// The scheduler's end of a request stream. Sends never block: events go
/// onto an unbounded channel and the `pending` counter (decremented by the
/// receiver) is what enforces [`SubmitOptions::stream_buffer`].
#[derive(Debug)]
pub struct StreamTx {
    tx: mpsc::Sender<StreamEvent>,
    /// Events sent but not yet received; shared with the [`StreamRx`].
    pending: Arc<AtomicUsize>,
    /// Overflow threshold; 0 = unbounded.
    buffer: usize,
    /// Set once `Final` is sent; no event may follow it.
    final_sent: AtomicBool,
}

impl StreamTx {
    pub(crate) fn new(
        tx: mpsc::Sender<StreamEvent>,
        pending: Arc<AtomicUsize>,
        buffer: usize,
    ) -> Self {
        StreamTx { tx, pending, buffer, final_sent: AtomicBool::new(false) }
    }

    /// Send one event; returns whether a receiver still exists. `Final`
    /// seals the stream — sending anything after it is a logic error.
    pub(crate) fn send(&self, ev: StreamEvent) -> bool {
        debug_assert!(
            !self.final_sent.load(Ordering::Relaxed),
            "no event may follow Final on a request stream"
        );
        if matches!(ev, StreamEvent::Final(_)) {
            self.final_sent.store(true, Ordering::Relaxed);
        }
        let delivered = self.tx.send(ev).is_ok();
        if delivered {
            self.pending.fetch_add(1, Ordering::SeqCst);
        }
        delivered
    }

    /// Whether the client has fallen behind a bounded stream buffer
    /// (strictly more sent-but-unread events than the bound).
    pub(crate) fn overflowed(&self) -> bool {
        self.buffer > 0 && self.pending.load(Ordering::SeqCst) > self.buffer
    }
}

/// The client's end of a request: a [`StreamEvent`] receiver tied to the
/// request's [`CancelToken`]. Dropping it before the stream's `Final` (and
/// without [`StreamRx::detach`]) cancels the request — a vanished client
/// must not keep decoding. Dropping it *after* receiving `Final` is a
/// normal hang-up: the request already retired and no cancel fires.
/// Exactly one terminal [`StreamEvent::Final`] arrives per request.
#[derive(Debug)]
pub struct StreamRx {
    /// `None` only after [`StreamRx::detach`] consumed the receiver.
    rx: Option<mpsc::Receiver<StreamEvent>>,
    cancel: CancelToken,
    pending: Arc<AtomicUsize>,
    /// Whether this receiver has seen the terminal `Final`.
    saw_final: bool,
}

impl StreamRx {
    pub(crate) fn new(
        rx: mpsc::Receiver<StreamEvent>,
        cancel: CancelToken,
        pending: Arc<AtomicUsize>,
    ) -> Self {
        StreamRx { rx: Some(rx), cancel, pending, saw_final: false }
    }

    fn rx(&self) -> &mpsc::Receiver<StreamEvent> {
        self.rx.as_ref().expect("receiver present until detach consumes self")
    }

    fn note(&mut self, ev: &StreamEvent) {
        self.pending.fetch_sub(1, Ordering::SeqCst);
        if matches!(ev, StreamEvent::Final(_)) {
            self.saw_final = true;
        }
    }

    /// Block for the next event.
    pub fn recv(&mut self) -> Result<StreamEvent, mpsc::RecvError> {
        let ev = self.rx().recv()?;
        self.note(&ev);
        Ok(ev)
    }

    /// Block for the next event with a timeout.
    pub fn recv_timeout(
        &mut self,
        timeout: Duration,
    ) -> Result<StreamEvent, mpsc::RecvTimeoutError> {
        let ev = self.rx().recv_timeout(timeout)?;
        self.note(&ev);
        Ok(ev)
    }

    /// Non-blocking poll for the next event.
    pub fn try_recv(&mut self) -> Result<StreamEvent, mpsc::TryRecvError> {
        let ev = self.rx().try_recv()?;
        self.note(&ev);
        Ok(ev)
    }

    /// Drain events until the terminal `Final` and return its [`Response`]
    /// — the whole-response compatibility shim for callers that do not care
    /// about per-token delivery. The receiver stays usable afterwards (e.g.
    /// to assert the stream is exhausted).
    pub fn recv_final(&mut self) -> Result<Response, mpsc::RecvError> {
        loop {
            if let StreamEvent::Final(resp) = self.recv()? {
                return Ok(resp);
            }
        }
    }

    /// [`StreamRx::recv_final`] with a total (not per-event) timeout.
    pub fn recv_final_timeout(
        &mut self,
        timeout: Duration,
    ) -> Result<Response, mpsc::RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if let StreamEvent::Final(resp) = self.recv_timeout(left)? {
                return Ok(resp);
            }
        }
    }

    /// Consume the stream and return the terminal [`Response`].
    pub fn recv_all(mut self) -> Result<Response, mpsc::RecvError> {
        self.recv_final()
    }

    /// [`StreamRx::recv_all`] with a total timeout.
    pub fn recv_all_timeout(
        mut self,
        timeout: Duration,
    ) -> Result<Response, mpsc::RecvTimeoutError> {
        self.recv_final_timeout(timeout)
    }

    /// Cancel the request (keeping the receiver: the stream still ends with
    /// a [`FinishReason::Cancelled`] `Final`).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clone of the request's cancel token, e.g. to cancel from another
    /// thread while this handle blocks in [`StreamRx::recv`].
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Opt out of drop-cancels: take the raw receiver and let the request
    /// run to completion even if the receiver is later dropped (fire-and-
    /// forget submission). Note the raw receiver no longer decrements the
    /// stream-buffer counter, so don't combine with a bounded
    /// [`SubmitOptions::stream_buffer`].
    pub fn detach(mut self) -> mpsc::Receiver<StreamEvent> {
        self.rx.take().expect("receiver present until detach consumes self")
    }
}

impl Drop for StreamRx {
    fn drop(&mut self) {
        // Hang-up before `Final` = implicit cancel. After `Final` the
        // request has already retired — cancelling then would at best be a
        // no-op and at worst (if `try_recv` raced a just-sent `Final` that
        // this receiver *did* consume) mislabel a completed request, so the
        // guard is skipped once the terminal event was seen. `detach` took
        // `rx` and opted out entirely.
        if self.rx.is_some() && !self.saw_final {
            self.cancel.cancel();
        }
    }
}

/// Why a submit was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
    BadRequest,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitError::QueueFull => "queue full (backpressure)",
            SubmitError::ShuttingDown => "engine is shutting down",
            SubmitError::BadRequest => "prompt empty or exceeds max context",
        })
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair(buffer: usize) -> (StreamTx, StreamRx) {
        let (tx, rx) = mpsc::channel();
        let cancel = CancelToken::new();
        let pending = Arc::new(AtomicUsize::new(0));
        (
            StreamTx::new(tx, Arc::clone(&pending), buffer),
            StreamRx::new(rx, cancel, pending),
        )
    }

    fn resp(finish: FinishReason) -> Response {
        Response {
            id: 1,
            tokens: vec![1, 2, 3],
            finish,
            queue_us: 100,
            prefill_us: 400,
            decode_us: 600,
            total_us: 1100,
        }
    }

    #[test]
    fn ttft_is_queue_plus_prefill() {
        let r = resp(FinishReason::Done);
        assert_eq!(r.ttft_us(), 500);
        assert_eq!(r.queue_us + r.prefill_us + r.decode_us, r.total_us);
        assert!((r.decode_per_token_us() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn single_token_decode_rate_is_zero() {
        let r = Response {
            id: 1,
            tokens: vec![9],
            finish: FinishReason::Length,
            queue_us: 0,
            prefill_us: 1,
            decode_us: 0,
            total_us: 1,
        };
        assert_eq!(r.decode_per_token_us(), 0.0);
    }

    #[test]
    fn cancel_token_is_shared_across_clones() {
        let t = CancelToken::new();
        let u = t.clone();
        assert!(!t.is_cancelled());
        u.cancel();
        assert!(t.is_cancelled());
        t.cancel(); // idempotent
        assert!(u.is_cancelled());
    }

    #[test]
    fn deadline_exceeded_checks_against_arrival() {
        let (tx, _rx) = pair(0);
        let mut req = Request {
            id: 1,
            prompt: vec![1],
            gen_len: 1,
            temperature: 0.0,
            top_k: 1,
            arrived: Instant::now(),
            deadline: None,
            waited_rounds: 0,
            cancel: CancelToken::new(),
            stream: tx,
        };
        assert!(!req.deadline_exceeded(), "no deadline never expires");
        req.deadline = Some(Duration::from_secs(3600));
        assert!(!req.deadline_exceeded());
        req.deadline = Some(Duration::ZERO);
        assert!(req.deadline_exceeded());
    }

    #[test]
    fn dropping_stream_rx_cancels_detached_does_not() {
        let (tx, rx) = pair(0);
        let token = rx.cancel_token();
        drop(rx);
        assert!(token.is_cancelled(), "hang-up is an implicit cancel");
        drop(tx);

        let (tx, rx) = pair(0);
        let token = rx.cancel_token();
        let raw = rx.detach();
        assert!(!token.is_cancelled(), "detach opts out of drop-cancel");
        drop(raw);
        drop(tx);
    }

    #[test]
    fn drop_after_final_does_not_cancel() {
        // The satellite regression: receive `Final`, then drop — the
        // drop-cancel guard must not fire (no Cancelled double-terminal).
        let (tx, mut rx) = pair(0);
        let token = rx.cancel_token();
        assert!(tx.send(StreamEvent::Queued { id: 1 }));
        assert!(tx.send(StreamEvent::Final(resp(FinishReason::Done))));
        assert!(matches!(rx.recv().unwrap(), StreamEvent::Queued { .. }));
        assert!(matches!(rx.recv().unwrap(), StreamEvent::Final(_)));
        drop(rx);
        assert!(!token.is_cancelled(), "drop after Final must not cancel");
    }

    #[test]
    fn drop_before_buffered_final_still_cancels() {
        // A `Final` that was sent but never read does not disarm the
        // guard: the client hung up without consuming the terminal, and
        // cancelling an already-retired request is a no-op anyway.
        let (tx, rx) = pair(0);
        let token = rx.cancel_token();
        assert!(tx.send(StreamEvent::Final(resp(FinishReason::Done))));
        drop(rx);
        assert!(token.is_cancelled());
    }

    #[test]
    fn recv_all_drains_to_final() {
        let (tx, rx) = pair(0);
        assert!(tx.send(StreamEvent::Queued { id: 7 }));
        assert!(tx.send(StreamEvent::Prefilling { id: 7, ts_us: 10 }));
        assert!(tx.send(StreamEvent::Token { id: 7, index: 0, token: 42, ts_us: 20 }));
        assert!(tx.send(StreamEvent::Final(resp(FinishReason::Done))));
        let r = rx.recv_all().unwrap();
        assert_eq!(r.finish, FinishReason::Done);
        assert_eq!(r.tokens, vec![1, 2, 3]);
    }

    #[test]
    fn stream_buffer_overflow_is_pending_minus_received() {
        let (tx, mut rx) = pair(2);
        assert!(!tx.overflowed(), "empty stream is within any bound");
        assert!(tx.send(StreamEvent::Queued { id: 1 }));
        assert!(tx.send(StreamEvent::Prefilling { id: 1, ts_us: 1 }));
        assert!(!tx.overflowed(), "at the bound is not over it");
        assert!(tx.send(StreamEvent::Token { id: 1, index: 0, token: 5, ts_us: 2 }));
        assert!(tx.overflowed(), "three unread events exceed a bound of 2");
        rx.recv().unwrap();
        assert!(!tx.overflowed(), "receiving drains the pending count");
        let (unbounded, _rx) = pair(0);
        for _ in 0..64 {
            assert!(unbounded.send(StreamEvent::Queued { id: 1 }));
        }
        assert!(!unbounded.overflowed(), "0 = unbounded");
    }

    #[test]
    fn submit_options_builder() {
        let o = SubmitOptions::default();
        assert_eq!(o.temperature, 0.0);
        assert_eq!(o.top_k, 1);
        assert!(o.deadline.is_none());
        assert_eq!(o.stream_buffer, 0);
        let o = SubmitOptions::sampling(0.7, 16)
            .with_deadline(Duration::from_millis(250))
            .with_stream_buffer(8);
        assert_eq!(o.temperature, 0.7);
        assert_eq!(o.top_k, 16);
        assert_eq!(o.deadline, Some(Duration::from_millis(250)));
        assert_eq!(o.stream_buffer, 8);
    }

    #[test]
    fn event_id_covers_all_variants() {
        assert_eq!(StreamEvent::Queued { id: 3 }.id(), 3);
        assert_eq!(StreamEvent::Prefilling { id: 4, ts_us: 0 }.id(), 4);
        assert_eq!(StreamEvent::Token { id: 5, index: 0, token: 1, ts_us: 0 }.id(), 5);
        assert_eq!(StreamEvent::Final(resp(FinishReason::Done)).id(), 1);
    }

    #[test]
    fn finish_reason_ok_split() {
        assert!(FinishReason::Done.is_ok());
        assert!(FinishReason::Length.is_ok());
        assert!(!FinishReason::Cancelled.is_ok());
        assert!(!FinishReason::DeadlineExceeded.is_ok());
        assert!(!FinishReason::Error.is_ok());
    }
}
