//! Request-level prefix sharing: a hash index from prompt-token prefixes to
//! copy-on-write KV-cache snapshots, so N requests carrying the same system
//! prompt cost **one** set of prefix pages and **one** quantization pass
//! plus per-request suffixes.
//!
//! ## Why alignment makes sharing invisible
//!
//! The hard invariant is that sharing must be *byte-invisible*: a request
//! that adopts a prefix must produce exactly the outputs it would have
//! produced computing the prefix itself. Two mechanisms interact:
//!
//! * **Pages** — a snapshot's page run is adopted by reference
//!   ([`KvCache::share_prefix`]); any later rewrite (tail-page append, INT8
//!   re-scale remap) forks the shared page first, so sharers never observe
//!   each other (see `crate::attention::state`).
//! * **Scales and chunk boundaries** — the integer pipelines quantize each
//!   prefill chunk's query block per call and carry running K/V scales, so
//!   resident bytes depend on *where the chunk boundaries fell*. A snapshot
//!   is therefore only adoptable if (a) it was taken when the donor's
//!   running scales covered exactly the snapshotted rows, and (b) the
//!   adopter's remaining chunk boundaries coincide with the boundaries an
//!   unshared run would have used.
//!
//! Both hold iff snapshots live only at multiples of
//! `align = lcm(page_rows, prefill_chunk)`: every such boundary is hit
//! exactly by the engine's chunk schedule (chunks step `prefill_chunk`
//! tokens from position 0), prefix pages are whole pages (the donor's later
//! appends open fresh pages instead of touching shared ones), and an
//! adopter resuming at a multiple of `prefill_chunk` reproduces the
//! unshared boundary sequence. With chunking disabled (`prefill_chunk ==
//! 0`) no boundary can be reproduced, so the index is simply not built.
//!
//! Keys are chained FNV-1a hashes of `align`-sized token chunks (vLLM-style
//! block hashing), and every hit is verified by full token comparison, so a
//! hash collision can never splice the wrong prefix into a request. Entries
//! hold page *references*; a bounded FIFO eviction caps how many pages the
//! index pins once donors retire.

use crate::model::lm::KvCache;
use std::collections::{HashMap, VecDeque};

/// Entries the index keeps before evicting the oldest (each entry pins its
/// snapshot's pages until evicted).
pub const PREFIX_INDEX_CAP: usize = 32;

/// Default on/off for prefix sharing: `INTATTN_PREFIX_SHARE` (`0`/`false`/
/// `off` disable; anything else — including unset — enables). Snapshotted
/// once per process with the page-size and thread-count knobs
/// ([`crate::util::env::knobs`]); tests that need both modes set
/// [`crate::coordinator::batcher::BatchPolicy::prefix_share`] directly
/// instead of mutating the environment (parse policy:
/// [`crate::util::env::prefix_share_from`]).
pub fn default_prefix_share() -> bool {
    crate::util::env::knobs().prefix_share
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: usize, b: usize) -> usize {
    a / gcd(a, b) * b
}

/// Chained FNV-1a over token chunks: `h_n = fnv(h_{n-1}, chunk_n)`, so all
/// aligned prefix hashes of a prompt come out of one linear pass.
fn fnv1a(mut h: u64, tokens: &[u16]) -> u64 {
    for &t in tokens {
        h = (h ^ t as u64).wrapping_mul(0x0100_0000_01b3);
    }
    h
}

const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;

struct Frozen {
    /// The exact token run the snapshot covers — every lookup hit is
    /// verified against it, so hash collisions cannot splice wrong pages.
    tokens: Vec<u16>,
    /// Page-sharing snapshot taken when the donor's cache held exactly
    /// `tokens.len()` positions (scales cover exactly the shared rows).
    cache: KvCache,
}

/// The admission-time prefix index. Owned by the scheduler thread (no
/// locking); dropped with the engine, releasing every pinned page.
pub struct PrefixIndex {
    align: usize,
    cap: usize,
    entries: HashMap<u64, Frozen>,
    /// Insertion order for FIFO eviction.
    order: VecDeque<u64>,
}

impl PrefixIndex {
    /// Build an index for the given page/chunk geometry, or `None` when
    /// sharing cannot be byte-invisible (chunking disabled — there is no
    /// boundary an adopter could resume from without changing the unshared
    /// run's quantization granularity).
    pub fn new(page_rows: usize, prefill_chunk: usize, cap: usize) -> Option<PrefixIndex> {
        if prefill_chunk == 0 || page_rows == 0 {
            return None;
        }
        Some(PrefixIndex {
            align: lcm(page_rows, prefill_chunk),
            cap: cap.max(1),
            entries: HashMap::new(),
            order: VecDeque::new(),
        })
    }

    /// Registration/adoption granularity: `lcm(page_rows, prefill_chunk)`.
    pub fn align(&self) -> usize {
        self.align
    }

    /// Is `pos` a snapshot boundary (aligned, non-zero)?
    pub fn aligned(&self, pos: usize) -> bool {
        pos > 0 && pos % self.align == 0
    }

    /// Entries currently held (each pins one snapshot's pages).
    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// KV pages the entries currently pin (page references held by the
    /// snapshots). Chained entries of one prompt alias the same physical
    /// pages, so this sum is an upper bound on distinct pinned pages —
    /// the conservative direction for the engine's page-budget charge
    /// (shared prefix pages are charged once, to the index).
    pub fn pinned_pages(&self) -> usize {
        self.entries.values().map(|f| f.cache.pages()).sum()
    }

    /// Evict the oldest entry whose token run is not exactly `keep`,
    /// releasing its page references. The engine calls this under
    /// page-budget pressure with `keep` = the token run the pressured
    /// candidate is about to adopt (empty when it matched nothing), so
    /// cached-but-idle prefixes — including *shorter chained snapshots of
    /// the same prompt*, whose pages overlap the kept entry's and only
    /// inflate [`Self::pinned_pages`] — yield to live admissions without
    /// invalidating the peeked match. Returns false when no entry is
    /// evictable — at that point at most the kept entry remains, so the
    /// pinned-page sum is overlap-free (exact).
    pub fn evict_oldest_excluding(&mut self, keep: &[u16]) -> bool {
        let pos = self
            .order
            .iter()
            .position(|k| !self.entries.get(k).is_some_and(|f| f.tokens[..] == *keep));
        match pos {
            Some(i) => {
                let key = self.order.remove(i).expect("position valid");
                self.entries.remove(&key);
                true
            }
            None => false,
        }
    }

    /// Chain hash of `prefix` (whole aligned chunks only).
    fn key_of(&self, prefix: &[u16]) -> u64 {
        debug_assert!(self.aligned(prefix.len()));
        prefix.chunks(self.align).fold(FNV_SEED, fnv1a)
    }

    /// Record a snapshot of `cache`'s first `prefix.len()` positions.
    /// `prefix` must be the prompt run the cache was prefilled with, its
    /// length must be an aligned boundary, and the cache must hold exactly
    /// that many positions (so the integer states' running scales describe
    /// precisely the shared rows). First writer wins; an existing entry for
    /// the same token run is kept (its pages are already shared around).
    pub fn register(&mut self, prefix: &[u16], cache: &KvCache) {
        debug_assert_eq!(cache.len, prefix.len(), "snapshot must cover exactly the prefix");
        if !self.aligned(prefix.len()) {
            return;
        }
        let key = self.key_of(prefix);
        if self.entries.contains_key(&key) {
            return;
        }
        if self.entries.len() >= self.cap {
            if let Some(old) = self.order.pop_front() {
                self.entries.remove(&old); // dropping the Frozen releases its page refs
            }
        }
        let frozen = Frozen { tokens: prefix.to_vec(), cache: cache.share_prefix(prefix.len()) };
        self.entries.insert(key, frozen);
        self.order.push_back(key);
    }

    /// Length of the longest adoptable prefix of `prompt` strictly beyond
    /// `beyond` (0 = none): aligned, registered, token-verified, and short
    /// enough to leave at least one prompt token to prefill (the last
    /// token's logits are what the first sampled token comes from).
    pub fn match_len(&self, prompt: &[u16], beyond: usize) -> usize {
        if prompt.len() <= 1 {
            return 0;
        }
        let max_len = prompt.len() - 1;
        let mut h = FNV_SEED;
        let mut best = 0;
        for n in 1..=max_len / self.align {
            let len = n * self.align;
            h = fnv1a(h, &prompt[len - self.align..len]);
            if len <= beyond {
                continue;
            }
            if self.entries.get(&h).is_some_and(|e| e.tokens == prompt[..len]) {
                best = len;
            }
        }
        best
    }

    /// Adopt the longest registered prefix of `prompt` strictly beyond
    /// position `beyond` (the caller's already-prefilled length — pass 0 at
    /// admission). Returns the adopted length and a cache aliasing the
    /// snapshot's pages copy-on-write; the caller replaces its cache with
    /// it and resumes prefill at that position. Because registration and
    /// adoption both live on aligned boundaries, the resumed chunk
    /// schedule is exactly the unshared one — sharing stays byte-invisible.
    pub fn adopt(&self, prompt: &[u16], beyond: usize) -> Option<(usize, KvCache)> {
        self.adopt_at(prompt, self.match_len(prompt, beyond))
    }

    /// [`Self::adopt`] for a length already known from a
    /// [`Self::match_len`] peek — hashes only the `len`-token prefix
    /// instead of re-scanning the whole prompt chain (the engine peeks for
    /// its budget projection first and materializes the CoW cache only
    /// after the request passes admission). Verifies the entry still
    /// token-matches; returns `None` for `len == 0`.
    pub fn adopt_at(&self, prompt: &[u16], len: usize) -> Option<(usize, KvCache)> {
        if len == 0 || len > prompt.len() || !self.aligned(len) {
            return None;
        }
        let entry = self.entries.get(&self.key_of(&prompt[..len]))?;
        if entry.tokens != prompt[..len] {
            return None;
        }
        Some((len, entry.cache.share_prefix(len)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PipelineKind;
    use crate::model::config::ModelConfig;
    use crate::model::lm::TinyLm;
    use crate::model::weights::Weights;

    fn lm() -> TinyLm {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 64, mlp_mult: 2 };
        TinyLm::new(Weights::random(cfg, 5), PipelineKind::IntAttention)
    }

    fn prefilled(lm: &mut TinyLm, tokens: &[u16], chunk: usize) -> KvCache {
        let mut c = lm.new_cache();
        for start in (0..tokens.len()).step_by(chunk) {
            let end = (start + chunk).min(tokens.len());
            let _ = lm.forward(&tokens[start..end], Some(&mut c));
        }
        c
    }

    #[test]
    fn prefix_share_env_policy() {
        // The parse policy lives (and is exercised) in `crate::util::env`;
        // this checks only the snapshot wiring.
        assert_eq!(default_prefix_share(), crate::util::env::knobs().prefix_share);
    }

    #[test]
    fn alignment_is_lcm_and_chunk_zero_disables() {
        assert!(PrefixIndex::new(64, 0, 8).is_none(), "no chunking → no sharing");
        let ix = PrefixIndex::new(4, 6, 8).unwrap();
        assert_eq!(ix.align(), 12);
        assert!(ix.aligned(24));
        assert!(!ix.aligned(0));
        assert!(!ix.aligned(18));
        assert_eq!(PrefixIndex::new(2, 8, 8).unwrap().align(), 8);
    }

    #[test]
    fn register_then_adopt_longest_verified_match() {
        let mut lm = lm();
        let mut ix = PrefixIndex::new(2, 4, 8).unwrap(); // align 4
        let prompt: Vec<u16> = (0..12).map(|i| (i * 3 % 32) as u16).collect();
        let c8 = prefilled(&mut lm, &prompt[..8], 4);
        ix.register(&prompt[..4], &prefilled(&mut lm, &prompt[..4], 4));
        ix.register(&prompt[..8], &c8);
        // Longest match below the last token wins.
        let (len, cache) = ix.adopt(&prompt, 0).expect("hit");
        assert_eq!(len, 8);
        assert_eq!(cache.len, 8);
        assert!(cache.shared_pages() > 0, "adoption must alias, not copy");
        // `beyond` filters matches the caller already passed.
        assert_eq!(ix.match_len(&prompt, 8), 0);
        assert_eq!(ix.match_len(&prompt, 4), 8);
        // adopt_at re-verifies a peeked length without a full re-scan.
        let (len, cache) = ix.adopt_at(&prompt, 8).expect("peeked length adoptable");
        assert_eq!((len, cache.len), (8, 8));
        assert!(ix.adopt_at(&prompt, 0).is_none());
        assert!(ix.adopt_at(&prompt, 6).is_none(), "unaligned length never adopts");
        // A prompt diverging inside the first chunk misses entirely.
        let mut other = prompt.clone();
        other[1] ^= 1;
        assert_eq!(ix.match_len(&other, 0), 0);
        // A prompt equal to a registered prefix cannot adopt all of itself
        // (no token left to prefill): it falls back to the shorter entry.
        assert_eq!(ix.match_len(&prompt[..8], 0), 4);
    }

    #[test]
    fn eviction_is_fifo_and_bounded() {
        let mut lm = lm();
        let mut ix = PrefixIndex::new(2, 2, 2).unwrap(); // cap 2, align 2
        let prompts: Vec<Vec<u16>> = (0..3u16).map(|s| vec![s + 1, s + 2]).collect();
        for p in &prompts {
            ix.register(p, &prefilled(&mut lm, p, 2));
        }
        assert_eq!(ix.entries(), 2);
        // Oldest entry evicted; the two newest still adoptable.
        assert_eq!(ix.match_len(&[1, 2, 9], 0), 0);
        assert_eq!(ix.match_len(&[2, 3, 9], 0), 2);
        assert_eq!(ix.match_len(&[3, 4, 9], 0), 2);
    }

    #[test]
    fn pressure_eviction_spares_only_the_adopted_entry() {
        let mut lm = lm();
        let mut ix = PrefixIndex::new(2, 2, 8).unwrap(); // align 2
        let mine: Vec<u16> = vec![5, 6, 7, 8, 9];
        ix.register(&mine[..2], &prefilled(&mut lm, &mine[..2], 2));
        ix.register(&[1, 2], &prefilled(&mut lm, &[1, 2], 2));
        ix.register(&mine[..4], &prefilled(&mut lm, &mine[..4], 2));
        assert!(ix.pinned_pages() > 0);
        // A candidate adopting `mine[..4]` protects exactly that entry;
        // everything else — other prompts AND shorter chained snapshots of
        // the same prompt (their pages overlap the kept entry's and only
        // inflate pinned_pages) — yields FIFO-first under pressure.
        let keep = &mine[..4];
        assert!(ix.evict_oldest_excluding(keep)); // mine[..2] (oldest)
        assert!(ix.evict_oldest_excluding(keep)); // [1,2]
        assert_eq!(ix.entries(), 1);
        assert_eq!(ix.match_len(&mine, 0), 4, "adopted match survives pressure");
        assert!(!ix.evict_oldest_excluding(keep), "kept entry is never evicted");
        // Once only the kept entry remains, the pinned sum is overlap-free.
        let kept_pages = ix.pinned_pages();
        assert!(kept_pages > 0);
        // With nothing to protect, eviction proceeds to empty.
        assert!(ix.evict_oldest_excluding(&[]));
        assert_eq!(ix.entries(), 0);
        assert_eq!(ix.pinned_pages(), 0);
    }

    #[test]
    fn register_ignores_unaligned_and_duplicate_prefixes() {
        let mut lm = lm();
        let mut ix = PrefixIndex::new(2, 4, 8).unwrap(); // align 4
        let prompt: Vec<u16> = (0..6).map(|i| i as u16 + 1).collect();
        ix.register(&prompt[..6], &prefilled(&mut lm, &prompt[..6], 4));
        assert_eq!(ix.entries(), 0, "6 is not a multiple of align 4");
        let c = prefilled(&mut lm, &prompt[..4], 4);
        ix.register(&prompt[..4], &c);
        ix.register(&prompt[..4], &c);
        assert_eq!(ix.entries(), 1, "duplicate registration is a no-op");
    }
}
