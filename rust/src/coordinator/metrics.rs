//! Serving metrics: counters + latency histograms, shared between the
//! scheduler thread and callers via a mutex (updates are coarse-grained —
//! once per request / decode round — so contention is negligible).

use crate::util::stats::LogHistogram;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Aggregated engine metrics.
#[derive(Debug)]
pub struct MetricsInner {
    pub started: Instant,
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// Terminal-response counts by [`crate::coordinator::request::FinishReason`]
    /// — the five always sum to `completed` (every terminal response is
    /// counted exactly once).
    pub finished_done: u64,
    pub finished_length: u64,
    pub finished_cancelled: u64,
    pub finished_deadline: u64,
    pub finished_error: u64,
    /// Wall time of the last shutdown drain (signal → scheduler exit), µs.
    pub drain_us: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// Token events actually delivered to a live stream receiver (a token
    /// generated after the client hung up is decoded but not streamed).
    pub tokens_streamed: u64,
    /// Requests cancelled because their bounded stream buffer overflowed
    /// (`SubmitOptions::with_stream_buffer`): the client stopped reading.
    pub stream_overflow_cancels: u64,
    pub ttft_us: LogHistogram,
    pub e2e_us: LogHistogram,
    pub per_token_us: LogHistogram,
    /// Inter-token gaps as streamed (per Token event past the first of a
    /// request, scheduler-side stamps) — the client-facing cadence the
    /// `serving_load` bench reports percentiles of.
    pub itl_us: LogHistogram,
    /// Max concurrent active (decoding) requests observed.
    pub peak_active: usize,
    /// Max total KV-cache bytes held by active requests (allocated page
    /// capacity at pipeline-native widths: INT8 + scales for the integer
    /// pipelines).
    pub peak_kv_bytes: usize,
    /// Max total KV pages held by active requests — the unit the admission
    /// budget (`BatchPolicy::max_kv_pages`) bounds. Summed per holder, so
    /// under prefix sharing a page adopted by several live requests counts
    /// once per sharer (logical residency); physical page traffic is the
    /// pool counters' domain.
    pub peak_kv_pages: usize,
    /// Tail-page utilization (stored rows / allocated row slots) sampled at
    /// the page peak — how much of the reserved page capacity held data.
    pub kv_tail_utilization: f64,
    /// Prompt-prefix adoptions: requests that started from a shared
    /// copy-on-write prefix instead of re-quantizing it.
    pub prefix_hits: u64,
    /// Prompt tokens those adoptions skipped re-computing (cumulative).
    pub shared_prefix_tokens: u64,
    /// KV pages adopted by reference instead of allocated (cumulative over
    /// adoptions; every adopted page is shared at adoption time).
    pub shared_kv_pages: u64,
}

impl Default for MetricsInner {
    fn default() -> Self {
        MetricsInner {
            started: Instant::now(),
            submitted: 0,
            rejected: 0,
            completed: 0,
            finished_done: 0,
            finished_length: 0,
            finished_cancelled: 0,
            finished_deadline: 0,
            finished_error: 0,
            drain_us: 0,
            prefill_tokens: 0,
            decode_tokens: 0,
            tokens_streamed: 0,
            stream_overflow_cancels: 0,
            ttft_us: LogHistogram::new(),
            e2e_us: LogHistogram::new(),
            per_token_us: LogHistogram::new(),
            itl_us: LogHistogram::new(),
            peak_active: 0,
            peak_kv_bytes: 0,
            peak_kv_pages: 0,
            kv_tail_utilization: 0.0,
            prefix_hits: 0,
            shared_prefix_tokens: 0,
            shared_kv_pages: 0,
        }
    }
}

/// Shared handle.
#[derive(Clone, Default)]
pub struct Metrics(Arc<Mutex<MetricsInner>>);

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_submit(&self) {
        self.0.lock().unwrap().submitted += 1;
    }

    pub fn on_reject(&self) {
        self.0.lock().unwrap().rejected += 1;
    }

    pub fn on_active(&self, n: usize) {
        let mut m = self.0.lock().unwrap();
        m.peak_active = m.peak_active.max(n);
    }

    /// Record the current total KV bytes of all active sequences.
    pub fn on_kv_bytes(&self, bytes: usize) {
        let mut m = self.0.lock().unwrap();
        m.peak_kv_bytes = m.peak_kv_bytes.max(bytes);
    }

    /// Record the current KV page residency of all active sequences:
    /// allocated pages, stored rows, and the row slots those pages could
    /// hold. Utilization is sampled at the page peak.
    pub fn on_kv_pages(&self, pages: usize, rows_stored: usize, capacity_rows: usize) {
        let mut m = self.0.lock().unwrap();
        if pages >= m.peak_kv_pages {
            m.peak_kv_pages = pages;
            if capacity_rows > 0 {
                m.kv_tail_utilization = rows_stored as f64 / capacity_rows as f64;
            }
        }
    }

    /// Record a terminal response — `completed` counts every lifecycle
    /// outcome (the per-reason counters break it down), while the latency
    /// histograms only sample successful runs: a request cancelled in the
    /// wait queue has no time-to-first-token, and mixing aborted lifetimes
    /// into the percentiles would make the tail look arbitrarily good or
    /// bad depending on when clients hang up.
    pub fn on_complete(&self, resp: &crate::coordinator::request::Response) {
        use crate::coordinator::request::FinishReason;
        let mut m = self.0.lock().unwrap();
        m.completed += 1;
        match resp.finish {
            FinishReason::Done => m.finished_done += 1,
            FinishReason::Length => m.finished_length += 1,
            FinishReason::Cancelled => m.finished_cancelled += 1,
            FinishReason::DeadlineExceeded => m.finished_deadline += 1,
            FinishReason::Error => m.finished_error += 1,
        }
        // Partial output still reflects real decode rounds spent.
        m.decode_tokens += resp.tokens.len().saturating_sub(1) as u64;
        if resp.finish.is_ok() {
            m.ttft_us.record_us(resp.ttft_us() as f64);
            m.e2e_us.record_us(resp.total_us as f64);
            let pt = resp.decode_per_token_us();
            if pt > 0.0 {
                m.per_token_us.record_us(pt);
            }
        }
    }

    /// Record the wall time of a completed shutdown drain.
    pub fn on_drain(&self, us: u64) {
        self.0.lock().unwrap().drain_us = us;
    }

    pub fn on_prefill_tokens(&self, n: usize) {
        self.0.lock().unwrap().prefill_tokens += n as u64;
    }

    /// Fold one scheduling round's streaming deltas in: `streamed` Token
    /// events delivered and the inter-token `gaps` (µs between consecutive
    /// Token stamps of the same request) observed this round.
    pub fn on_stream_round(&self, streamed: u64, gaps: &[u64]) {
        if streamed == 0 && gaps.is_empty() {
            return;
        }
        let mut m = self.0.lock().unwrap();
        m.tokens_streamed += streamed;
        for &g in gaps {
            m.itl_us.record_us(g as f64);
        }
    }

    /// Record one slow-consumer cancellation (bounded stream buffer
    /// overflowed; the lifecycle sweep retires the request as `Cancelled`).
    pub fn on_stream_overflow(&self) {
        self.0.lock().unwrap().stream_overflow_cancels += 1;
    }

    /// Record one prefix adoption: `tokens` prompt positions and `pages` KV
    /// pages taken by reference instead of recomputed/allocated.
    pub fn on_prefix_hit(&self, tokens: usize, pages: usize) {
        let mut m = self.0.lock().unwrap();
        m.prefix_hits += 1;
        m.shared_prefix_tokens += tokens as u64;
        m.shared_kv_pages += pages as u64;
    }

    /// Snapshot for reporting. Page-pool counters come from the
    /// process-wide pools ([`crate::attention::page_pool_stats`]) and the
    /// fault counters from [`crate::util::fault::stats`] — both are
    /// monotone process totals, not per-engine deltas.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.0.lock().unwrap();
        let elapsed_s = m.started.elapsed().as_secs_f64().max(1e-9);
        let pool = crate::attention::page_pool_stats();
        let faults = crate::util::fault::stats();
        MetricsSnapshot {
            submitted: m.submitted,
            rejected: m.rejected,
            completed: m.completed,
            finished_done: m.finished_done,
            finished_length: m.finished_length,
            finished_cancelled: m.finished_cancelled,
            finished_deadline: m.finished_deadline,
            finished_error: m.finished_error,
            drain_us: m.drain_us,
            prefill_tokens: m.prefill_tokens,
            decode_tokens: m.decode_tokens,
            tokens_streamed: m.tokens_streamed,
            stream_overflow_cancels: m.stream_overflow_cancels,
            elapsed_s,
            throughput_tok_s: (m.prefill_tokens + m.decode_tokens) as f64 / elapsed_s,
            requests_per_s: m.completed as f64 / elapsed_s,
            ttft_p50_us: m.ttft_us.percentile_us(50.0),
            ttft_p99_us: m.ttft_us.percentile_us(99.0),
            e2e_p50_us: m.e2e_us.percentile_us(50.0),
            e2e_p99_us: m.e2e_us.percentile_us(99.0),
            per_token_mean_us: m.per_token_us.mean_us(),
            itl_p50_us: m.itl_us.percentile_us(50.0),
            itl_p95_us: m.itl_us.percentile_us(95.0),
            itl_p99_us: m.itl_us.percentile_us(99.0),
            peak_active: m.peak_active,
            peak_kv_bytes: m.peak_kv_bytes,
            peak_kv_pages: m.peak_kv_pages,
            kv_tail_utilization: m.kv_tail_utilization,
            prefix_hits: m.prefix_hits,
            shared_prefix_tokens: m.shared_prefix_tokens,
            shared_kv_pages: m.shared_kv_pages,
            kv_pages_allocated: pool.allocated,
            kv_pages_recycled: pool.recycled,
            kv_cow_forks: pool.cow_forks,
            fault_injected_panics: faults.injected_panics,
            fault_failed_allocs: faults.failed_allocs,
            fault_injected_delays: faults.injected_delays,
        }
    }
}

/// Immutable metrics snapshot.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// Terminal responses by [`crate::coordinator::request::FinishReason`];
    /// the five sum to `completed`.
    pub finished_done: u64,
    pub finished_length: u64,
    pub finished_cancelled: u64,
    pub finished_deadline: u64,
    pub finished_error: u64,
    /// Wall time of the last shutdown drain (signal → scheduler exit), µs.
    pub drain_us: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// Token events delivered to live stream receivers.
    pub tokens_streamed: u64,
    /// Requests cancelled for overflowing their bounded stream buffer.
    pub stream_overflow_cancels: u64,
    pub elapsed_s: f64,
    pub throughput_tok_s: f64,
    pub requests_per_s: f64,
    pub ttft_p50_us: f64,
    pub ttft_p99_us: f64,
    pub e2e_p50_us: f64,
    pub e2e_p99_us: f64,
    pub per_token_mean_us: f64,
    /// Inter-token latency percentiles over streamed Token stamps.
    pub itl_p50_us: f64,
    pub itl_p95_us: f64,
    pub itl_p99_us: f64,
    pub peak_active: usize,
    pub peak_kv_bytes: usize,
    /// Peak concurrent KV pages across active requests (per holder: a
    /// prefix-shared page counts once per live sharer).
    pub peak_kv_pages: usize,
    /// Stored rows / allocated row slots at the page peak.
    pub kv_tail_utilization: f64,
    /// Requests that adopted a shared prompt prefix (copy-on-write pages).
    pub prefix_hits: u64,
    /// Prompt tokens adoption skipped re-computing (cumulative).
    pub shared_prefix_tokens: u64,
    /// KV pages adopted by reference instead of allocated (cumulative).
    pub shared_kv_pages: u64,
    /// Process-wide pages allocated fresh from the allocator (monotone).
    pub kv_pages_allocated: u64,
    /// Process-wide pages recycled from the pool free list (monotone).
    pub kv_pages_recycled: u64,
    /// Process-wide copy-on-write page forks — shared pages copied before a
    /// divergent append or re-scale remap (monotone).
    pub kv_cow_forks: u64,
    /// Process-wide injected step panics that fired (monotone; see
    /// [`crate::util::fault`] — 0 unless a fault plan is armed).
    pub fault_injected_panics: u64,
    /// Process-wide injected page-acquisition failures that fired.
    pub fault_failed_allocs: u64,
    /// Process-wide injected delays slept.
    pub fault_injected_delays: u64,
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests: {} ok / {} rejected / {} submitted | tokens: {} prefill + {} decode \
             ({} streamed) | {:.1} tok/s | ttft p50 {:.1} ms p99 {:.1} ms | e2e p50 {:.1} ms \
             | itl p50 {:.1} ms p99 {:.1} ms | peak batch {} \
             | peak kv {:.1} KiB ({} pages, {:.0}% util) | pool {} alloc / {} recycled \
             | prefix hits {} ({} pages shared, {} cow forks) \
             | finish: {} done, {} length, {} cancelled ({} overflow), {} deadline, {} error \
             | drain {:.1} ms | faults: {} panics / {} allocs / {} delays",
            self.completed,
            self.rejected,
            self.submitted,
            self.prefill_tokens,
            self.decode_tokens,
            self.tokens_streamed,
            self.throughput_tok_s,
            self.ttft_p50_us / 1e3,
            self.ttft_p99_us / 1e3,
            self.e2e_p50_us / 1e3,
            self.itl_p50_us / 1e3,
            self.itl_p99_us / 1e3,
            self.peak_active,
            self.peak_kv_bytes as f64 / 1024.0,
            self.peak_kv_pages,
            100.0 * self.kv_tail_utilization,
            self.kv_pages_allocated,
            self.kv_pages_recycled,
            self.prefix_hits,
            self.shared_kv_pages,
            self.kv_cow_forks,
            self.finished_done,
            self.finished_length,
            self.finished_cancelled,
            self.stream_overflow_cancels,
            self.finished_deadline,
            self.finished_error,
            self.drain_us as f64 / 1e3,
            self.fault_injected_panics,
            self.fault_failed_allocs,
            self.fault_injected_delays,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;

    #[test]
    fn counts_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_prefill_tokens(100);
        m.on_active(3);
        m.on_active(2);
        let r = Response {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            finish: crate::coordinator::request::FinishReason::Done,
            queue_us: 10,
            prefill_us: 90,
            decode_us: 300,
            total_us: 400,
        };
        m.on_complete(&r);
        m.on_stream_round(1, &[]); // first token of a request: no gap yet
        m.on_stream_round(3, &[120, 80, 100]);
        m.on_stream_round(0, &[]); // idle round: no-op
        m.on_kv_bytes(2048);
        m.on_kv_pages(10, 18, 20);
        m.on_kv_pages(4, 4, 8); // below peak: utilization sample kept
        m.on_prefix_hit(64, 12);
        m.on_prefix_hit(64, 12);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.prefill_tokens, 100);
        assert_eq!(s.decode_tokens, 3);
        assert_eq!(s.peak_active, 3);
        assert_eq!(s.peak_kv_bytes, 2048);
        assert_eq!(s.peak_kv_pages, 10);
        assert!((s.kv_tail_utilization - 0.9).abs() < 1e-12);
        assert_eq!(s.prefix_hits, 2);
        assert_eq!(s.shared_prefix_tokens, 128);
        assert_eq!(s.shared_kv_pages, 24);
        assert!(s.ttft_p50_us > 0.0);
        assert_eq!(s.tokens_streamed, 4);
        assert!(s.itl_p50_us > 0.0, "three gaps were recorded");
        assert!(s.itl_p50_us <= s.itl_p99_us);
        let rendered = s.render();
        assert!(rendered.contains("requests: 1 ok"));
        assert!(rendered.contains("4 streamed"), "{rendered}");
        assert!(rendered.contains("itl p50"), "{rendered}");
        assert!(rendered.contains("10 pages"), "{rendered}");
        assert!(rendered.contains("recycled"), "{rendered}");
        assert!(rendered.contains("prefix hits 2"), "{rendered}");
    }

    #[test]
    fn stream_overflow_cancels_counted_separately() {
        let m = Metrics::new();
        m.on_stream_overflow();
        let s = m.snapshot();
        assert_eq!(s.stream_overflow_cancels, 1);
        assert!(s.render().contains("(1 overflow)"), "{}", s.render());
    }

    #[test]
    fn finish_reasons_partition_completed_and_histograms_skip_aborts() {
        use crate::coordinator::request::FinishReason;
        let m = Metrics::new();
        let resp = |finish, tokens: Vec<u16>| Response {
            id: 0,
            tokens,
            finish,
            queue_us: 5,
            prefill_us: 5,
            decode_us: 10,
            total_us: 20,
        };
        m.on_complete(&resp(FinishReason::Done, vec![1, 2]));
        m.on_complete(&resp(FinishReason::Length, vec![1]));
        m.on_complete(&resp(FinishReason::Cancelled, vec![1, 2, 3]));
        m.on_complete(&resp(FinishReason::DeadlineExceeded, vec![]));
        m.on_complete(&resp(FinishReason::Error, vec![1]));
        m.on_drain(2500);
        let s = m.snapshot();
        assert_eq!(s.completed, 5);
        assert_eq!(s.finished_done, 1);
        assert_eq!(s.finished_length, 1);
        assert_eq!(s.finished_cancelled, 1);
        assert_eq!(s.finished_deadline, 1);
        assert_eq!(s.finished_error, 1);
        let by_reason = s.finished_done
            + s.finished_length
            + s.finished_cancelled
            + s.finished_deadline
            + s.finished_error;
        assert_eq!(by_reason, s.completed, "reasons partition completed");
        // Decode work is real whatever the outcome (3 aborted-path tokens
        // beyond each first = 1+0+2+0+0), but latency histograms sample
        // only the two successful runs.
        assert_eq!(s.decode_tokens, 3);
        assert_eq!(s.drain_us, 2500);
        let rendered = s.render();
        assert!(rendered.contains("1 cancelled"), "{rendered}");
        assert!(rendered.contains("1 deadline"), "{rendered}");
        assert!(rendered.contains("1 error"), "{rendered}");
        assert!(rendered.contains("drain 2.5 ms"), "{rendered}");
        assert!(rendered.contains("faults:"), "{rendered}");
    }
}
