//! Serving metrics: counters + latency histograms, shared between the
//! scheduler thread and callers via a mutex (updates are coarse-grained —
//! once per request / decode round — so contention is negligible).

use crate::util::stats::LogHistogram;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Aggregated engine metrics.
#[derive(Debug)]
pub struct MetricsInner {
    pub started: Instant,
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub ttft_us: LogHistogram,
    pub e2e_us: LogHistogram,
    pub per_token_us: LogHistogram,
    /// Max concurrent active (decoding) requests observed.
    pub peak_active: usize,
    /// Max total KV-cache bytes held by active requests (pipeline-native
    /// widths: INT8 + scales for the integer pipelines).
    pub peak_kv_bytes: usize,
}

impl Default for MetricsInner {
    fn default() -> Self {
        MetricsInner {
            started: Instant::now(),
            submitted: 0,
            rejected: 0,
            completed: 0,
            prefill_tokens: 0,
            decode_tokens: 0,
            ttft_us: LogHistogram::new(),
            e2e_us: LogHistogram::new(),
            per_token_us: LogHistogram::new(),
            peak_active: 0,
            peak_kv_bytes: 0,
        }
    }
}

/// Shared handle.
#[derive(Clone, Default)]
pub struct Metrics(Arc<Mutex<MetricsInner>>);

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_submit(&self) {
        self.0.lock().unwrap().submitted += 1;
    }

    pub fn on_reject(&self) {
        self.0.lock().unwrap().rejected += 1;
    }

    pub fn on_active(&self, n: usize) {
        let mut m = self.0.lock().unwrap();
        m.peak_active = m.peak_active.max(n);
    }

    /// Record the current total KV bytes of all active sequences.
    pub fn on_kv_bytes(&self, bytes: usize) {
        let mut m = self.0.lock().unwrap();
        m.peak_kv_bytes = m.peak_kv_bytes.max(bytes);
    }

    pub fn on_complete(&self, resp: &crate::coordinator::request::Response) {
        let mut m = self.0.lock().unwrap();
        m.completed += 1;
        m.decode_tokens += resp.tokens.len().saturating_sub(1) as u64;
        m.ttft_us.record_us(resp.ttft_us() as f64);
        m.e2e_us.record_us(resp.total_us as f64);
        let pt = resp.decode_per_token_us();
        if pt > 0.0 {
            m.per_token_us.record_us(pt);
        }
    }

    pub fn on_prefill_tokens(&self, n: usize) {
        self.0.lock().unwrap().prefill_tokens += n as u64;
    }

    /// Snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.0.lock().unwrap();
        let elapsed_s = m.started.elapsed().as_secs_f64().max(1e-9);
        MetricsSnapshot {
            submitted: m.submitted,
            rejected: m.rejected,
            completed: m.completed,
            prefill_tokens: m.prefill_tokens,
            decode_tokens: m.decode_tokens,
            elapsed_s,
            throughput_tok_s: (m.prefill_tokens + m.decode_tokens) as f64 / elapsed_s,
            requests_per_s: m.completed as f64 / elapsed_s,
            ttft_p50_us: m.ttft_us.percentile_us(50.0),
            ttft_p99_us: m.ttft_us.percentile_us(99.0),
            e2e_p50_us: m.e2e_us.percentile_us(50.0),
            e2e_p99_us: m.e2e_us.percentile_us(99.0),
            per_token_mean_us: m.per_token_us.mean_us(),
            peak_active: m.peak_active,
            peak_kv_bytes: m.peak_kv_bytes,
        }
    }
}

/// Immutable metrics snapshot.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub elapsed_s: f64,
    pub throughput_tok_s: f64,
    pub requests_per_s: f64,
    pub ttft_p50_us: f64,
    pub ttft_p99_us: f64,
    pub e2e_p50_us: f64,
    pub e2e_p99_us: f64,
    pub per_token_mean_us: f64,
    pub peak_active: usize,
    pub peak_kv_bytes: usize,
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests: {} ok / {} rejected / {} submitted | tokens: {} prefill + {} decode \
             | {:.1} tok/s | ttft p50 {:.1} ms p99 {:.1} ms | e2e p50 {:.1} ms | peak batch {} \
             | peak kv {:.1} KiB",
            self.completed,
            self.rejected,
            self.submitted,
            self.prefill_tokens,
            self.decode_tokens,
            self.throughput_tok_s,
            self.ttft_p50_us / 1e3,
            self.ttft_p99_us / 1e3,
            self.e2e_p50_us / 1e3,
            self.peak_active,
            self.peak_kv_bytes as f64 / 1024.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;

    #[test]
    fn counts_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_prefill_tokens(100);
        m.on_active(3);
        m.on_active(2);
        let r = Response {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            finish: crate::coordinator::request::FinishReason::Done,
            queue_us: 10,
            prefill_us: 90,
            decode_us: 300,
            total_us: 400,
        };
        m.on_complete(&r);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.prefill_tokens, 100);
        assert_eq!(s.decode_tokens, 3);
        assert_eq!(s.peak_active, 3);
        assert!(s.ttft_p50_us > 0.0);
        assert!(s.render().contains("requests: 1 ok"));
    }
}
