//! Serving metrics: counters + latency histograms, shared between the
//! scheduler thread and callers via a mutex (updates are coarse-grained —
//! once per request / decode round — so contention is negligible).

use crate::util::stats::LogHistogram;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Aggregated engine metrics.
#[derive(Debug)]
pub struct MetricsInner {
    pub started: Instant,
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub ttft_us: LogHistogram,
    pub e2e_us: LogHistogram,
    pub per_token_us: LogHistogram,
    /// Max concurrent active (decoding) requests observed.
    pub peak_active: usize,
    /// Max total KV-cache bytes held by active requests (allocated page
    /// capacity at pipeline-native widths: INT8 + scales for the integer
    /// pipelines).
    pub peak_kv_bytes: usize,
    /// Max total KV pages held by active requests — the unit the admission
    /// budget (`BatchPolicy::max_kv_pages`) bounds. Summed per holder, so
    /// under prefix sharing a page adopted by several live requests counts
    /// once per sharer (logical residency); physical page traffic is the
    /// pool counters' domain.
    pub peak_kv_pages: usize,
    /// Tail-page utilization (stored rows / allocated row slots) sampled at
    /// the page peak — how much of the reserved page capacity held data.
    pub kv_tail_utilization: f64,
    /// Prompt-prefix adoptions: requests that started from a shared
    /// copy-on-write prefix instead of re-quantizing it.
    pub prefix_hits: u64,
    /// Prompt tokens those adoptions skipped re-computing (cumulative).
    pub shared_prefix_tokens: u64,
    /// KV pages adopted by reference instead of allocated (cumulative over
    /// adoptions; every adopted page is shared at adoption time).
    pub shared_kv_pages: u64,
}

impl Default for MetricsInner {
    fn default() -> Self {
        MetricsInner {
            started: Instant::now(),
            submitted: 0,
            rejected: 0,
            completed: 0,
            prefill_tokens: 0,
            decode_tokens: 0,
            ttft_us: LogHistogram::new(),
            e2e_us: LogHistogram::new(),
            per_token_us: LogHistogram::new(),
            peak_active: 0,
            peak_kv_bytes: 0,
            peak_kv_pages: 0,
            kv_tail_utilization: 0.0,
            prefix_hits: 0,
            shared_prefix_tokens: 0,
            shared_kv_pages: 0,
        }
    }
}

/// Shared handle.
#[derive(Clone, Default)]
pub struct Metrics(Arc<Mutex<MetricsInner>>);

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_submit(&self) {
        self.0.lock().unwrap().submitted += 1;
    }

    pub fn on_reject(&self) {
        self.0.lock().unwrap().rejected += 1;
    }

    pub fn on_active(&self, n: usize) {
        let mut m = self.0.lock().unwrap();
        m.peak_active = m.peak_active.max(n);
    }

    /// Record the current total KV bytes of all active sequences.
    pub fn on_kv_bytes(&self, bytes: usize) {
        let mut m = self.0.lock().unwrap();
        m.peak_kv_bytes = m.peak_kv_bytes.max(bytes);
    }

    /// Record the current KV page residency of all active sequences:
    /// allocated pages, stored rows, and the row slots those pages could
    /// hold. Utilization is sampled at the page peak.
    pub fn on_kv_pages(&self, pages: usize, rows_stored: usize, capacity_rows: usize) {
        let mut m = self.0.lock().unwrap();
        if pages >= m.peak_kv_pages {
            m.peak_kv_pages = pages;
            if capacity_rows > 0 {
                m.kv_tail_utilization = rows_stored as f64 / capacity_rows as f64;
            }
        }
    }

    pub fn on_complete(&self, resp: &crate::coordinator::request::Response) {
        let mut m = self.0.lock().unwrap();
        m.completed += 1;
        m.decode_tokens += resp.tokens.len().saturating_sub(1) as u64;
        m.ttft_us.record_us(resp.ttft_us() as f64);
        m.e2e_us.record_us(resp.total_us as f64);
        let pt = resp.decode_per_token_us();
        if pt > 0.0 {
            m.per_token_us.record_us(pt);
        }
    }

    pub fn on_prefill_tokens(&self, n: usize) {
        self.0.lock().unwrap().prefill_tokens += n as u64;
    }

    /// Record one prefix adoption: `tokens` prompt positions and `pages` KV
    /// pages taken by reference instead of recomputed/allocated.
    pub fn on_prefix_hit(&self, tokens: usize, pages: usize) {
        let mut m = self.0.lock().unwrap();
        m.prefix_hits += 1;
        m.shared_prefix_tokens += tokens as u64;
        m.shared_kv_pages += pages as u64;
    }

    /// Snapshot for reporting. Page-pool counters come from the
    /// process-wide pools ([`crate::attention::page_pool_stats`]) — they
    /// are monotone process totals, not per-engine deltas.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.0.lock().unwrap();
        let elapsed_s = m.started.elapsed().as_secs_f64().max(1e-9);
        let pool = crate::attention::page_pool_stats();
        MetricsSnapshot {
            submitted: m.submitted,
            rejected: m.rejected,
            completed: m.completed,
            prefill_tokens: m.prefill_tokens,
            decode_tokens: m.decode_tokens,
            elapsed_s,
            throughput_tok_s: (m.prefill_tokens + m.decode_tokens) as f64 / elapsed_s,
            requests_per_s: m.completed as f64 / elapsed_s,
            ttft_p50_us: m.ttft_us.percentile_us(50.0),
            ttft_p99_us: m.ttft_us.percentile_us(99.0),
            e2e_p50_us: m.e2e_us.percentile_us(50.0),
            e2e_p99_us: m.e2e_us.percentile_us(99.0),
            per_token_mean_us: m.per_token_us.mean_us(),
            peak_active: m.peak_active,
            peak_kv_bytes: m.peak_kv_bytes,
            peak_kv_pages: m.peak_kv_pages,
            kv_tail_utilization: m.kv_tail_utilization,
            prefix_hits: m.prefix_hits,
            shared_prefix_tokens: m.shared_prefix_tokens,
            shared_kv_pages: m.shared_kv_pages,
            kv_pages_allocated: pool.allocated,
            kv_pages_recycled: pool.recycled,
            kv_cow_forks: pool.cow_forks,
        }
    }
}

/// Immutable metrics snapshot.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub elapsed_s: f64,
    pub throughput_tok_s: f64,
    pub requests_per_s: f64,
    pub ttft_p50_us: f64,
    pub ttft_p99_us: f64,
    pub e2e_p50_us: f64,
    pub e2e_p99_us: f64,
    pub per_token_mean_us: f64,
    pub peak_active: usize,
    pub peak_kv_bytes: usize,
    /// Peak concurrent KV pages across active requests (per holder: a
    /// prefix-shared page counts once per live sharer).
    pub peak_kv_pages: usize,
    /// Stored rows / allocated row slots at the page peak.
    pub kv_tail_utilization: f64,
    /// Requests that adopted a shared prompt prefix (copy-on-write pages).
    pub prefix_hits: u64,
    /// Prompt tokens adoption skipped re-computing (cumulative).
    pub shared_prefix_tokens: u64,
    /// KV pages adopted by reference instead of allocated (cumulative).
    pub shared_kv_pages: u64,
    /// Process-wide pages allocated fresh from the allocator (monotone).
    pub kv_pages_allocated: u64,
    /// Process-wide pages recycled from the pool free list (monotone).
    pub kv_pages_recycled: u64,
    /// Process-wide copy-on-write page forks — shared pages copied before a
    /// divergent append or re-scale remap (monotone).
    pub kv_cow_forks: u64,
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests: {} ok / {} rejected / {} submitted | tokens: {} prefill + {} decode \
             | {:.1} tok/s | ttft p50 {:.1} ms p99 {:.1} ms | e2e p50 {:.1} ms | peak batch {} \
             | peak kv {:.1} KiB ({} pages, {:.0}% util) | pool {} alloc / {} recycled \
             | prefix hits {} ({} pages shared, {} cow forks)",
            self.completed,
            self.rejected,
            self.submitted,
            self.prefill_tokens,
            self.decode_tokens,
            self.throughput_tok_s,
            self.ttft_p50_us / 1e3,
            self.ttft_p99_us / 1e3,
            self.e2e_p50_us / 1e3,
            self.peak_active,
            self.peak_kv_bytes as f64 / 1024.0,
            self.peak_kv_pages,
            100.0 * self.kv_tail_utilization,
            self.kv_pages_allocated,
            self.kv_pages_recycled,
            self.prefix_hits,
            self.shared_kv_pages,
            self.kv_cow_forks,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;

    #[test]
    fn counts_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_prefill_tokens(100);
        m.on_active(3);
        m.on_active(2);
        let r = Response {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            finish: crate::coordinator::request::FinishReason::Done,
            queue_us: 10,
            prefill_us: 90,
            decode_us: 300,
            total_us: 400,
        };
        m.on_complete(&r);
        m.on_kv_bytes(2048);
        m.on_kv_pages(10, 18, 20);
        m.on_kv_pages(4, 4, 8); // below peak: utilization sample kept
        m.on_prefix_hit(64, 12);
        m.on_prefix_hit(64, 12);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.prefill_tokens, 100);
        assert_eq!(s.decode_tokens, 3);
        assert_eq!(s.peak_active, 3);
        assert_eq!(s.peak_kv_bytes, 2048);
        assert_eq!(s.peak_kv_pages, 10);
        assert!((s.kv_tail_utilization - 0.9).abs() < 1e-12);
        assert_eq!(s.prefix_hits, 2);
        assert_eq!(s.shared_prefix_tokens, 128);
        assert_eq!(s.shared_kv_pages, 24);
        assert!(s.ttft_p50_us > 0.0);
        let rendered = s.render();
        assert!(rendered.contains("requests: 1 ok"));
        assert!(rendered.contains("10 pages"), "{rendered}");
        assert!(rendered.contains("recycled"), "{rendered}");
        assert!(rendered.contains("prefix hits 2"), "{rendered}");
    }
}
