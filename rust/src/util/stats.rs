//! Descriptive statistics used by the bench harness, the serving metrics and
//! the fidelity evaluations (cosine similarity, relative L1, RMSE — the
//! metrics of paper Table 9).

/// Arithmetic mean (0.0 for an empty slice).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy; `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Percentile over an already sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Minimum (NaN-free input assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Cosine similarity between two vectors (Table 9 metric).
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return if na == nb { 1.0 } else { 0.0 };
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Relative L1 error: `Σ|a-b| / Σ|a|` (Table 9 metric; `a` is the reference).
pub fn relative_l1(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| (x as f64 - y as f64).abs()).sum();
    let den: f64 = a.iter().map(|&x| (x as f64).abs()).sum();
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    num / den
}

/// Root-mean-square error (Table 9 metric).
pub fn rmse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum();
    (s / a.len() as f64).sqrt()
}

/// Maximum absolute difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 - y as f64).abs())
        .fold(0.0, f64::max)
}

/// Summary of a sample of latencies/values: the row format every bench prints.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_dev(xs),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Streaming histogram with fixed log-spaced buckets, for serving latency
/// metrics where storing every sample would be wasteful.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    /// Bucket upper bounds in microseconds.
    bounds_us: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
    max_us: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// Buckets from 1 µs to ~100 s, ×1.5 per step.
    pub fn new() -> Self {
        let mut bounds = Vec::new();
        let mut b = 1.0f64;
        while b < 100_000_000.0 {
            bounds.push(b);
            b *= 1.5;
        }
        let n = bounds.len();
        LogHistogram { bounds_us: bounds, counts: vec![0; n + 1], total: 0, sum_us: 0.0, max_us: 0.0 }
    }

    pub fn record_us(&mut self, us: f64) {
        let idx = match self
            .bounds_us
            .binary_search_by(|b| b.partial_cmp(&us).unwrap())
        {
            Ok(i) => i,
            Err(i) => i,
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Approximate percentile from bucket boundaries.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    self.max_us
                };
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn cosine_of_identical_is_one() {
        let a = [0.2f32, -1.5, 3.0, 0.0];
        assert!((cosine_similarity(&a, &a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cosine_of_orthogonal_is_zero() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!(cosine_similarity(&a, &b).abs() < 1e-12);
    }

    #[test]
    fn relative_l1_scale() {
        let a = [1.0f32, 1.0, 1.0, 1.0];
        let b = [1.1f32, 0.9, 1.1, 0.9];
        assert!((relative_l1(&a, &b) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn rmse_basics() {
        let a = [0.0f32, 0.0];
        let b = [3.0f32, 4.0];
        assert!((rmse(&a, &b) - (12.5f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn summary_orders_percentiles() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 500.5).abs() < 1.0);
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = LogHistogram::new();
        for i in 1..=10_000u64 {
            h.record_us(i as f64);
        }
        assert_eq!(h.count(), 10_000);
        let p50 = h.percentile_us(50.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p99);
        // Log buckets are coarse (×1.5); allow generous tolerance.
        assert!(p50 > 2_000.0 && p50 < 10_000.0, "p50={p50}");
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record_us(10.0);
        b.record_us(100.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean_us() - 55.0).abs() < 1e-9);
    }
}
