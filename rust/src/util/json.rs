//! Minimal JSON parser + writer.
//!
//! `serde`'s facade crate is absent from the offline cache, so the weight
//! metadata (`artifacts/model_meta.json`), bench reports and serving configs
//! are handled by this small, fully tested implementation. It supports the
//! complete JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null); numbers are held as `f64`, which is lossless for every
//! integer the artifacts contain (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` + `as_usize`, with a descriptive error.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing or non-integer field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field '{key}'"))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes_round_trip() {
        let original = Json::Str("line\nquote\"backslash\\tab\tü✓".into());
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, ]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn error_carries_offset() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert!(e.offset >= 4, "offset={}", e.offset);
    }

    #[test]
    fn writer_round_trips_random_structures() {
        use crate::util::prng::Pcg64;
        let mut rng = Pcg64::seed_from_u64(11);
        fn gen(rng: &mut Pcg64, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.next_u64() & 1 == 0),
                2 => Json::Num((rng.range_i64(-1_000_000, 1_000_000) as f64) / 8.0),
                3 => Json::Str(format!("s{}", rng.next_u32())),
                4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        for _ in 0..200 {
            let v = gen(&mut rng, 3);
            let text = v.to_string();
            assert_eq!(Json::parse(&text).unwrap(), v, "text={text}");
        }
    }

    #[test]
    fn req_accessors() {
        let v = Json::parse(r#"{"n": 4, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 4);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!((v.req_f64("f").unwrap() - 1.5).abs() < 1e-12);
        assert!(v.req_usize("missing").is_err());
        assert!(v.req_usize("f").is_err()); // non-integer
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(7.0).to_string(), "7");
        assert_eq!(Json::Num(7.25).to_string(), "7.25");
    }
}
