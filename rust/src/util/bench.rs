//! Criterion-style micro/macro-benchmark harness (criterion is not in the
//! offline cache). Used by every `cargo bench` target.
//!
//! Design: warmup runs until the clock stabilizes, then an adaptive number
//! of timed iterations bounded by both a target wall-clock budget and a
//! minimum sample count; reports mean/σ/percentiles through
//! [`crate::util::stats::Summary`].

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Harness configuration; tuned for this 1-core host (see DESIGN.md §2).
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Minimum timed samples per benchmark.
    pub min_samples: usize,
    /// Maximum timed samples.
    pub max_samples: usize,
    /// Wall-clock budget per benchmark (warmup excluded).
    pub budget: Duration,
    /// Warmup budget.
    pub warmup: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            min_samples: 3,
            max_samples: 30,
            budget: Duration::from_secs(2),
            warmup: Duration::from_millis(200),
        }
    }
}

impl BenchConfig {
    /// Budget scaled for heavyweight end-to-end cases (long sequence sweeps).
    pub fn heavy() -> Self {
        BenchConfig {
            min_samples: 2,
            max_samples: 8,
            budget: Duration::from_secs(4),
            warmup: Duration::from_millis(100),
        }
    }

    /// Fast config for CI smoke runs (`INTATTN_BENCH_FAST=1`).
    pub fn fast() -> Self {
        BenchConfig {
            min_samples: 1,
            max_samples: 3,
            budget: Duration::from_millis(300),
            warmup: Duration::from_millis(20),
        }
    }

    /// Honor the `INTATTN_BENCH_FAST` toggle (snapshotted once with the
    /// other knobs, [`crate::util::env::knobs`]).
    pub fn from_env(base: Self) -> Self {
        if crate::util::env::knobs().bench_fast {
            Self::fast()
        } else {
            base
        }
    }
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Per-sample wall times in milliseconds.
    pub samples_ms: Vec<f64>,
    pub summary: Summary,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean
    }
}

/// Time `f` under `cfg`, returning a [`Measurement`].
///
/// `f` receives the sample index; its return value is black-boxed to keep
/// the optimizer from eliding the work.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut f: impl FnMut(usize) -> T) -> Measurement {
    // Warmup.
    let w0 = Instant::now();
    let mut warm_iters = 0usize;
    while w0.elapsed() < cfg.warmup && warm_iters < cfg.max_samples {
        black_box(f(usize::MAX));
        warm_iters += 1;
    }

    let mut samples = Vec::with_capacity(cfg.max_samples);
    let t0 = Instant::now();
    for i in 0..cfg.max_samples {
        let s0 = Instant::now();
        black_box(f(i));
        samples.push(s0.elapsed().as_secs_f64() * 1e3);
        if samples.len() >= cfg.min_samples && t0.elapsed() > cfg.budget {
            break;
        }
    }
    let summary = Summary::of(&samples);
    Measurement { name: name.to_string(), samples_ms: samples, summary }
}

/// Identity function the optimizer must assume has side effects.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Table printer for bench binaries: fixed-width, paper-style rows.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_at_least_min_samples() {
        let cfg = BenchConfig { min_samples: 3, max_samples: 10, budget: Duration::ZERO, warmup: Duration::ZERO };
        let m = bench("noop", cfg, |_| 1 + 1);
        assert!(m.samples_ms.len() >= 3);
        assert!(m.summary.mean >= 0.0);
    }

    #[test]
    fn bench_respects_max_samples() {
        let cfg = BenchConfig {
            min_samples: 1,
            max_samples: 5,
            budget: Duration::from_secs(100),
            warmup: Duration::ZERO,
        };
        let m = bench("noop", cfg, |_| ());
        assert!(m.samples_ms.len() <= 5);
    }

    #[test]
    fn bench_times_are_plausible() {
        let cfg = BenchConfig::fast();
        let m = bench("sleep", cfg, |_| std::thread::sleep(Duration::from_millis(3)));
        assert!(m.mean_ms() >= 2.5, "mean={}", m.mean_ms());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["L", "ms"]);
        t.row(vec!["1024".into(), "3.14".into()]);
        t.row(vec!["16384".into(), "200.00".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("16384"));
        let lines: Vec<&str> = s.lines().filter(|l| l.contains("  ")).collect();
        assert!(lines.len() >= 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
