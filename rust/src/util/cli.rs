//! A small command-line argument parser (clap is not in the offline cache).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands; generates usage text from registered options.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list of integers, e.g. `--seq-lens 1024,2048`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad integer '{t}'"))
                })
                .collect(),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Command definition: name, help, options.
pub struct Command {
    pub name: String,
    pub about: String,
    opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command { name: name.to_string(), about: about.to_string(), opts: Vec::new() }
    }

    pub fn opt(mut self, name: &str, help: &str, default: Option<&str>) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(String::from),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    fn usage(&self) -> String {
        let mut s = format!("  {:<18} {}\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("--{}", o.name)
            } else {
                format!("--{} <v>", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("      {:<24} {}{}\n", head, o.help, def));
        }
        s
    }

    /// Parse raw tokens against this command's spec.
    pub fn parse(&self, tokens: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(body) = t.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key} for '{}'", self.name))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("--{key} is a flag and takes no value");
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{key} expects a value"))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

/// Top-level app: a set of subcommands.
pub struct App {
    pub name: String,
    pub about: String,
    commands: Vec<Command>,
}

impl App {
    pub fn new(name: &str, about: &str) -> Self {
        App { name: name.to_string(), about: about.to_string(), commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE: {} <command> [options]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&c.usage());
        }
        s
    }

    /// Dispatch: returns (command name, parsed args) or prints usage.
    pub fn parse(&self, argv: &[String]) -> anyhow::Result<(String, Args)> {
        let Some(cmd_name) = argv.first() else {
            anyhow::bail!("{}", self.usage());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            anyhow::bail!("{}", self.usage());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == *cmd_name)
            .ok_or_else(|| anyhow::anyhow!("unknown command '{cmd_name}'\n\n{}", self.usage()))?;
        let args = cmd.parse(&argv[1..])?;
        Ok((cmd.name.clone(), args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    fn demo_cmd() -> Command {
        Command::new("bench", "run a benchmark")
            .opt("seq-len", "sequence length", Some("1024"))
            .opt("pipeline", "which pipeline", None)
            .flag("verbose", "chatty output")
    }

    #[test]
    fn defaults_apply() {
        let a = demo_cmd().parse(&toks(&[])).unwrap();
        assert_eq!(a.get("seq-len"), Some("1024"));
        assert_eq!(a.get("pipeline"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = demo_cmd()
            .parse(&toks(&["--seq-len", "2048", "--pipeline=int"]))
            .unwrap();
        assert_eq!(a.get("seq-len"), Some("2048"));
        assert_eq!(a.get("pipeline"), Some("int"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = demo_cmd().parse(&toks(&["--verbose", "input.txt"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["input.txt".to_string()]);
    }

    #[test]
    fn typed_getters() {
        let a = demo_cmd().parse(&toks(&["--seq-len", "4096"])).unwrap();
        assert_eq!(a.get_usize("seq-len", 0).unwrap(), 4096);
        assert!(demo_cmd()
            .parse(&toks(&["--seq-len", "abc"]))
            .unwrap()
            .get_usize("seq-len", 0)
            .is_err());
    }

    #[test]
    fn usize_list() {
        let c = Command::new("x", "").opt("ls", "lens", Some("1,2,3"));
        let a = c.parse(&toks(&[])).unwrap();
        assert_eq!(a.get_usize_list("ls", &[]).unwrap(), vec![1, 2, 3]);
        let a = c.parse(&toks(&["--ls", "256, 512"])).unwrap();
        assert_eq!(a.get_usize_list("ls", &[]).unwrap(), vec![256, 512]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(demo_cmd().parse(&toks(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(demo_cmd().parse(&toks(&["--pipeline"])).is_err());
    }

    #[test]
    fn app_dispatch() {
        let app = App::new("intattn", "edge attention engine")
            .command(demo_cmd())
            .command(Command::new("serve", "start the engine"));
        let (name, a) = app
            .parse(&toks(&["bench", "--seq-len", "128"]))
            .unwrap();
        assert_eq!(name, "bench");
        assert_eq!(a.get("seq-len"), Some("128"));
        assert!(app.parse(&toks(&["bogus"])).is_err());
        assert!(app.parse(&toks(&[])).is_err()); // prints usage via error
    }

    #[test]
    fn usage_lists_commands_and_defaults() {
        let app = App::new("intattn", "x").command(demo_cmd());
        let u = app.usage();
        assert!(u.contains("bench"));
        assert!(u.contains("default: 1024"));
    }
}
