//! Substrate utilities.
//!
//! This image's offline crate cache ships neither `rand`, `serde`, `clap`,
//! `tokio`, `criterion` nor `proptest`, so the pieces of those crates this
//! project needs are implemented here from scratch (see DESIGN.md §3,
//! "Offline-cache constraint").

pub mod env;
pub mod fault;
pub mod prng;
pub mod stats;
pub mod timer;
pub mod f16;
pub mod json;
pub mod cli;
pub mod threadpool;
pub mod proptest;
pub mod bench;
pub mod logging;
