//! A miniature property-testing driver (`proptest` is not in the offline
//! cache). Runs a property against many PRNG-generated cases and, on
//! failure, reports the seed so the case reproduces exactly.
//!
//! ```
//! use intattention::util::proptest::{check, Config};
//! check("add is commutative", Config::default(), |rng| {
//!     let a = rng.range_i64(-1000, 1000);
//!     let b = rng.range_i64(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::prng::Pcg64;

/// Driver configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, base_seed: 0xC0FFEE }
    }
}

impl Config {
    pub fn cases(n: usize) -> Self {
        Config { cases: n, ..Default::default() }
    }
}

/// Run `property` against `cfg.cases` seeded PRNGs. Panics (with the failing
/// seed in the message) if any case panics.
pub fn check<F>(name: &str, cfg: Config, property: F)
where
    F: Fn(&mut Pcg64) + std::panic::RefUnwindSafe,
{
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg64::seed_from_u64(seed);
            property(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {i} (reproduce with seed {seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", Config::cases(16), |rng| {
            let x = rng.next_u64();
            assert_eq!(x, x);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", Config::cases(4), |_| {
                panic!("boom");
            });
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("reproduce with seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn cases_use_distinct_seeds() {
        use std::sync::Mutex;
        let seen = Mutex::new(Vec::new());
        check("collect first draws", Config::cases(8), |rng| {
            seen.lock().unwrap().push(rng.next_u64());
        });
        let v = seen.lock().unwrap();
        let mut uniq = v.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), v.len());
    }
}
