//! Leveled stderr logging with a runtime-settable level.
//!
//! Deliberately tiny: the serving engine needs structured progress lines,
//! not a logging framework. Level comes from `INTATTN_LOG`
//! (`error|warn|info|debug|trace`), default `info`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static INIT: std::sync::Once = std::sync::Once::new();

fn ensure_init() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("INTATTN_LOG") {
            set_level(match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            });
        }
    });
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    ensure_init();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Core emit function used by the macros.
pub fn emit(l: Level, module: &str, msg: &str) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_output() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Error);
        log_info!("hidden {}", 1);
        log_error!("visible {}", 2);
        set_level(Level::Info);
    }
}
