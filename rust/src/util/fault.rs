//! Deterministic fault injection for the serving engine's failure paths.
//!
//! Compiled always, inert unless armed: every hook is a relaxed atomic load
//! on the hot path, and nothing fires until [`arm`] installs a [`Plan`].
//! Plans come either from code (the chaos tests arm programmatically) or
//! from the `INTATTN_FAULT` environment knob, read once via
//! [`crate::util::env::knobs`] and armed by [`ensure_env_armed`] on the
//! first engine start.
//!
//! A plan is a comma-separated clause string:
//!
//! | Clause | Effect |
//! |---|---|
//! | `pool_alloc@N` | the `N`-th page acquisition (1-based) panics — a simulated allocation failure |
//! | `panic_prefill@N` | the `N`-th prefill step entry panics, attributed to its request |
//! | `panic_decode@N` | the `N`-th per-sequence decode step entry panics, attributed to its sequence |
//! | `delay_prefill=D` | every prefill step sleeps `D` (`2ms`, `500us`) first |
//! | `delay_decode=D` | every per-sequence decode step sleeps `D` first |
//! | `delay_round=D` | every scheduler round sleeps `D` at its top |
//! | `seed=N` | no direct effect; the chaos property suite uses it as its PRNG base seed |
//!
//! e.g. `pool_alloc@17`, `panic_decode@3,delay_prefill=2ms`, `seed=7`.
//!
//! Injected panics carry an [`Injected`] payload, so the engine's
//! `catch_unwind` wrappers can tell an injected fault (and its victim
//! sequence) from a genuine bug, and panic hooks can silence the expected
//! ones. Ordinals are one-shot by construction: an arrival counter is
//! compared for equality, so each `@N` clause fires exactly once per [`arm`]
//! (arming resets the arrival counters; the fired counters behind [`stats`]
//! are monotone process totals, like the page-pool counters).
//!
//! The injection points live in [`crate::attention::state`] (`PagePool`
//! acquisition) and [`crate::coordinator::engine`] (prefill entry, decode
//! entry, round top) — the places real deployments fail: out of pages,
//! poisoned model step, slow step tripping a deadline.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once};
use std::time::Duration;

/// Where a fault fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// `PagePool` page acquisition.
    PoolAlloc,
    /// Prefill step entry (one per request per round).
    Prefill,
    /// Decode step entry (one per decoding sequence per round).
    Decode,
    /// Scheduler round top.
    Round,
}

/// Panic payload of an injected fault: lets `catch_unwind` attribute the
/// unwind to the sequence whose step was poisoned (`victim`), and lets test
/// panic hooks suppress expected injections without hiding real bugs.
#[derive(Clone, Copy, Debug)]
pub struct Injected {
    pub site: Site,
    /// Request id whose step hosted the fault; `None` when the fault is not
    /// attributable to one sequence (a pool allocation can serve anyone).
    pub victim: Option<u64>,
}

/// A parsed fault plan. `Default` is fully inert.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Plan {
    /// `seed=N` — base seed handed to randomized chaos schedules.
    pub seed: Option<u64>,
    /// `pool_alloc@N` — the N-th page acquisition panics.
    pub pool_alloc_at: Option<u64>,
    /// `panic_prefill@N` — the N-th prefill step entry panics.
    pub panic_prefill_at: Option<u64>,
    /// `panic_decode@N` — the N-th decode step entry panics.
    pub panic_decode_at: Option<u64>,
    /// `delay_prefill=D` — sleep before every prefill step, µs.
    pub delay_prefill_us: Option<u64>,
    /// `delay_decode=D` — sleep before every decode step, µs.
    pub delay_decode_us: Option<u64>,
    /// `delay_round=D` — sleep at the top of every scheduler round, µs.
    pub delay_round_us: Option<u64>,
}

const INERT: Plan = Plan {
    seed: None,
    pool_alloc_at: None,
    panic_prefill_at: None,
    panic_decode_at: None,
    delay_prefill_us: None,
    delay_decode_us: None,
    delay_round_us: None,
};

/// Parse a plan string (see the module docs for the clause grammar). Pure:
/// no global effect. Errors name the offending clause.
pub fn parse_plan(s: &str) -> Result<Plan, String> {
    let mut plan = Plan::default();
    for clause in s.split(',') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        if let Some((site, n)) = clause.split_once('@') {
            let n: u64 = n
                .trim()
                .parse()
                .map_err(|_| format!("fault clause `{clause}`: ordinal must be an integer"))?;
            if n == 0 {
                return Err(format!("fault clause `{clause}`: ordinals are 1-based"));
            }
            match site.trim() {
                "pool_alloc" => plan.pool_alloc_at = Some(n),
                "panic_prefill" => plan.panic_prefill_at = Some(n),
                "panic_decode" => plan.panic_decode_at = Some(n),
                other => return Err(format!("fault clause `{clause}`: unknown site `{other}`")),
            }
        } else if let Some((key, val)) = clause.split_once('=') {
            let (key, val) = (key.trim(), val.trim());
            match key {
                "seed" => {
                    plan.seed = Some(val.parse().map_err(|_| {
                        format!("fault clause `{clause}`: seed must be an integer")
                    })?);
                }
                "delay_prefill" => plan.delay_prefill_us = Some(parse_duration_us(clause, val)?),
                "delay_decode" => plan.delay_decode_us = Some(parse_duration_us(clause, val)?),
                "delay_round" => plan.delay_round_us = Some(parse_duration_us(clause, val)?),
                other => return Err(format!("fault clause `{clause}`: unknown key `{other}`")),
            }
        } else {
            return Err(format!(
                "fault clause `{clause}`: expected `site@ordinal` or `key=value`"
            ));
        }
    }
    Ok(plan)
}

/// `2ms` / `500us` → microseconds. A bare number is rejected: a unitless
/// delay silently read as the wrong scale is exactly the kind of config bug
/// a fault harness must not have.
fn parse_duration_us(clause: &str, val: &str) -> Result<u64, String> {
    let (digits, scale) = if let Some(d) = val.strip_suffix("ms") {
        (d, 1000)
    } else if let Some(d) = val.strip_suffix("us") {
        (d, 1)
    } else {
        return Err(format!("fault clause `{clause}`: duration needs a `ms` or `us` suffix"));
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| format!("fault clause `{clause}`: duration must be an integer"))?;
    Ok(n * scale)
}

/// Monotone injection totals since process start (mirrors the page-pool
/// counter style; surfaced in the engine's metrics snapshot).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Injected step panics that fired (prefill + decode sites).
    pub injected_panics: u64,
    /// Injected page-acquisition failures that fired.
    pub failed_allocs: u64,
    /// Injected delays slept (one per delayed step/round).
    pub injected_delays: u64,
}

/// The whole injection state, instantiable so unit tests exercise firing
/// semantics on a private instance without racing the process-global one.
struct State {
    armed: AtomicBool,
    plan: Mutex<Plan>,
    pool_seen: AtomicU64,
    prefill_seen: AtomicU64,
    decode_seen: AtomicU64,
    injected_panics: AtomicU64,
    failed_allocs: AtomicU64,
    injected_delays: AtomicU64,
}

impl State {
    const fn new() -> Self {
        State {
            armed: AtomicBool::new(false),
            plan: Mutex::new(INERT),
            pool_seen: AtomicU64::new(0),
            prefill_seen: AtomicU64::new(0),
            decode_seen: AtomicU64::new(0),
            injected_panics: AtomicU64::new(0),
            failed_allocs: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
        }
    }

    fn arm(&self, plan: Plan) {
        let mut p = self.plan.lock().unwrap_or_else(|e| e.into_inner());
        *p = plan;
        // Fresh arrival counters: `@N` ordinals count from this arming.
        self.pool_seen.store(0, Ordering::SeqCst);
        self.prefill_seen.store(0, Ordering::SeqCst);
        self.decode_seen.store(0, Ordering::SeqCst);
        self.armed.store(plan != INERT, Ordering::SeqCst);
    }

    fn disarm(&self) {
        self.arm(INERT);
    }

    fn plan(&self) -> Plan {
        *self.plan.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn stats(&self) -> FaultStats {
        FaultStats {
            injected_panics: self.injected_panics.load(Ordering::Relaxed),
            failed_allocs: self.failed_allocs.load(Ordering::Relaxed),
            injected_delays: self.injected_delays.load(Ordering::Relaxed),
        }
    }

    fn delay(&self, us: Option<u64>) {
        if let Some(us) = us {
            self.injected_delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_micros(us));
        }
    }

    fn on_pool_alloc(&self) {
        if !self.armed.load(Ordering::Relaxed) {
            return;
        }
        let plan = self.plan();
        let arrival = self.pool_seen.fetch_add(1, Ordering::SeqCst) + 1;
        if plan.pool_alloc_at == Some(arrival) {
            self.failed_allocs.fetch_add(1, Ordering::Relaxed);
            std::panic::panic_any(Injected { site: Site::PoolAlloc, victim: None });
        }
    }

    fn on_prefill_step(&self, victim: u64) {
        if !self.armed.load(Ordering::Relaxed) {
            return;
        }
        let plan = self.plan();
        self.delay(plan.delay_prefill_us);
        let arrival = self.prefill_seen.fetch_add(1, Ordering::SeqCst) + 1;
        if plan.panic_prefill_at == Some(arrival) {
            self.injected_panics.fetch_add(1, Ordering::Relaxed);
            std::panic::panic_any(Injected { site: Site::Prefill, victim: Some(victim) });
        }
    }

    fn on_decode_step(&self, victim: u64) {
        if !self.armed.load(Ordering::Relaxed) {
            return;
        }
        let plan = self.plan();
        self.delay(plan.delay_decode_us);
        let arrival = self.decode_seen.fetch_add(1, Ordering::SeqCst) + 1;
        if plan.panic_decode_at == Some(arrival) {
            self.injected_panics.fetch_add(1, Ordering::Relaxed);
            std::panic::panic_any(Injected { site: Site::Decode, victim: Some(victim) });
        }
    }

    fn on_round(&self) {
        if !self.armed.load(Ordering::Relaxed) {
            return;
        }
        self.delay(self.plan().delay_round_us);
    }
}

static GLOBAL: State = State::new();

/// Arm the process-global plan (resets arrival counters). An inert plan
/// leaves the hooks on their no-op fast path.
pub fn arm(plan: Plan) {
    GLOBAL.arm(plan);
}

/// Parse and arm in one step.
pub fn arm_str(s: &str) -> Result<(), String> {
    parse_plan(s).map(arm)
}

/// Return every hook to its inert fast path.
pub fn disarm() {
    GLOBAL.disarm();
}

/// The currently armed plan (inert when disarmed).
pub fn plan() -> Plan {
    GLOBAL.plan()
}

/// Monotone process-wide injection totals.
pub fn stats() -> FaultStats {
    GLOBAL.stats()
}

/// The `seed=N` clause of the environment plan, if any — the chaos property
/// suite's base seed, so a CI failure names a seed that reproduces locally.
pub fn env_seed() -> Option<u64> {
    crate::util::env::knobs().fault.and_then(|s| parse_plan(s).ok()).and_then(|p| p.seed)
}

/// Arm the `INTATTN_FAULT` environment plan, once per process. Called on
/// engine start; a later explicit [`arm`]/[`disarm`] overrides it (the test
/// harness forces this `Once` first, then arms its own scenario plans). A
/// malformed plan must not be silently inert: it aborts engine start.
pub fn ensure_env_armed() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        if let Some(s) = crate::util::env::knobs().fault {
            arm_str(s).unwrap_or_else(|e| panic!("bad fault plan in environment: {e}"));
        }
    });
}

/// Injection point: `PagePool` page acquisition (before any counter moves,
/// so an injected failure never skews the pool's outstanding accounting).
#[inline]
pub fn on_pool_alloc() {
    GLOBAL.on_pool_alloc();
}

/// Injection point: prefill step entry for request `victim`.
#[inline]
pub fn on_prefill_step(victim: u64) {
    GLOBAL.on_prefill_step(victim);
}

/// Injection point: decode step entry for sequence `victim`.
#[inline]
pub fn on_decode_step(victim: u64) {
    GLOBAL.on_decode_step(victim);
}

/// Injection point: scheduler round top (delays only).
#[inline]
pub fn on_round() {
    GLOBAL.on_round();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_clause_grammar() {
        let p = parse_plan("pool_alloc@17, panic_decode@3 ,delay_prefill=2ms,seed=9").unwrap();
        assert_eq!(p.pool_alloc_at, Some(17));
        assert_eq!(p.panic_decode_at, Some(3));
        assert_eq!(p.delay_prefill_us, Some(2000));
        assert_eq!(p.seed, Some(9));
        assert_eq!(p.panic_prefill_at, None);
        let p = parse_plan("panic_prefill@1,delay_decode=500us,delay_round=1ms").unwrap();
        assert_eq!(p.panic_prefill_at, Some(1));
        assert_eq!(p.delay_decode_us, Some(500));
        assert_eq!(p.delay_round_us, Some(1000));
        assert_eq!(parse_plan("").unwrap(), Plan::default());
        assert_eq!(parse_plan(" , ").unwrap(), Plan::default());
    }

    #[test]
    fn rejects_malformed_plans() {
        for bad in [
            "pool_alloc@0",     // ordinals are 1-based
            "pool_alloc@x",     // non-integer ordinal
            "panic_gemm@1",     // unknown site
            "delay_prefill=2",  // unitless duration
            "delay_prefill=2s", // unknown unit
            "seed=abc",         // non-integer seed
            "frobnicate=1",     // unknown key
            "pool_alloc",       // no shape at all
        ] {
            let err = parse_plan(bad).unwrap_err();
            assert!(err.contains("fault clause"), "{bad}: {err}");
        }
    }

    /// Firing semantics on a private instance — no interference with (or
    /// from) concurrently running tests that drive the global hooks.
    #[test]
    fn ordinal_faults_fire_exactly_once_at_their_arrival() {
        let st = State::new();
        st.arm(parse_plan("panic_decode@3").unwrap());
        st.on_decode_step(7);
        st.on_decode_step(8);
        let hit = std::panic::catch_unwind(|| st.on_decode_step(9));
        let payload = hit.unwrap_err();
        let inj = payload.downcast_ref::<Injected>().expect("typed payload");
        assert_eq!(inj.site, Site::Decode);
        assert_eq!(inj.victim, Some(9));
        // One-shot: later arrivals pass untouched.
        st.on_decode_step(10);
        assert_eq!(st.stats().injected_panics, 1);
        // Other sites unaffected.
        st.on_pool_alloc();
        st.on_prefill_step(1);
        assert_eq!(st.stats().failed_allocs, 0);
    }

    #[test]
    fn rearming_resets_arrival_counters() {
        let st = State::new();
        st.arm(parse_plan("pool_alloc@2").unwrap());
        st.on_pool_alloc();
        assert!(std::panic::catch_unwind(|| st.on_pool_alloc()).is_err());
        st.arm(parse_plan("pool_alloc@2").unwrap());
        st.on_pool_alloc(); // arrival 1 of the new arming: no fire
        assert!(std::panic::catch_unwind(|| st.on_pool_alloc()).is_err());
        assert_eq!(st.stats().failed_allocs, 2);
    }

    #[test]
    fn disarmed_state_is_inert_and_delays_count() {
        let st = State::new();
        st.arm(parse_plan("delay_decode=1us").unwrap());
        st.on_decode_step(1);
        st.on_decode_step(2);
        assert_eq!(st.stats().injected_delays, 2);
        st.disarm();
        assert!(!st.armed.load(Ordering::SeqCst));
        st.on_decode_step(3);
        st.on_pool_alloc();
        st.on_prefill_step(4);
        st.on_round();
        assert_eq!(st.stats().injected_delays, 2, "disarmed hooks are no-ops");
    }

    #[test]
    fn seed_only_plan_is_armed_but_harmless() {
        let st = State::new();
        st.arm(parse_plan("seed=42").unwrap());
        // Armed (the plan is not inert) but every hook passes through.
        st.on_pool_alloc();
        st.on_prefill_step(1);
        st.on_decode_step(1);
        st.on_round();
        assert_eq!(st.stats(), FaultStats::default());
    }
}
