//! Deterministic pseudo-random number generation.
//!
//! `rand` is not in the offline cache; we implement PCG64 (O'Neill 2014,
//! `pcg_xsl_rr_128_64` variant) seeded through SplitMix64, which is more than
//! adequate for workload generation and property tests, and — crucially for
//! reproducing paper tables — fully deterministic across runs.

/// SplitMix64: used to expand a `u64` seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG64 generator (128-bit state, 64-bit output, XSL-RR output function).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Construct from a 64-bit seed (expanded via SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm) as u128;
        let s1 = splitmix64(&mut sm) as u128;
        let i0 = splitmix64(&mut sm) as u128;
        let i1 = splitmix64(&mut sm) as u128;
        let mut rng = Pcg64 {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1, // increment must be odd
        };
        // Advance once so seeds 0/1 do not emit near-identical first draws.
        rng.next_u64();
        rng
    }

    #[inline]
    fn step(&mut self) -> u128 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        old
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let old = self.step();
        let xored = ((old >> 64) as u64) ^ (old as u64);
        let rot = (old >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)` with 53 bits of randomness.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift (no modulo bias
    /// for the ranges used here).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generation is not on any hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-12 {
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (used for Poisson request arrivals).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len() as u64) as usize;
        }
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w.max(0.0) as f64;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of standard-normal draws.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

/// Zipf-distributed sampler over `{0, .., n-1}` with exponent `s`; used for
/// request-trace generation in the serving harness.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::seed_from_u64(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from_u64(9);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Pcg64::seed_from_u64(3);
        for _ in 0..10_000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn below_hits_all_buckets() {
        let mut rng = Pcg64::seed_from_u64(4);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[rng.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_prefers_heavy_weight() {
        let mut rng = Pcg64::seed_from_u64(5);
        let w = [0.05f32, 0.9, 0.05];
        let hits = (0..2_000).filter(|_| rng.categorical(&w) == 1).count();
        assert!(hits > 1_500, "hits={hits}");
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let mut rng = Pcg64::seed_from_u64(6);
        let z = Zipf::new(16, 1.1);
        let mut counts = [0usize; 16];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[1] > counts[8]);
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::seed_from_u64(8);
        let lam = 4.0;
        let n = 40_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(lam)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lam).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(10);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
