//! A small fixed-size thread pool (tokio/rayon are not in the offline cache).
//!
//! Two entry points:
//!
//! * [`ThreadPool::execute`] — fire-and-forget jobs for the serving engine
//!   (the coordinator's worker threads).
//! * [`ThreadPool::scope_chunks`] — data-parallel row partitioning for the
//!   GEMM / softmax hot paths: splits `0..n` into contiguous chunks and runs
//!   a closure per chunk, blocking until all complete.
//!
//! On this 1-core benchmark host the pool degenerates gracefully: with
//! `workers == 1` `scope_chunks` runs inline with zero dispatch overhead,
//! which keeps single-thread bench numbers honest.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed pool of worker threads.
pub struct ThreadPool {
    workers: Vec<JoinHandle<()>>,
    sender: mpsc::Sender<Message>,
    /// Receiver shared by workers behind a mutex (simple MPMC).
    _receiver: Arc<Mutex<mpsc::Receiver<Message>>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    size: usize,
}

impl ThreadPool {
    /// `n == 0` is clamped to 1.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            let handle = std::thread::Builder::new()
                .name(format!("intattn-worker-{i}"))
                .spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job)) => {
                            job();
                            let (lock, cv) = &*pending;
                            let mut p = lock.lock().unwrap();
                            *p -= 1;
                            if *p == 0 {
                                cv.notify_all();
                            }
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
                .expect("spawn worker");
            workers.push(handle);
        }
        ThreadPool { workers, sender: tx, _receiver: rx, pending, size: n }
    }

    /// Pool sized from `INTATTN_THREADS` env var, defaulting to the number of
    /// available CPUs.
    pub fn default_pool() -> Self {
        Self::new(default_threads())
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.sender.send(Message::Run(Box::new(job))).expect("pool alive");
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Run `f(chunk_start, chunk_end)` over a partition of `0..n` into at
    /// most `self.size` contiguous chunks, blocking until all finish.
    ///
    /// The closure only borrows — no `'static` bound — via a scoped trick:
    /// with 1 worker it runs inline; otherwise it uses `std::thread::scope`,
    /// bypassing the queue entirely (cheaper and borrow-friendly).
    pub fn scope_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        scope_chunks_with(self.size, n, f)
    }
}

/// Free-function version of [`ThreadPool::scope_chunks`], usable without
/// constructing a pool (it spawns scoped threads per call; the GEMM driver
/// amortizes this by chunking coarsely).
pub fn scope_chunks_with<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        if n > 0 {
            f(0, n);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(start, end));
        }
    });
}

/// Number of worker threads to use: `INTATTN_THREADS` env override, else
/// available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("INTATTN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A simple atomic work counter used by tests and the scheduler.
#[derive(Default)]
pub struct Counter(AtomicUsize);

impl Counter {
    pub fn incr(&self) -> usize {
        self.0.fetch_add(1, Ordering::SeqCst) + 1
    }
    pub fn get(&self) -> usize {
        self.0.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(Counter::default());
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.incr();
            });
        }
        pool.wait_idle();
        assert_eq!(counter.get(), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn scope_chunks_covers_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        scope_chunks_with(7, 1000, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scope_chunks_single_thread_inline() {
        let mut touched = vec![false; 10];
        let cell = std::sync::Mutex::new(&mut touched);
        scope_chunks_with(1, 10, |s, e| {
            let mut t = cell.lock().unwrap();
            for i in s..e {
                t[i] = true;
            }
        });
        assert!(touched.iter().all(|&t| t));
    }

    #[test]
    fn scope_chunks_zero_n_is_noop() {
        scope_chunks_with(4, 0, |_, _| panic!("must not run"));
    }

    #[test]
    fn more_threads_than_items() {
        let hits: Vec<AtomicU64> = (0..3).map(|_| AtomicU64::new(0)).collect();
        scope_chunks_with(16, 3, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(Counter::default());
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.incr();
            });
        }
        pool.wait_idle();
        drop(pool); // must not deadlock
        assert_eq!(c.get(), 10);
    }

    #[test]
    fn default_threads_env_override() {
        std::env::set_var("INTATTN_THREADS", "3");
        assert_eq!(default_threads(), 3);
        std::env::remove_var("INTATTN_THREADS");
        assert!(default_threads() >= 1);
    }
}
