//! Parallel runtime (tokio/rayon are not in the offline cache).
//!
//! Two executors live here:
//!
//! * [`ParallelPool`] — the **persistent-worker parallel runtime** behind
//!   every GEMM driver and the grouped decode path. Workers are spawned once
//!   and park on a condvar when idle; a `parallel_for`/`parallel_groups`
//!   launch publishes a per-launch descriptor (atomic chunk cursor +
//!   completion latch) that the caller *and* the workers drain together.
//!   Dispatching onto parked workers costs ~0.5–2 µs per launch — one to
//!   two orders of magnitude below the ~10–30 µs of spawning OS threads per
//!   launch (`std::thread::scope`), which is what the pre-persistent design
//!   paid and why its `PAR_GRAIN_*` guards had to keep every small-or-medium
//!   decode launch single-threaded. The ratio is measured by the
//!   launch-overhead microbench in `benches/decode_throughput.rs`.
//!
//!   Launch model:
//!   - **Dynamic chunking.** Work items (output rows, or whole decode
//!     groups) are claimed through an atomic cursor, so ragged grouped
//!     launches (per-sequence context lengths `L_b`) load-balance instead
//!     of relying on a static strided assignment.
//!   - **Grain policy.** One pool-owned threshold replaces the old
//!     per-dtype `PAR_GRAIN_*` constants: a launch gets one worker per
//!     [`ParallelPool::grain`] units of work (callers pass MAC-proportional
//!     work estimates), capped at the pool size. Default
//!     [`DEFAULT_GRAIN`] = 2^14 — re-derived from the ~µs dispatch cost the
//!     same way the old 2^16–2^20 constants were derived from the ~10–30 µs
//!     spawn cost. Override with `INTATTN_PAR_GRAIN` (units per worker).
//!   - **Determinism.** Chunk boundaries and worker count never affect
//!     results: every work item writes a disjoint output range and its
//!     value does not depend on which worker computes it or in what order.
//!     `tests/decode_equivalence.rs` asserts bit-identity at pool sizes
//!     1/2/8.
//!   - **Panic safety.** A panicking chunk is caught on the worker, the
//!     completion latch is still released (via a drop guard), and the
//!     launch call re-panics on the calling thread. Workers survive.
//!   - **Nested launches** run inline on the calling worker (safe
//!     fallback) instead of deadlocking the pool.
//!
//!   The process-wide pool ([`ParallelPool::global`]) is sized from
//!   `INTATTN_THREADS` (else available parallelism), snapshotted **once**
//!   at first use; [`ParallelPool::sized`] returns cached fixed-size pools
//!   for benches that compare 1-thread vs N-thread configurations. With
//!   size 1 every launch runs inline with zero dispatch overhead, which
//!   keeps single-thread bench numbers honest.
//!
//! * [`ThreadPool`] — the original small fixed pool with fire-and-forget
//!   [`ThreadPool::execute`] jobs. Kept as a utility API (nothing on the
//!   serving path currently submits through it — the engine runs a single
//!   scheduler thread and all kernel parallelism goes through
//!   [`ParallelPool`]). A panicking job is caught, counted
//!   ([`ThreadPool::panic_count`]) and its `pending` slot released through
//!   a drop guard, so [`ThreadPool::wait_idle`] can no longer deadlock on
//!   a panicked job.
//!
//! [`scope_chunks_with`] (spawn-per-launch via `std::thread::scope`) is kept
//! only as the baseline the launch-overhead microbench compares against; no
//! hot path uses it anymore.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

// ---------------------------------------------------------------------------
// ParallelPool — persistent-worker data-parallel runtime

/// Default work units (MAC-proportional, see the module docs) per worker
/// before a launch is handed an additional one. Re-derived for the ~µs
/// persistent-dispatch cost; the spawn-per-launch design needed 2^16–2^20.
pub const DEFAULT_GRAIN: usize = 1 << 14;

/// One in-flight launch: an atomic cursor over `n_chunks` chunks of the
/// caller's range, a completion latch, and a lifetime-erased pointer to the
/// caller's closure. The pointer is only dereferenced for chunks claimed
/// while the caller is still blocked in the launch call (the latch releases
/// strictly after the last chunk finishes), so the borrow never escapes.
struct Launch {
    /// Next chunk index to claim (monotone; claims past `n_chunks` are
    /// no-ops).
    cursor: AtomicUsize,
    n_chunks: usize,
    /// Work items per chunk.
    chunk: usize,
    /// Total work items (`0..n`).
    n: usize,
    /// Type-erased `&closure` of the launching call.
    func_data: *const (),
    /// Monomorphized trampoline that calls `*func_data` on a range.
    func_call: unsafe fn(*const (), usize, usize),
    /// Chunks not yet *completed* (claimed-and-finished); the launch call
    /// returns only when this reaches zero.
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

// SAFETY: `func_data` points at a `Sync` closure that outlives the launch
// (the caller blocks until `remaining == 0`), and every other field is
// inherently thread-safe.
unsafe impl Send for Launch {}
unsafe impl Sync for Launch {}

/// Monomorphized trampoline stored in [`Launch::func_call`].
///
/// # Safety
///
/// `data` must be the type-erased `&F` of a live launch closure — i.e. the
/// launching call must still be blocked on the completion latch, and `F`
/// must be the same type this instantiation was monomorphized for.
unsafe fn call_range<F: Fn(usize, usize) + Sync>(data: *const (), start: usize, end: usize) {
    // SAFETY: per this fn's contract, `data` is the erased `&F` of a launch
    // whose caller is still blocked, so the closure is alive and `Sync`.
    let f = unsafe { &*(data as *const F) };
    f(start, end)
}

/// Release one completion slot even if the chunk body panics.
struct CompletionGuard<'a>(&'a Launch);

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        let mut rem = self.0.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.0.done.notify_all();
        }
    }
}

/// Claim and execute chunks of `launch` until its cursor is exhausted.
fn run_chunks(launch: &Launch) {
    loop {
        let c = launch.cursor.fetch_add(1, Ordering::Relaxed);
        if c >= launch.n_chunks {
            break;
        }
        let start = c * launch.chunk;
        let end = ((c + 1) * launch.chunk).min(launch.n);
        let _guard = CompletionGuard(launch);
        // SAFETY: the caller of the launch is still blocked (this chunk has
        // not completed), so the closure behind `func_data` is alive.
        let body = || unsafe { (launch.func_call)(launch.func_data, start, end) };
        if catch_unwind(AssertUnwindSafe(body)).is_err() {
            launch.panicked.store(true, Ordering::SeqCst);
        }
    }
}

struct PoolShared {
    /// Launches with unclaimed chunks, oldest first. Workers help the front
    /// launch; exhausted entries are dropped lazily by workers and
    /// explicitly by the launching caller on completion.
    queue: Mutex<VecDeque<Arc<Launch>>>,
    work: Condvar,
    shutdown: AtomicBool,
}

thread_local! {
    /// Set while this thread is executing launch chunks: a nested launch
    /// from inside a chunk body runs inline instead of deadlocking on the
    /// pool it is itself a worker of.
    static IN_LAUNCH: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let launch = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                while q
                    .front()
                    .is_some_and(|l| l.cursor.load(Ordering::Relaxed) >= l.n_chunks)
                {
                    q.pop_front();
                }
                if let Some(l) = q.front() {
                    break Arc::clone(l);
                }
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        IN_LAUNCH.with(|f| f.set(true));
        run_chunks(&launch);
        IN_LAUNCH.with(|f| f.set(false));
    }
}

/// Persistent-worker parallel runtime; see the module docs for the launch
/// model. Cheap to share (`&ParallelPool` is all the kernels take); the
/// process-wide instance is [`ParallelPool::global`].
pub struct ParallelPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
    /// Workers participating in a launch, **including** the calling thread
    /// (so `size` threads compute and only `size − 1` are pool-owned).
    size: usize,
    /// Work units per worker (the launch grain policy, module docs).
    grain: usize,
}

impl std::fmt::Debug for ParallelPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParallelPool")
            .field("size", &self.size)
            .field("grain", &self.grain)
            .finish()
    }
}

impl ParallelPool {
    /// Pool with `threads` computing threads (clamped to ≥ 1) and the
    /// default grain (env-overridable via `INTATTN_PAR_GRAIN`, snapshotted
    /// once with the other knobs in [`crate::util::env::knobs`]).
    pub fn new(threads: usize) -> Self {
        Self::with_grain(threads, crate::util::env::knobs().par_grain)
    }

    /// Pool with an explicit grain (tests use `grain == 1` to force real
    /// multi-worker dispatch on tiny launches).
    pub fn with_grain(threads: usize, grain: usize) -> Self {
        let size = threads.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        // The launching thread is participant #1; spawn the other size−1.
        let workers = (1..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("intattn-par-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        ParallelPool { shared, workers, size, grain: grain.max(1) }
    }

    /// The process-wide pool every serving-path component shares. Sized from
    /// `INTATTN_THREADS` (else available parallelism), **snapshotted once**
    /// on first use — later env mutations do not resize it.
    pub fn global() -> &'static ParallelPool {
        Self::sized(crate::util::env::knobs().threads)
    }

    /// A cached `'static` pool of exactly `n` computing threads (created and
    /// leaked on first request). Benches use this to pin 1-thread vs
    /// N-thread configurations; repeated calls reuse the same pool, so the
    /// process never accumulates more than one pool per distinct size.
    pub fn sized(n: usize) -> &'static ParallelPool {
        static REGISTRY: OnceLock<Mutex<Vec<(usize, &'static ParallelPool)>>> = OnceLock::new();
        let n = n.max(1);
        let reg = REGISTRY.get_or_init(|| Mutex::new(Vec::new()));
        let mut v = reg.lock().unwrap();
        if let Some(&(_, p)) = v.iter().find(|(s, _)| *s == n) {
            return p;
        }
        let p: &'static ParallelPool = Box::leak(Box::new(ParallelPool::new(n)));
        v.push((n, p));
        p
    }

    /// Leak this pool into a `'static` handle (tests that need non-default
    /// grains in `AttentionConfig`, which stores a `'static` pool).
    pub fn leak(self) -> &'static ParallelPool {
        Box::leak(Box::new(self))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn grain(&self) -> usize {
        self.grain
    }

    /// Workers the grain policy grants a launch of `work` units: one per
    /// `grain`, capped at the pool size. Never zero.
    pub fn workers_for(&self, work: usize) -> usize {
        self.size.min((work / self.grain).saturating_add(1))
    }

    /// Run `f(start, end)` over a partition of `0..n`, using up to
    /// `workers_for(work)` threads with dynamically claimed chunks. Blocks
    /// until every chunk completed; re-panics if any chunk panicked.
    ///
    /// `work` is the launch's total cost in grain units (MAC-proportional
    /// for the GEMM drivers); pass `usize::MAX` to request full width.
    pub fn parallel_for<F>(&self, n: usize, work: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        let workers = self.workers_for(work);
        // ~4 chunks per worker: dynamic balancing without per-item claims.
        let chunk = n.div_ceil((4 * workers).max(1)).max(1);
        self.dispatch(n, workers, chunk, f);
    }

    /// Run `f` once for each group, up to `workers_for(work)` threads
    /// claiming **one group at a time** through the atomic cursor — the
    /// fully dynamic schedule ragged decode batches need (a group's cost is
    /// its context length; static assignment would let one worker inherit
    /// all the long sequences).
    pub fn parallel_groups<G, F>(&self, groups: &mut [G], work: usize, f: F)
    where
        G: Send,
        F: Fn(&mut G) + Sync,
    {
        let n = groups.len();
        let workers = self.workers_for(work).min(n.max(1));
        if workers <= 1 || n <= 1 {
            for g in groups.iter_mut() {
                f(g);
            }
            return;
        }
        let ptr = SendPtr(groups.as_mut_ptr());
        self.dispatch(n, workers, 1, |i0, i1| {
            for i in i0..i1 {
                // SAFETY: each index is claimed exactly once (atomic
                // cursor), so the &mut is exclusive; G: Send moves the
                // group's data across the worker boundary.
                let g = unsafe { &mut *ptr.get().add(i) };
                f(g);
            }
        });
    }

    /// Core launch: publish a descriptor, help execute it, wait on the
    /// completion latch, propagate panics.
    fn dispatch<F>(&self, n: usize, workers: usize, chunk: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        let workers = workers.max(1).min(n.max(1)).min(self.size);
        if workers <= 1 || n <= 1 || IN_LAUNCH.with(|fl| fl.get()) {
            // Inline: single-worker launches, trivial ranges, and nested
            // launches from inside a chunk body (safe fallback).
            if n > 0 {
                f(0, n);
            }
            return;
        }
        let n_chunks = n.div_ceil(chunk);
        let launch = Arc::new(Launch {
            cursor: AtomicUsize::new(0),
            n_chunks,
            chunk,
            n,
            func_data: &f as *const F as *const (),
            func_call: call_range::<F>,
            remaining: Mutex::new(n_chunks),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Arc::clone(&launch));
        }
        if workers >= self.size {
            self.shared.work.notify_all();
        } else {
            for _ in 1..workers {
                self.shared.work.notify_one();
            }
        }
        // The caller is a full participant — a launch completes even if
        // every pool worker is busy with someone else's launch.
        IN_LAUNCH.with(|fl| fl.set(true));
        run_chunks(&launch);
        IN_LAUNCH.with(|fl| fl.set(false));
        let mut rem = launch.remaining.lock().unwrap();
        while *rem > 0 {
            rem = launch.done.wait(rem).unwrap();
        }
        drop(rem);
        // Drop our queue entry eagerly (workers also skip exhausted fronts
        // lazily, but an idle pool must not pin finished descriptors).
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.retain(|l| !Arc::ptr_eq(l, &launch));
        }
        if launch.panicked.load(Ordering::SeqCst) {
            panic!("ParallelPool launch panicked in a worker chunk");
        }
    }
}

impl Drop for ParallelPool {
    fn drop(&mut self) {
        // Store shutdown while holding the queue mutex: a worker checks the
        // flag only under that mutex, so it either observes `true` and
        // exits, or is already parked in `wait` when the notify below fires.
        // Storing without the lock could race a worker between its check
        // and its `wait`, losing the wakeup and deadlocking the joins.
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Send+Sync raw-pointer wrapper for handing disjoint &mut regions to
/// workers. Sound only while every index/range dereferenced through it is
/// claimed by exactly one worker (the atomic-cursor / disjoint-row-chunk
/// contract); shared with the GEMM drivers, which uphold the same contract.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
// SAFETY: sending the wrapper moves `T` values (behind disjoint `&mut T`
// reconstructions) to another thread, so `T` itself must be sendable. The
// unbounded `impl<T>` the pool originally shipped would have let a caller
// smuggle an `Rc` (or other !Send state) into workers; the bound makes that
// a compile error instead of UB.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: `&SendPtr<T>` is shared across workers so each can reconstruct an
// exclusive `&mut T` over its *own* claimed indices — sharing the wrapper
// distributes `&mut T` (not `&T`) access, hence the bound is `T: Send`, the
// same requirement `std` places on `&mut T: Send`.
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Accessor (rather than field access) so closures capture the whole
    /// `Sync` wrapper, not the raw pointer (edition-2021 disjoint capture).
    #[inline]
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

// ---------------------------------------------------------------------------
// ThreadPool — fire-and-forget job pool (utility; not on the serving path)

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed pool of worker threads for fire-and-forget jobs.
pub struct ThreadPool {
    workers: Vec<JoinHandle<()>>,
    sender: mpsc::Sender<Message>,
    /// Receiver shared by workers behind a mutex (simple MPMC).
    _receiver: Arc<Mutex<mpsc::Receiver<Message>>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    panics: Arc<AtomicUsize>,
    size: usize,
}

/// Decrements the pending counter when dropped — a panicking job releases
/// its slot exactly like a finishing one, so `wait_idle` cannot deadlock.
struct PendingGuard<'a>(&'a (Mutex<usize>, Condvar));

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        let (lock, cv) = self.0;
        let mut p = lock.lock().unwrap();
        *p -= 1;
        if *p == 0 {
            cv.notify_all();
        }
    }
}

impl ThreadPool {
    /// `n == 0` is clamped to 1.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let panics = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            let panics = Arc::clone(&panics);
            let handle = std::thread::Builder::new()
                .name(format!("intattn-worker-{i}"))
                .spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job)) => {
                            let _guard = PendingGuard(&pending);
                            if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                panics.fetch_add(1, Ordering::SeqCst);
                                eprintln!("[threadpool] job panicked (worker survives)");
                            }
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
                .expect("spawn worker");
            workers.push(handle);
        }
        ThreadPool { workers, sender: tx, _receiver: rx, pending, panics, size: n }
    }

    /// Pool sized from `INTATTN_THREADS` env var, defaulting to the number of
    /// available CPUs.
    pub fn default_pool() -> Self {
        Self::new(default_threads())
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job. A job that panics is caught on the
    /// worker (which survives) and counted in [`Self::panic_count`].
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.sender.send(Message::Run(Box::new(job))).expect("pool alive");
    }

    /// Block until all submitted jobs have completed (or panicked — check
    /// [`Self::panic_count`] afterwards if job failures matter).
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Number of jobs that panicked since the pool was created.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Run `f(chunk_start, chunk_end)` over a partition of `0..n` into at
    /// most `self.size` contiguous chunks, blocking until all finish.
    /// Legacy spawn-per-launch path; hot paths use [`ParallelPool`].
    pub fn scope_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        scope_chunks_with(self.size, n, f)
    }
}

/// Spawn-per-launch data parallelism over `std::thread::scope`: splits
/// `0..n` into at most `threads` contiguous chunks, spawning an OS thread
/// per chunk (~10–30 µs each). Kept **only** as the baseline the
/// launch-overhead microbench compares [`ParallelPool`] dispatch against;
/// no kernel driver calls this anymore.
pub fn scope_chunks_with<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        if n > 0 {
            f(0, n);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(start, end));
        }
    });
}

/// Number of worker threads to use: `INTATTN_THREADS` env override, else
/// available parallelism — the [`crate::util::env::knobs`] snapshot, so one
/// process sees one value everywhere (parse policy:
/// [`crate::util::env::threads_from`]).
pub fn default_threads() -> usize {
    crate::util::env::knobs().threads
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A simple atomic work counter used by tests and the scheduler.
#[derive(Default)]
pub struct Counter(AtomicUsize);

impl Counter {
    pub fn incr(&self) -> usize {
        self.0.fetch_add(1, Ordering::SeqCst) + 1
    }
    pub fn get(&self) -> usize {
        self.0.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(Counter::default());
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.incr();
            });
        }
        pool.wait_idle();
        assert_eq!(counter.get(), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn panicking_job_does_not_deadlock_wait_idle() {
        // Regression: a panicking job used to leave `pending` stuck above
        // zero forever, deadlocking wait_idle. The drop guard releases the
        // slot and the panic is surfaced through panic_count.
        let pool = ThreadPool::new(2);
        let c = Arc::new(Counter::default());
        pool.execute(|| panic!("job panic"));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.incr();
            });
        }
        pool.wait_idle(); // must return
        assert_eq!(c.get(), 10, "workers must survive a panicking job");
        assert_eq!(pool.panic_count(), 1);
    }

    #[test]
    fn scope_chunks_covers_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        scope_chunks_with(7, 1000, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scope_chunks_zero_n_is_noop() {
        scope_chunks_with(4, 0, |_, _| panic!("must not run"));
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(Counter::default());
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.incr();
            });
        }
        pool.wait_idle();
        drop(pool); // must not deadlock
        assert_eq!(c.get(), 10);
    }

    #[test]
    fn default_threads_env_override() {
        // The parse/override logic lives in the pure policies of
        // `crate::util::env` (exercised there); this checks only the
        // snapshot wiring. No test mutates the real environment — that
        // races every other concurrently running test's `getenv` (UB on
        // glibc).
        assert!(default_threads() >= 1);
        assert_eq!(default_threads(), crate::util::env::knobs().threads);
        assert_eq!(ParallelPool::new(2).grain(), crate::util::env::knobs().par_grain);
    }

    // -- ParallelPool --------------------------------------------------

    #[test]
    fn parallel_for_covers_range_exactly_once() {
        let pool = ParallelPool::with_grain(7, 1);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(1000, usize::MAX, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_reusable_across_many_launches() {
        // Workers must return to the parked state and pick up later
        // launches; finished descriptors must not accumulate.
        let pool = ParallelPool::with_grain(4, 1);
        for round in 0..50 {
            let sum = AtomicU64::new(0);
            pool.parallel_for(round + 1, usize::MAX, |s, e| {
                sum.fetch_add((e - s) as u64, Ordering::SeqCst);
            });
            assert_eq!(sum.load(Ordering::SeqCst), round as u64 + 1);
        }
        assert!(pool.shared.queue.lock().unwrap().is_empty());
    }

    #[test]
    fn parallel_for_zero_work_is_noop() {
        let pool = ParallelPool::with_grain(4, 1);
        pool.parallel_for(0, usize::MAX, |_, _| panic!("must not run"));
    }

    #[test]
    fn parallel_for_oversubscribed_more_workers_than_items() {
        let pool = ParallelPool::with_grain(16, 1);
        let hits: Vec<AtomicU64> = (0..3).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(3, usize::MAX, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn grain_policy_keeps_small_launches_inline() {
        let pool = ParallelPool::with_grain(8, 1 << 14);
        assert_eq!(pool.workers_for(0), 1);
        assert_eq!(pool.workers_for((1 << 14) - 1), 1);
        assert_eq!(pool.workers_for(1 << 14), 2);
        assert_eq!(pool.workers_for(100 << 14), 8, "capped at pool size");
        assert_eq!(pool.workers_for(usize::MAX), 8, "no overflow at usize::MAX");
        let single = ParallelPool::with_grain(1, 1);
        assert_eq!(single.workers_for(usize::MAX), 1);
    }

    #[test]
    fn panic_in_chunk_propagates_and_pool_survives() {
        let pool = ParallelPool::with_grain(4, 1);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(100, usize::MAX, |s, _| {
                if s == 0 {
                    panic!("chunk panic");
                }
            });
        }));
        assert!(r.is_err(), "launch must re-panic on the caller");
        // The pool must still work after a panicked launch.
        let sum = AtomicU64::new(0);
        pool.parallel_for(64, usize::MAX, |s, e| {
            sum.fetch_add((e - s) as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn nested_launch_runs_inline_and_completes() {
        let pool = ParallelPool::with_grain(4, 1);
        let outer = AtomicU64::new(0);
        let inner = AtomicU64::new(0);
        pool.parallel_for(8, usize::MAX, |s, e| {
            outer.fetch_add((e - s) as u64, Ordering::SeqCst);
            // Nested launch from a chunk body: must run inline (safe
            // fallback), not deadlock the pool.
            pool.parallel_for(4, usize::MAX, |s2, e2| {
                inner.fetch_add((e2 - s2) as u64, Ordering::SeqCst);
            });
        });
        assert_eq!(outer.load(Ordering::SeqCst), 8);
        // One inner launch of 4 items per outer chunk; every item ran.
        assert_eq!(inner.load(Ordering::SeqCst) % 4, 0);
        assert!(inner.load(Ordering::SeqCst) >= 4);
    }

    #[test]
    fn parallel_groups_visits_every_group_once() {
        for (n, threads) in [(1usize, 4usize), (7, 3), (23, 4), (8, 16), (5, 1)] {
            let pool = ParallelPool::with_grain(threads, 1);
            let mut groups: Vec<u32> = vec![0; n];
            pool.parallel_groups(&mut groups, usize::MAX, |g| *g += 1);
            assert!(groups.iter().all(|&x| x == 1), "n={n} threads={threads}");
        }
    }

    #[test]
    fn parallel_from_multiple_caller_threads() {
        // Concurrent launches from independent threads (the engine + tests
        // share the global pool): each caller participates in its own
        // launch, so progress is guaranteed even under contention.
        let pool: &'static ParallelPool = ParallelPool::with_grain(4, 1).leak();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    let sum = AtomicU64::new(0);
                    for _ in 0..20 {
                        pool.parallel_for(97, usize::MAX, |s, e| {
                            sum.fetch_add((e - s) as u64, Ordering::SeqCst);
                        });
                    }
                    sum.load(Ordering::SeqCst)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 20 * 97);
        }
    }

    #[test]
    fn sized_pools_are_cached() {
        let a = ParallelPool::sized(3);
        let b = ParallelPool::sized(3);
        assert!(std::ptr::eq(a, b));
        assert_eq!(a.size(), 3);
        assert_eq!(ParallelPool::sized(0).size(), 1, "clamped to 1");
    }

    #[test]
    fn global_pool_is_one_snapshotted_instance() {
        // The size is snapshotted into a OnceLock on first use (the
        // structural guarantee behind "later env mutations don't resize");
        // repeated calls must return the very same pool.
        let a = ParallelPool::global();
        let b = ParallelPool::global();
        assert!(std::ptr::eq(a, b));
        assert!(a.size() >= 1);
    }

    #[test]
    fn drop_races_worker_wakeup_without_lost_notify() {
        // TSan/stress target for the Drop protocol: `shutdown` is stored
        // while holding the queue mutex, so a worker can never check the
        // flag, miss the notify, and park forever (the exhaustive
        // interleaving argument is tests/pool_interleavings.rs). Churn
        // pools whose workers are in every phase of the loop — just
        // spawned, parked, draining a launch, re-checking the queue.
        let rounds = if cfg!(miri) { 8 } else { 200 };
        for round in 0..rounds {
            let pool = ParallelPool::with_grain(3, 1);
            if round % 2 == 0 {
                let sum = AtomicU64::new(0);
                pool.parallel_for(17, usize::MAX, |s, e| {
                    sum.fetch_add((e - s) as u64, Ordering::SeqCst);
                });
                assert_eq!(sum.load(Ordering::SeqCst), 17);
            }
            drop(pool); // must join every worker, never hang
        }
    }
}
