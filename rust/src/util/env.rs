//! Centralized `INTATTN_*` environment configuration.
//!
//! Every runtime knob the crate reads from the environment is listed here —
//! this table is the source of truth, and `intattn-audit`'s env-var pass
//! (see [`crate::audit`]) fails CI if a `std::env::var("INTATTN_…")` read
//! appears anywhere that is not reflected in the generated inventory
//! (`rust/audit/env_vars.md`).
//!
//! | Variable | Kind | Meaning | Default |
//! |---|---|---|---|
//! | `INTATTN_THREADS` | snapshot | computing threads in [`crate::util::threadpool::ParallelPool::global`] | available parallelism |
//! | `INTATTN_PAR_GRAIN` | snapshot | work units per worker before a launch widens | `DEFAULT_GRAIN` (2^14) |
//! | `INTATTN_KV_PAGE` | snapshot | rows per KV page | `DEFAULT_KV_PAGE_ROWS` (64) |
//! | `INTATTN_PREFIX_SHARE` | snapshot | copy-on-write prefix sharing (`0`/`false`/`off` disable) | on |
//! | `INTATTN_FUSED_DECODE` | snapshot | fused one-page-walk decode (`0`/`false`/`off` disable) | on |
//! | `INTATTN_DECODE_SPLIT` | snapshot | page spans per sequence in the fused decode walk (`0` = auto by pool workers per batch row) | `0` (auto) |
//! | `INTATTN_TILED_PREFILL` | snapshot | online-tiled (flash-style) prefill (`0`/`false`/`off` fall back to the materialized score block) | on |
//! | `INTATTN_BENCH_FAST` | snapshot | `=1` shrinks every bench to CI smoke budgets | off |
//! | `INTATTN_FAULT` | snapshot | fault-injection plan armed on engine start ([`crate::util::fault`]) | unset (inert) |
//! | `INTATTN_DRAIN_TIMEOUT_MS` | snapshot | engine shutdown-drain hard stop, ms (`0` = unlimited) | `DEFAULT_DRAIN_TIMEOUT_MS` (10000) |
//! | `INTATTN_WAITING_RATIO` | snapshot | admission interleaving gate: waiting/active ratio below which in-flight decode is not stalled for new prefills (`0` = admit greedily) | `DEFAULT_WAITING_RATIO` (1.2) |
//! | `INTATTN_LOG` | per-read | log level (`error`/`warn`/`info`/`debug`/`trace`) | `info` |
//! | `INTATTN_ARTIFACTS` | per-read | PJRT artifacts directory | `artifacts/` |
//! | `INTATTN_REPORTS` | per-read | bench/experiment report directory | `reports/` |
//! | `INTATTN_FULL` | per-read | `=1` enables the paper-scale 1K..16K sweeps | off |
//! | `INTATTN_SERVE_ADDR` | per-read | TCP listen address of the `serve` front-end binary | `127.0.0.1:7411` |
//!
//! ## Snapshot semantics
//!
//! The eleven *snapshot* knobs configure process-lifetime singletons (the
//! global pool, the page geometry every state must agree on, the serving
//! defaults). They are read **exactly once**, together, on the first
//! [`knobs`] call; later environment mutations are invisible. That is a
//! feature twice over: every component sees one consistent configuration,
//! and no hot path ever calls `getenv` (mutating the environment while
//! another thread reads it is undefined behavior on glibc — which is also
//! why **no test in this crate touches the real environment**: each knob's
//! parsing lives in a pure `*_from(Option<&str>)` policy function below,
//! and tests exercise those).
//!
//! The *per-read* variables gate cold paths (logger init, report/artifact
//! directories, bench sweep sizes) where a fresh read per use is harmless;
//! they stay at their call sites but are still inventoried.

use std::sync::OnceLock;

/// Engine drain hard-stop default, milliseconds (`INTATTN_DRAIN_TIMEOUT_MS`
/// overrides; `0` means wait forever).
pub const DEFAULT_DRAIN_TIMEOUT_MS: u64 = 10_000;

/// Default admission interleaving gate (`INTATTN_WAITING_RATIO` overrides;
/// `0` disables): TGI ships 1.2 waiting per active as the point where a
/// prefill stall starts paying for itself.
pub const DEFAULT_WAITING_RATIO: f32 = 1.2;

/// The eleven process-lifetime knobs, snapshotted together on first access.
#[derive(Clone, Copy, Debug)]
pub struct Knobs {
    /// `INTATTN_THREADS` — computing threads for the global pool.
    pub threads: usize,
    /// `INTATTN_PAR_GRAIN` — launch-grain work units per worker.
    pub par_grain: usize,
    /// `INTATTN_KV_PAGE` — rows per KV page.
    pub kv_page_rows: usize,
    /// `INTATTN_PREFIX_SHARE` — copy-on-write prefix sharing default.
    pub prefix_share: bool,
    /// `INTATTN_FUSED_DECODE` — fused flash-decode default.
    pub fused_decode: bool,
    /// `INTATTN_DECODE_SPLIT` — page spans per sequence in the fused decode
    /// walk (`0` = auto: pool workers left over per batch row).
    pub decode_split: usize,
    /// `INTATTN_TILED_PREFILL` — online-tiled (flash-style) prefill default.
    pub tiled_prefill: bool,
    /// `INTATTN_BENCH_FAST` — CI smoke budgets for every bench harness.
    pub bench_fast: bool,
    /// `INTATTN_FAULT` — fault-injection plan armed on the first engine
    /// start ([`crate::util::fault::ensure_env_armed`]); `None` is inert.
    /// Leaked to `'static` so the snapshot stays `Copy`.
    pub fault: Option<&'static str>,
    /// `INTATTN_DRAIN_TIMEOUT_MS` — engine shutdown-drain hard stop in
    /// milliseconds (`0` = wait for in-flight work forever).
    pub drain_timeout_ms: u64,
    /// `INTATTN_WAITING_RATIO` — default
    /// [`crate::coordinator::BatchPolicy::waiting_served_ratio`] admission
    /// gate (`0` = admit greedily every round).
    pub waiting_ratio: f32,
}

/// The process-wide snapshot. First call reads all eleven variables; every
/// later call returns the same values.
pub fn knobs() -> &'static Knobs {
    static K: OnceLock<Knobs> = OnceLock::new();
    K.get_or_init(|| Knobs {
        threads: threads_from(std::env::var("INTATTN_THREADS").ok().as_deref()),
        par_grain: grain_from(std::env::var("INTATTN_PAR_GRAIN").ok().as_deref()),
        kv_page_rows: page_rows_from(std::env::var("INTATTN_KV_PAGE").ok().as_deref()),
        prefix_share: prefix_share_from(std::env::var("INTATTN_PREFIX_SHARE").ok().as_deref()),
        fused_decode: fused_decode_from(std::env::var("INTATTN_FUSED_DECODE").ok().as_deref()),
        decode_split: decode_split_from(std::env::var("INTATTN_DECODE_SPLIT").ok().as_deref()),
        tiled_prefill: tiled_prefill_from(
            std::env::var("INTATTN_TILED_PREFILL").ok().as_deref(),
        ),
        bench_fast: bench_fast_from(std::env::var("INTATTN_BENCH_FAST").ok().as_deref()),
        fault: fault_from(std::env::var("INTATTN_FAULT").ok().as_deref())
            .map(|s| &*Box::leak(s.into_boxed_str())),
        drain_timeout_ms: drain_timeout_ms_from(
            std::env::var("INTATTN_DRAIN_TIMEOUT_MS").ok().as_deref(),
        ),
        waiting_ratio: waiting_ratio_from(std::env::var("INTATTN_WAITING_RATIO").ok().as_deref()),
    })
}

// ---------------------------------------------------------------------------
// Pure policy functions — the parse/default logic, testable without getenv

/// `INTATTN_THREADS`: positive integer (0 clamps to 1); junk or unset falls
/// back to available parallelism.
pub fn threads_from(env: Option<&str>) -> usize {
    if let Some(n) = env.and_then(|v| v.parse::<usize>().ok()) {
        return n.max(1);
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// `INTATTN_PAR_GRAIN`: positive integer (0 clamps to 1); junk or unset
/// falls back to [`crate::util::threadpool::DEFAULT_GRAIN`].
pub fn grain_from(env: Option<&str>) -> usize {
    if let Some(n) = env.and_then(|v| v.parse::<usize>().ok()) {
        return n.max(1);
    }
    crate::util::threadpool::DEFAULT_GRAIN
}

/// `INTATTN_KV_PAGE`: positive integer (0 clamps to 1); junk or unset falls
/// back to [`crate::attention::state::DEFAULT_KV_PAGE_ROWS`].
pub fn page_rows_from(env: Option<&str>) -> usize {
    env.and_then(|v| v.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(crate::attention::state::DEFAULT_KV_PAGE_ROWS)
}

/// `INTATTN_PREFIX_SHARE`: `0`/`false`/`off` disable; anything else —
/// including unset — enables.
pub fn prefix_share_from(env: Option<&str>) -> bool {
    !matches!(env, Some("0") | Some("false") | Some("off"))
}

/// `INTATTN_FUSED_DECODE`: `0`/`false`/`off` (whitespace-tolerant) disable;
/// anything else — including unset — enables.
pub fn fused_decode_from(env: Option<&str>) -> bool {
    !matches!(env.map(str::trim), Some("0") | Some("false") | Some("off"))
}

/// `INTATTN_DECODE_SPLIT`: page spans per sequence in the fused decode
/// walk. `0` — and junk or unset — means auto (the split policy divides
/// the pool's workers across the batch; see
/// [`crate::gemm::decode_split_spans`]).
pub fn decode_split_from(env: Option<&str>) -> usize {
    env.and_then(|v| v.trim().parse::<usize>().ok()).unwrap_or(0)
}

/// `INTATTN_TILED_PREFILL`: `0`/`false`/`off` (whitespace-tolerant) fall
/// back to the materialized-score-block prefill; anything else — including
/// unset — keeps the online-tiled walk.
pub fn tiled_prefill_from(env: Option<&str>) -> bool {
    !matches!(env.map(str::trim), Some("0") | Some("false") | Some("off"))
}

/// `INTATTN_BENCH_FAST`: exactly `1` enables; anything else stays off.
pub fn bench_fast_from(env: Option<&str>) -> bool {
    env == Some("1")
}

/// `INTATTN_FAULT`: a fault-injection plan string for
/// [`crate::util::fault`] (e.g. `pool_alloc@17,delay_prefill=2ms`); blank
/// or whitespace-only is unset. Deliberately *not* validated here: a
/// malformed plan must fail loudly at arm time
/// ([`crate::util::fault::ensure_env_armed`]), not silently disarm.
pub fn fault_from(env: Option<&str>) -> Option<String> {
    env.map(str::trim).filter(|s| !s.is_empty()).map(str::to_owned)
}

/// `INTATTN_DRAIN_TIMEOUT_MS`: drain hard stop in milliseconds; `0` waits
/// forever. Junk or unset falls back to [`DEFAULT_DRAIN_TIMEOUT_MS`].
pub fn drain_timeout_ms_from(env: Option<&str>) -> u64 {
    env.and_then(|v| v.trim().parse::<u64>().ok()).unwrap_or(DEFAULT_DRAIN_TIMEOUT_MS)
}

/// `INTATTN_WAITING_RATIO`: waiting/active admission gate; `0` disables
/// (admit greedily). Junk, negatives, NaN or unset fall back to
/// [`DEFAULT_WAITING_RATIO`].
pub fn waiting_ratio_from(env: Option<&str>) -> f32 {
    env.and_then(|v| v.trim().parse::<f32>().ok())
        .filter(|r| r.is_finite() && *r >= 0.0)
        .unwrap_or(DEFAULT_WAITING_RATIO)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::state::DEFAULT_KV_PAGE_ROWS;
    use crate::util::threadpool::DEFAULT_GRAIN;

    #[test]
    fn threads_policy() {
        assert_eq!(threads_from(Some("3")), 3);
        assert_eq!(threads_from(Some("0")), 1, "clamped to 1");
        assert!(threads_from(Some("not-a-number")) >= 1, "junk falls back");
        assert!(threads_from(None) >= 1);
    }

    #[test]
    fn grain_policy() {
        assert_eq!(grain_from(Some("123")), 123);
        assert_eq!(grain_from(Some("0")), 1, "clamped to 1");
        assert_eq!(grain_from(None), DEFAULT_GRAIN);
        assert_eq!(grain_from(Some("junk")), DEFAULT_GRAIN);
    }

    #[test]
    fn page_rows_policy() {
        assert_eq!(page_rows_from(None), DEFAULT_KV_PAGE_ROWS);
        assert_eq!(page_rows_from(Some("2")), 2);
        assert_eq!(page_rows_from(Some("0")), 1, "clamped to 1");
        assert_eq!(page_rows_from(Some("junk")), DEFAULT_KV_PAGE_ROWS);
    }

    #[test]
    fn prefix_share_policy() {
        assert!(prefix_share_from(None));
        assert!(prefix_share_from(Some("1")));
        assert!(prefix_share_from(Some("yes")));
        assert!(!prefix_share_from(Some("0")));
        assert!(!prefix_share_from(Some("false")));
        assert!(!prefix_share_from(Some("off")));
    }

    #[test]
    fn fused_decode_policy() {
        assert!(fused_decode_from(None));
        assert!(fused_decode_from(Some("1")));
        assert!(fused_decode_from(Some("yes")));
        assert!(!fused_decode_from(Some("0")));
        assert!(!fused_decode_from(Some("false")));
        assert!(!fused_decode_from(Some("off")));
        assert!(!fused_decode_from(Some(" 0 ")));
    }

    #[test]
    fn decode_split_policy() {
        assert_eq!(decode_split_from(None), 0, "unset = auto");
        assert_eq!(decode_split_from(Some("0")), 0);
        assert_eq!(decode_split_from(Some("4")), 4);
        assert_eq!(decode_split_from(Some(" 2 ")), 2);
        assert_eq!(decode_split_from(Some("junk")), 0, "junk falls back to auto");
    }

    #[test]
    fn tiled_prefill_policy() {
        assert!(tiled_prefill_from(None));
        assert!(tiled_prefill_from(Some("1")));
        assert!(tiled_prefill_from(Some("yes")));
        assert!(!tiled_prefill_from(Some("0")));
        assert!(!tiled_prefill_from(Some("false")));
        assert!(!tiled_prefill_from(Some("off")));
        assert!(!tiled_prefill_from(Some(" 0 ")));
    }

    #[test]
    fn bench_fast_policy() {
        assert!(bench_fast_from(Some("1")));
        assert!(!bench_fast_from(Some("true")));
        assert!(!bench_fast_from(None));
    }

    #[test]
    fn fault_policy() {
        assert_eq!(fault_from(None), None);
        assert_eq!(fault_from(Some("")), None);
        assert_eq!(fault_from(Some("   ")), None);
        assert_eq!(fault_from(Some(" pool_alloc@1 ")), Some("pool_alloc@1".to_string()));
        // Junk is preserved for arm time to reject loudly, not eaten here.
        assert_eq!(fault_from(Some("not-a-plan")), Some("not-a-plan".to_string()));
    }

    #[test]
    fn drain_timeout_policy() {
        assert_eq!(drain_timeout_ms_from(None), DEFAULT_DRAIN_TIMEOUT_MS);
        assert_eq!(drain_timeout_ms_from(Some("250")), 250);
        assert_eq!(drain_timeout_ms_from(Some(" 250 ")), 250);
        assert_eq!(drain_timeout_ms_from(Some("0")), 0, "0 = wait forever");
        assert_eq!(drain_timeout_ms_from(Some("junk")), DEFAULT_DRAIN_TIMEOUT_MS);
    }

    #[test]
    fn waiting_ratio_policy() {
        assert_eq!(waiting_ratio_from(None), DEFAULT_WAITING_RATIO);
        assert_eq!(waiting_ratio_from(Some("2.5")), 2.5);
        assert_eq!(waiting_ratio_from(Some(" 2.5 ")), 2.5);
        assert_eq!(waiting_ratio_from(Some("0")), 0.0, "0 = admit greedily");
        assert_eq!(waiting_ratio_from(Some("-1")), DEFAULT_WAITING_RATIO, "negatives fall back");
        assert_eq!(waiting_ratio_from(Some("NaN")), DEFAULT_WAITING_RATIO);
        assert_eq!(waiting_ratio_from(Some("junk")), DEFAULT_WAITING_RATIO);
    }

    #[test]
    fn knobs_snapshot_is_stable() {
        let a = knobs();
        let b = knobs();
        assert!(std::ptr::eq(a, b));
        assert!(a.threads >= 1);
        assert!(a.par_grain >= 1);
        assert!(a.kv_page_rows >= 1);
    }
}
