//! Software IEEE-754 binary16 ("half", FP16).
//!
//! The `half` crate is not in the offline cache and this x86 host has no
//! scalar f16 ALU, so the FP16 baseline pipeline stores activations as
//! bit-exact binary16 and computes in f32 — the same storage-bandwidth
//! profile as a real FP16 edge path (see DESIGN.md §2). Conversions follow
//! round-to-nearest-even, with correct handling of subnormals, infinities
//! and NaN.

/// A 16-bit IEEE binary16 value.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const NAN: F16 = F16(0x7E00);
    /// Largest finite f16 = 65504.
    pub const MAX: F16 = F16(0x7BFF);

    #[inline]
    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }

    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }
}

/// f32 → f16 bits, round-to-nearest-even (branchful but clear; the bulk
/// conversions below are what the hot paths use and autovectorize well).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN — preserve NaN-ness with a quiet mantissa bit.
        return if mant == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }

    // Unbiased exponent, rebiased for f16 (bias 15 vs 127).
    let unbiased = exp - 127;
    let f16_exp = unbiased + 15;

    if f16_exp >= 0x1F {
        // Overflow → infinity.
        return sign | 0x7C00;
    }

    if f16_exp <= 0 {
        // Subnormal or underflow to zero.
        if f16_exp < -10 {
            return sign; // too small: signed zero
        }
        // Implicit leading 1 becomes explicit, then shift right.
        let m = mant | 0x0080_0000;
        let shift = (14 - f16_exp) as u32;
        let half_ulp = 1u32 << (shift - 1);
        let mut half_mant = m >> shift;
        let rem = m & ((1 << shift) - 1);
        // Round to nearest even.
        if rem > half_ulp || (rem == half_ulp && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        return sign | half_mant as u16;
    }

    // Normal number: keep top 10 mantissa bits, round-to-nearest-even.
    let mut out = ((f16_exp as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
        out += 1; // may carry into exponent — that is correct (rounds up to inf)
    }
    sign | out as u16
}

/// f16 bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;

    let bits = if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((127 - 15 + e + 2) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf / nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Bulk conversion f32 slice → f16 vec.
pub fn encode_slice(xs: &[f32]) -> Vec<F16> {
    xs.iter().map(|&x| F16::from_f32(x)).collect()
}

/// Bulk conversion f16 slice → f32, into a caller-provided buffer.
pub fn decode_into(h: &[F16], out: &mut [f32]) {
    assert_eq!(h.len(), out.len());
    for (o, &v) in out.iter_mut().zip(h) {
        *o = v.to_f32();
    }
}

/// Round-trip an f32 through f16 precision ("fp16 quantization" of a value).
#[inline]
pub fn round_f32_to_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_round_trip() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(round_f32_to_f16(x), x, "i={i}");
        }
    }

    #[test]
    fn one_and_constants() {
        assert_eq!(F16::ONE.to_f32(), 1.0);
        assert_eq!(F16::from_f32(1.0), F16::ONE);
        assert_eq!(F16::MAX.to_f32(), 65504.0);
    }

    #[test]
    fn overflow_to_infinity() {
        assert!(F16::from_f32(1e6).is_infinite());
        assert!(F16::from_f32(-1e6).is_infinite());
        assert_eq!(F16::from_f32(65520.0), F16::INFINITY); // rounds up past MAX
    }

    #[test]
    fn nan_preserved() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16::NAN.to_f32().is_nan());
    }

    #[test]
    fn signed_zero() {
        assert_eq!(F16::from_f32(-0.0).0, 0x8000);
        assert_eq!(F16::from_f32(0.0).0, 0x0000);
    }

    #[test]
    fn subnormals_round_trip() {
        // Smallest positive subnormal f16 = 2^-24.
        let tiny = 2.0f32.powi(-24);
        assert_eq!(F16::from_f32(tiny).0, 1);
        assert_eq!(f16_bits_to_f32(1), tiny);
        // Largest subnormal.
        let big_sub = f16_bits_to_f32(0x03FF);
        assert_eq!(F16::from_f32(big_sub).0, 0x03FF);
    }

    #[test]
    fn underflow_to_zero() {
        assert_eq!(F16::from_f32(1e-12).0, 0);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between two f16 values; ties to even
        // keep the mantissa even (i.e. 1.0).
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(round_f32_to_f16(x), 1.0);
        // 1 + 3·2^-11 is halfway as well but rounds up to the even neighbor.
        let y = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(round_f32_to_f16(y), 1.0 + 2.0f32.powi(-9));
    }

    #[test]
    fn all_f16_bit_patterns_round_trip_through_f32() {
        // Every finite f16 must survive f16→f32→f16 exactly.
        for bits in 0..=0xFFFFu32 {
            let h = F16(bits as u16);
            if h.is_nan() {
                continue;
            }
            let back = F16::from_f32(h.to_f32());
            assert_eq!(back.0, h.0, "bits={bits:#06x}");
        }
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        // f16 has 11 significand bits → rel error ≤ 2^-11.
        let mut x = 1.0e-4f32;
        while x < 6.0e4 {
            let r = round_f32_to_f16(x);
            let rel = ((r - x) / x).abs();
            assert!(rel <= 2.0f32.powi(-11), "x={x} r={r} rel={rel}");
            x *= 1.37;
        }
    }

    #[test]
    fn bulk_encode_decode() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.37).collect();
        let h = encode_slice(&xs);
        let mut back = vec![0.0f32; xs.len()];
        decode_into(&h, &mut back);
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() * 2.0f32.powi(-11) + 1e-6);
        }
    }
}
