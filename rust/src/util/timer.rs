//! Per-stage timing instrumentation.
//!
//! Every attention pipeline reports where its time goes through a
//! [`StageTimes`] record — this is the data behind the paper's Figure 2
//! (share of the dequantize→softmax→requantize path) and the §4.4 latency
//! breakdown ablation.

use std::time::Instant;

/// The stages the paper's breakdown distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Dynamic quantization of Q/K/V inputs (FP → INT8).
    Quantize,
    /// The `Q·Kᵀ` similarity GEMM.
    QkGemm,
    /// INT32→FP32 dequantization before a floating-point softmax.
    Dequantize,
    /// The softmax itself (float or integer surrogate).
    Softmax,
    /// FP32→INT8/UINT8 requantization of the probability matrix.
    Requantize,
    /// The `P·V` aggregation GEMM.
    PvGemm,
    /// Final output rescale / dtype restore.
    Output,
}

pub const ALL_STAGES: [Stage; 7] = [
    Stage::Quantize,
    Stage::QkGemm,
    Stage::Dequantize,
    Stage::Softmax,
    Stage::Requantize,
    Stage::PvGemm,
    Stage::Output,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Quantize => "quantize",
            Stage::QkGemm => "qk_gemm",
            Stage::Dequantize => "dequantize",
            Stage::Softmax => "softmax",
            Stage::Requantize => "requantize",
            Stage::PvGemm => "pv_gemm",
            Stage::Output => "output",
        }
    }

    fn index(self) -> usize {
        match self {
            Stage::Quantize => 0,
            Stage::QkGemm => 1,
            Stage::Dequantize => 2,
            Stage::Softmax => 3,
            Stage::Requantize => 4,
            Stage::PvGemm => 5,
            Stage::Output => 6,
        }
    }
}

/// Accumulated nanoseconds per stage for one or more forward passes.
#[derive(Clone, Debug, Default)]
pub struct StageTimes {
    ns: [u64; 7],
}

impl StageTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, attributing the elapsed wall-clock to `stage`.
    #[inline]
    pub fn measure<T>(&mut self, stage: Stage, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.ns[stage.index()] += t0.elapsed().as_nanos() as u64;
        out
    }

    pub fn add_ns(&mut self, stage: Stage, ns: u64) {
        self.ns[stage.index()] += ns;
    }

    pub fn get_ns(&self, stage: Stage) -> u64 {
        self.ns[stage.index()]
    }

    pub fn total_ns(&self) -> u64 {
        self.ns.iter().sum()
    }

    /// Nanoseconds in the dequantize→softmax→requantize path — the quantity
    /// Figure 2 tracks. (For float pipelines the De/Requantize entries are
    /// zero and the path is just the softmax.)
    pub fn softmax_path_ns(&self) -> u64 {
        self.get_ns(Stage::Dequantize) + self.get_ns(Stage::Softmax) + self.get_ns(Stage::Requantize)
    }

    /// Share of total time spent on the softmax path, in `[0, 1]`.
    pub fn softmax_path_share(&self) -> f64 {
        let total = self.total_ns();
        if total == 0 {
            0.0
        } else {
            self.softmax_path_ns() as f64 / total as f64
        }
    }

    pub fn reset(&mut self) {
        self.ns = [0; 7];
    }

    pub fn merge(&mut self, other: &StageTimes) {
        for (a, b) in self.ns.iter_mut().zip(&other.ns) {
            *a += b;
        }
    }

    /// Render a one-line breakdown like `qk_gemm 41.2% | softmax 13.8% | ...`.
    pub fn render(&self) -> String {
        let total = self.total_ns().max(1) as f64;
        ALL_STAGES
            .iter()
            .filter(|s| self.get_ns(**s) > 0)
            .map(|s| format!("{} {:.1}%", s.name(), 100.0 * self.get_ns(*s) as f64 / total))
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_accumulates() {
        let mut t = StageTimes::new();
        let x = t.measure(Stage::Softmax, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(x, 42);
        assert!(t.get_ns(Stage::Softmax) >= 1_000_000);
        assert_eq!(t.get_ns(Stage::QkGemm), 0);
    }

    #[test]
    fn softmax_path_includes_conversions() {
        let mut t = StageTimes::new();
        t.add_ns(Stage::Dequantize, 10);
        t.add_ns(Stage::Softmax, 20);
        t.add_ns(Stage::Requantize, 30);
        t.add_ns(Stage::QkGemm, 40);
        assert_eq!(t.softmax_path_ns(), 60);
        assert!((t.softmax_path_share() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn merge_and_reset() {
        let mut a = StageTimes::new();
        let mut b = StageTimes::new();
        a.add_ns(Stage::QkGemm, 5);
        b.add_ns(Stage::QkGemm, 7);
        a.merge(&b);
        assert_eq!(a.get_ns(Stage::QkGemm), 12);
        a.reset();
        assert_eq!(a.total_ns(), 0);
    }

    #[test]
    fn share_of_empty_is_zero() {
        assert_eq!(StageTimes::new().softmax_path_share(), 0.0);
    }

    #[test]
    fn render_mentions_nonzero_stages() {
        let mut t = StageTimes::new();
        t.add_ns(Stage::Softmax, 100);
        let s = t.render();
        assert!(s.contains("softmax"));
        assert!(!s.contains("qk_gemm"));
    }
}
