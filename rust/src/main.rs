//! `intattn` — the IntAttention edge-inference CLI.
//!
//! Subcommands cover the serving engine, text generation, perplexity
//! evaluation, and every paper experiment (each also available as a
//! `cargo bench` target; see DESIGN.md §5).

use intattention::attention::PipelineKind;
use intattention::coordinator::{Engine, EngineOptions, SubmitOptions};
use intattention::harness::experiments as exp;
use intattention::harness::workload::request_trace;
use intattention::model::lm::TinyLm;
use intattention::model::tokenizer;
use intattention::util::cli::{App, Args, Command};
use intattention::util::prng::Pcg64;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = build_app();
    match app.parse(&argv) {
        Ok((cmd, args)) => {
            if let Err(e) = dispatch(&cmd, &args) {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

fn build_app() -> App {
    App::new("intattn", "fully integer attention for edge inference (IntAttention reproduction)")
        .command(
            Command::new("generate", "generate text with the tiny LM")
                .opt("prompt", "prompt text", Some("edge device"))
                .opt("tokens", "tokens to generate", Some("64"))
                .opt("pipeline", "fp32|fp16|quant-only|int|exaq2|exaq3", Some("int"))
                .opt("temperature", "sampling temperature", Some("0.8"))
                .opt("top-k", "top-k truncation", Some("20"))
                .opt("seed", "rng seed", Some("0")),
        )
        .command(
            Command::new("perplexity", "held-out perplexity under a pipeline")
                .opt("pipeline", "fp32|fp16|quant-only|int|exaq2|exaq3", Some("int"))
                .opt("seqs", "number of eval sequences", Some("8"))
                .opt("len", "sequence length", Some("192")),
        )
        .command(
            Command::new("serve", "run the serving engine on a synthetic trace")
                .opt("pipeline", "attention backend", Some("int"))
                .opt("requests", "number of requests", Some("32"))
                .opt("rate", "arrival rate per second", Some("8"))
                .opt("max-active", "max batch size", Some("8"))
                .opt("gen", "max tokens generated per request", Some("16")),
        )
        .command(
            Command::new("bench", "run a paper experiment")
                .opt("id", "fig2|fig4|fig5|fig6|fig7|fig8|fig9|tab1|tab2|tab3|tab5|tab8|tab9|tab10|decode|all", Some("all"))
                .opt("seq-lens", "comma-separated L sweep", None)
                .opt("head-dim", "head dimension d", Some("128")),
        )
        .command(Command::new("report", "print engine/version info"))
}

fn dispatch(cmd: &str, args: &Args) -> anyhow::Result<()> {
    match cmd {
        "generate" => cmd_generate(args),
        "perplexity" => cmd_perplexity(args),
        "serve" => cmd_serve(args),
        "bench" => cmd_bench(args),
        "report" => {
            println!("intattn v{}", intattention::VERSION);
            let dir = intattention::runtime::default_artifacts_dir();
            println!("artifacts dir: {}", dir.display());
            match intattention::runtime::ArtifactRuntime::new(&dir) {
                Ok(rt) => println!(
                    "pjrt platform: {} | artifacts: {:?}",
                    rt.platform(),
                    rt.list_artifacts()
                ),
                Err(e) => println!("pjrt unavailable: {e}"),
            }
            Ok(())
        }
        _ => anyhow::bail!("unhandled command {cmd}"),
    }
}

fn pipeline_arg(args: &Args) -> anyhow::Result<PipelineKind> {
    let s = args.get_or("pipeline", "int");
    PipelineKind::parse(s).ok_or_else(|| anyhow::anyhow!("unknown pipeline '{s}'"))
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let kind = pipeline_arg(args)?;
    let weights = exp::load_or_random_weights();
    let mut lm = TinyLm::new(weights, kind);
    let prompt = tokenizer::encode(args.get_or("prompt", "edge device"));
    let n = args.get_usize("tokens", 64)?;
    let temp = args.get_f64("temperature", 0.8)? as f32;
    let top_k = args.get_usize("top-k", 20)?;
    let mut rng = Pcg64::seed_from_u64(args.get_usize("seed", 0)? as u64);
    let out = lm.generate(&prompt, n, temp, top_k, &mut rng);
    println!("[{}] {}{}", kind.name(), args.get_or("prompt", ""), tokenizer::decode(&out));
    println!("attention: {}", lm.attention_times().render());
    Ok(())
}

fn cmd_perplexity(args: &Args) -> anyhow::Result<()> {
    let kind = pipeline_arg(args)?;
    let weights = exp::load_or_random_weights();
    let dir = intattention::runtime::default_artifacts_dir();
    let max_seq = weights.cfg.max_seq;
    let seqs = intattention::harness::fidelity::eval_sequences(
        &dir,
        args.get_usize("seqs", 8)?,
        args.get_usize("len", 192)?.min(max_seq),
        weights.cfg.vocab,
    );
    let f = intattention::harness::fidelity::eval_lm_fidelity(&weights, kind, &seqs);
    println!(
        "{}: perplexity {:.3} | top-1 agreement with FP32 {:.3} | loss MAD {:.4}",
        f.pipeline, f.perplexity, f.top1_agreement, f.loss_mad
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let kind = pipeline_arg(args)?;
    let weights = exp::load_or_random_weights();
    let max_seq = weights.cfg.max_seq;
    let opts = EngineOptions {
        attention: kind,
        policy: intattention::coordinator::batcher::BatchPolicy {
            max_active: args.get_usize("max-active", 8)?,
            ..Default::default()
        },
        ..Default::default()
    };
    let n = args.get_usize("requests", 32)?;
    let rate = args.get_f64("rate", 8.0)?;
    let max_gen = args.get_usize("gen", 16)?;
    let mut rng = Pcg64::seed_from_u64(42);
    let trace = request_trace(&mut rng, n, rate, &[16, 48, 128], max_gen);
    let handle = Engine::start(weights, opts);
    println!("serving {n} requests (pipeline {}, rate {rate}/s)...", kind.name());
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::new();
    for r in &trace {
        // Replay arrivals in (compressed) time.
        let target = std::time::Duration::from_micros(r.arrival_us);
        if let Some(sleep) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        let prompt: Vec<u16> = (0..r.prompt_len.min(max_seq / 2))
            .map(|i| (i * 31 % 256) as u16)
            .collect();
        match handle.submit(prompt, r.gen_len, SubmitOptions::sampling(0.7, 16)) {
            Ok(rx) => receivers.push(rx),
            Err(e) => eprintln!("rejected: {e}"),
        }
    }
    for mut rx in receivers {
        let _ = rx.recv_final();
    }
    let snap = handle.shutdown();
    println!("{}", snap.render());
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let id = args.get_or("id", "all").to_string();
    let d = args.get_usize("head-dim", exp::HEAD_DIM)?;
    let lens = args.get_usize_list("seq-lens", &exp::default_seq_lens())?;
    let run = |want: &str| id == "all" || id == want;

    if run("fig2") {
        exp::render_fig2(&exp::fig2_breakdown(&lens, d, 1)).print();
    }
    if run("fig4") {
        exp::render_fig4(&exp::fig4_sparsity(256, d.min(64))).print();
    }
    if run("fig5") {
        exp::render_fig5(&exp::fig5_lut_resolution()).print();
    }
    if run("fig6") {
        exp::render_speed(&exp::speed_sweep(&lens, d, 1), "Figure 6 — throughput, cfg-A (1 thread)").print();
    }
    if run("fig7") {
        exp::render_speed(
            &exp::speed_sweep(&lens, d, intattention::util::threadpool::default_threads()),
            "Figure 7 — throughput, cfg-B (all threads)",
        )
        .print();
    }
    if run("fig8") {
        exp::render_fig8(&exp::fig8_energy(&lens, d)).print();
    }
    if run("fig9") {
        exp::render_fig9(&exp::fig9_sweep(&[2, 3, 4, 5, 6, 8], &[4.4, 5.5, 6.6, 7.7, 8.8], 128, d.min(64))).print();
    }
    if run("tab8") {
        let a = exp::speed_sweep(&lens, d, 1);
        let b = exp::speed_sweep(&lens, d, intattention::util::threadpool::default_threads());
        exp::render_tab8(&a, &b).print();
    }
    if run("tab9") {
        let (i8f, u8f) = exp::tab9_p_quant(256, d.min(64), 4);
        exp::render_tab9(&i8f, &u8f).print();
    }
    if run("decode") {
        exp::render_decode(&exp::decode_sweep(&lens, d, 32, 1)).print();
    }
    if run("tab1") || run("tab5") || run("tab3") || run("tab10") || run("tab2") {
        let w = exp::load_or_random_weights();
        if run("tab1") {
            exp::render_lm_fidelity(&exp::tab1_lm_fidelity(&w, 6, 192), "Table 1 — LM fidelity").print();
        }
        if run("tab2") {
            exp::render_tab2(&exp::tab2_encoder_fidelity(128, d.min(64), 3)).print();
        }
        if run("tab3") {
            for (ctx, rows) in exp::tab3_long_context(&w, &[64, 128, 256], 4) {
                exp::render_lm_fidelity(&rows, &format!("Table 3 — long-context fidelity @ ctx={ctx}")).print();
            }
        }
        if run("tab5") {
            exp::render_lm_fidelity(
                &exp::tab5_softmax_ablation(&w, 6, 192),
                "Table 5 — softmax-only ablation",
            )
            .print();
        }
        if run("tab10") {
            exp::render_tab10(&exp::tab10_stability(&w, 256, 4)).print();
        }
    }
    Ok(())
}
