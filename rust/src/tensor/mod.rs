//! Row-major matrix types used throughout the attention pipelines.
//!
//! Attention operates head-by-head on 2-D slabs, so the core type is a
//! generic row-major [`Mat<T>`] with typed aliases for the element types the
//! paper's dataflow uses: `f32` activations, software-f16 storage, `i8`
//! quantized Q/K/V, `u8` probabilities, and `i32` accumulators/logits.

use crate::util::f16::F16;

/// A dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat<T> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

pub type MatF32 = Mat<f32>;
pub type MatF16 = Mat<F16>;
pub type MatI8 = Mat<i8>;
pub type MatU8 = Mat<u8>;
pub type MatI32 = Mat<i32>;

impl<T: Copy + Default> Mat<T> {
    /// Zero-filled (default-filled) matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![T::default(); rows * cols] }
    }

    /// Build from an existing buffer; `data.len()` must equal `rows*cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// The whole backing buffer.
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Transposed copy. Used once per forward to lay K out column-major for
    /// the GEMM microkernels (so the inner loops stream contiguously).
    pub fn transpose(&self) -> Mat<T> {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            let src = self.row(r);
            for c in 0..self.cols {
                out.data[c * self.rows + r] = src[c];
            }
        }
        out
    }

    /// Two disjoint row-range views `(rows[..mid], rows[mid..])`.
    pub fn split_rows_mut(&mut self, mid: usize) -> (&mut [T], &mut [T]) {
        assert!(mid <= self.rows);
        self.data.split_at_mut(mid * self.cols)
    }

    /// Map every element.
    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Mat<U> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }
}

impl MatF32 {
    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt()
    }

    /// Max |x| over all elements (the per-tensor dynamic-quantization range).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Elementwise approximate equality.
    pub fn allclose(&self, other: &MatF32, atol: f32, rtol: f32) -> bool {
        if self.rows != other.rows || self.cols != other.cols {
            return false;
        }
        self.data.iter().zip(&other.data).all(|(&a, &b)| {
            (a - b).abs() <= atol + rtol * b.abs()
        })
    }

    /// Convert to f16 storage.
    pub fn to_f16(&self) -> MatF16 {
        self.map(F16::from_f32)
    }
}

impl MatF16 {
    /// Convert back to f32.
    pub fn to_f32(&self) -> MatF32 {
        self.map(|h| h.to_f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_and_access() {
        let mut m = MatF32::zeros(3, 4);
        assert_eq!((m.rows(), m.cols(), m.len()), (3, 4, 12));
        m.set(2, 3, 7.5);
        assert_eq!(m.get(2, 3), 7.5);
        assert_eq!(m.row(2)[3], 7.5);
    }

    #[test]
    #[should_panic(expected = "buffer size mismatch")]
    fn from_vec_checks_size() {
        let _ = MatF32::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn transpose_round_trip() {
        let m = MatI32::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]);
        let t = m.transpose();
        assert_eq!((t.rows(), t.cols()), (3, 2));
        assert_eq!(t.get(0, 1), 4);
        assert_eq!(t.get(2, 0), 3);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn abs_max_and_frobenius() {
        let m = MatF32::from_vec(1, 4, vec![3.0, -4.0, 0.0, 2.0]);
        assert_eq!(m.abs_max(), 4.0);
        assert!((m.frobenius() - (29.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn allclose_tolerances() {
        let a = MatF32::from_vec(1, 2, vec![1.0, 100.0]);
        let b = MatF32::from_vec(1, 2, vec![1.0005, 100.05]);
        assert!(a.allclose(&b, 1e-3, 1e-3));
        assert!(!a.allclose(&b, 1e-5, 1e-6));
        let c = MatF32::zeros(2, 1);
        assert!(!a.allclose(&c, 1.0, 1.0)); // shape mismatch
    }

    #[test]
    fn f16_round_trip_precision() {
        let m = MatF32::from_vec(1, 3, vec![0.5, -1.25, 1000.0]);
        let back = m.to_f16().to_f32();
        assert!(m.allclose(&back, 1e-6, 1e-3));
    }

    #[test]
    fn map_changes_type() {
        let m = MatF32::from_vec(1, 3, vec![1.4, -2.6, 3.5]);
        let q: MatI8 = m.map(|x| x.round() as i8);
        assert_eq!(q.as_slice(), &[1, -3, 4]);
    }

    #[test]
    fn split_rows_mut_disjoint() {
        let mut m = MatI32::from_vec(3, 2, vec![1, 2, 3, 4, 5, 6]);
        let (top, bottom) = m.split_rows_mut(1);
        assert_eq!(top, &[1, 2]);
        assert_eq!(bottom, &[3, 4, 5, 6]);
        top[0] = 10;
        bottom[0] = 30;
        assert_eq!(m.get(0, 0), 10);
        assert_eq!(m.get(1, 0), 30);
    }
}
