//! Numerically stable floating-point softmax (paper eq. 6) — the operator
//! inside the FP32, FP16 and Quant-Only baselines, written the way an edge
//! runtime would: row-wise max subtraction, `exp`, row-sum, divide.
//!
//! The FP16 variant rounds inputs, intermediates and outputs through binary16
//! precision to model a native half-precision unit (see DESIGN.md §2).

use crate::softmax::index_softmax::Mask;
use crate::tensor::MatF32;
use crate::util::f16::round_f32_to_f16;

/// In-place stable softmax over each row of `x` (eq. 6). Masked-out columns
/// are set to exactly 0.
pub fn softmax_rows(x: &mut MatF32, mask: Mask) {
    let l = x.cols();
    for r in 0..x.rows() {
        let valid = mask.valid_cols(r, l);
        let row = x.row_mut(r);
        let m = row[..valid].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for v in row[..valid].iter_mut() {
            // Cut off deep-underflow exponents: exp(-80) ≈ 1.8e-35 is below
            // any representable contribution to the row sum, and letting it
            // through produces subnormal probabilities that cost ~100× per
            // op downstream on x86 (real edge kernels run FTZ/DAZ instead).
            let diff = *v - m;
            *v = if diff < -80.0 { 0.0 } else { diff.exp() };
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row[..valid].iter_mut() {
            *v *= inv;
        }
        for v in row[valid..].iter_mut() {
            *v = 0.0;
        }
    }
}

/// In-place stable softmax of one fully-valid row over a plain slice (the
/// decode hot path — a decode row attends to its whole history, so no mask
/// argument and no matrix wrapper). Identical arithmetic, in identical
/// order, to [`softmax_rows`] on the same data as a `1×L` matrix.
pub fn softmax_row(row: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for v in row.iter_mut() {
        let diff = *v - m;
        *v = if diff < -80.0 { 0.0 } else { diff.exp() };
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Stable softmax with every elementary result rounded to f16 precision —
/// the FP16 pipeline's softmax stage. The max subtraction happens *before*
/// rounding (as real FP16 kernels do): the difference is ≤ 0, so `exp` and
/// everything after it stay inside the binary16 range even when the raw
/// logits overflow it.
pub fn softmax_rows_f16(x: &mut MatF32, mask: Mask) {
    let l = x.cols();
    for r in 0..x.rows() {
        let valid = mask.valid_cols(r, l);
        let row = x.row_mut(r);
        let m = row[..valid].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for v in row[..valid].iter_mut() {
            *v = round_f32_to_f16((round_f32_to_f16(*v - m)).exp());
            sum += *v;
        }
        sum = round_f32_to_f16(sum);
        let inv = round_f32_to_f16(1.0 / sum);
        for v in row[..valid].iter_mut() {
            *v = round_f32_to_f16(*v * inv);
        }
        for v in row[valid..].iter_mut() {
            *v = 0.0;
        }
    }
}

/// Softmax of `alpha·Â` given INT32 logits, i.e. the dequantize→softmax step
/// of the Quant-Only pipeline fused for evaluation convenience. Returns a
/// fresh matrix; the production Quant-Only pipeline keeps the stages separate
/// so each can be timed (see `attention::quant_only`).
pub fn softmax_of_scaled_logits(
    logits: &crate::tensor::MatI32,
    alpha: f32,
    mask: Mask,
) -> MatF32 {
    let mut x = logits.map(|v| v as f32 * alpha);
    softmax_rows(&mut x, mask);
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn rows_sum_to_one() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut x = MatF32::from_vec(4, 64, (0..256).map(|_| rng.normal_ms(0.0, 3.0)).collect());
        softmax_rows(&mut x, Mask::None);
        for r in 0..4 {
            let s: f32 = x.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
            assert!(x.row(r).iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn stable_under_huge_logits() {
        let mut x = MatF32::from_vec(1, 3, vec![1e30, 1e30 - 1.0, -1e30]);
        softmax_rows(&mut x, Mask::None);
        assert!(x.as_slice().iter().all(|v| v.is_finite()));
        let s: f32 = x.row(0).iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn causal_mask_zeroes_future() {
        let mut rng = Pcg64::seed_from_u64(2);
        let mut x = MatF32::from_vec(5, 5, (0..25).map(|_| rng.normal()).collect());
        softmax_rows(&mut x, Mask::Causal);
        for r in 0..5 {
            for c in 0..5 {
                if c > r {
                    assert_eq!(x.get(r, c), 0.0);
                }
            }
            let s: f32 = x.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn row_form_bit_identical_to_matrix_form() {
        let mut rng = Pcg64::seed_from_u64(4);
        for l in [1usize, 9, 77] {
            let data: Vec<f32> = (0..l).map(|_| rng.normal_ms(0.0, 5.0)).collect();
            let mut want = MatF32::from_vec(1, l, data.clone());
            softmax_rows(&mut want, Mask::None);
            let mut row = data;
            softmax_row(&mut row);
            assert_eq!(&row[..], want.as_slice(), "l={l}");
        }
    }

    #[test]
    fn order_preserved() {
        let mut x = MatF32::from_vec(1, 4, vec![1.0, 3.0, 2.0, -1.0]);
        softmax_rows(&mut x, Mask::None);
        let r = x.row(0);
        assert!(r[1] > r[2] && r[2] > r[0] && r[0] > r[3]);
    }

    #[test]
    fn f16_variant_close_to_f32() {
        let mut rng = Pcg64::seed_from_u64(3);
        let data: Vec<f32> = (0..128).map(|_| rng.normal_ms(0.0, 2.0)).collect();
        let mut a = MatF32::from_vec(2, 64, data.clone());
        let mut b = MatF32::from_vec(2, 64, data);
        softmax_rows(&mut a, Mask::None);
        softmax_rows_f16(&mut b, Mask::None);
        let cos = crate::util::stats::cosine_similarity(a.as_slice(), b.as_slice());
        assert!(cos > 0.9999, "cos={cos}");
        // but not bit-identical — f16 rounding must actually happen
        assert!(a.as_slice() != b.as_slice());
    }

    #[test]
    fn scaled_logits_path_matches_manual() {
        let logits = crate::tensor::MatI32::from_vec(1, 3, vec![100, 200, 50]);
        let alpha = 0.01;
        let p = softmax_of_scaled_logits(&logits, alpha, Mask::None);
        let mut manual = MatF32::from_vec(1, 3, vec![1.0, 2.0, 0.5]);
        softmax_rows(&mut manual, Mask::None);
        assert!(p.allclose(&manual, 1e-6, 1e-5));
    }
}
