//! Softermax baseline (Stevens et al., DAC 2021) — the hardware-co-design
//! softmax family the paper surveys in §2.3: replace `e^x` with `2^x` so
//! exponentiation becomes an integer shift plus a small fractional
//! correction, and normalize with fixed-point arithmetic.
//!
//! Implemented here as a third comparator for the softmax-ablation studies:
//! like IndexSoftmax it avoids `exp()`, but unlike IndexSoftmax it needs a
//! per-element shift + polynomial rather than a single table gather, and the
//! paper's point stands — it was designed for dedicated accelerator logic,
//! not commodity integer SIMD.

use crate::softmax::index_softmax::Mask;
use crate::tensor::{MatF32, MatI32, MatU8};

/// Softermax operator over INT32 logits (same interface as the other
/// integer softmax operators so it can slot into the ablation benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct Softermax;

/// Fixed-point fractional `2^f` for `f ∈ [0, 1)` in Q8: a 2-term
/// minimax-ish polynomial `2^f ≈ 1 + f·(0.6565 + 0.3435·f)` — max abs error
/// ≈ 0.3 % over the interval, matching Softermax's low-order correction.
#[inline]
fn pow2_frac_q8(frac_q8: u32) -> u32 {
    // all in Q8 fixed point
    let f = frac_q8 & 0xFF;
    let c1 = 168; // 0.6565 in Q8
    let c2 = 88;  // 0.3435 in Q8
    let poly = c1 + ((c2 * f) >> 8);
    256 + ((f * poly) >> 8)
}

impl Softermax {
    /// `P̂ = round(255 · 2^(α̂·(Â−m)) / Σ 2^(α̂·(Â−m)))` where `α̂ = α·log2 e`
    /// folds the base conversion into the scale. The `2^x` evaluation is an
    /// integer shift by the integer part plus the Q8 fractional correction.
    pub fn forward(&self, logits: &MatI32, alpha: f32, mask: Mask) -> MatU8 {
        assert!(alpha > 0.0);
        let l = logits.cols();
        let mut out = MatU8::zeros(logits.rows(), l);
        // Per-element exponent in Q8: x_q8 = (m − a)·alpha·log2(e)·256,
        // computed with one fixed-point multiplier per tensor.
        let scale_q8 = (alpha as f64 * std::f64::consts::LOG2_E * 256.0 * 65536.0) as u64; // Q8<<16
        // One scratch row reused across rows — every element is written
        // before it is read, so no per-row clear (or per-row alloc) needed.
        let mut scratch = vec![0u32; l];
        for r in 0..logits.rows() {
            let valid = mask.valid_cols(r, l);
            let row = &logits.row(r)[..valid];
            let m = *row.iter().max().expect("non-empty row") as i64;
            // 2^(−x) in Q24 per element; sum in Q24.
            let vals = &mut scratch[..valid];
            let mut sum: u64 = 0;
            for (o, &a) in vals.iter_mut().zip(row) {
                let delta = (m - a as i64) as u64;
                let x_q8 = (delta.saturating_mul(scale_q8)) >> 16; // Q8
                let int_part = (x_q8 >> 8) as u32;
                if int_part >= 24 {
                    *o = 0; // below Q24 resolution — the 2^x sparsity
                } else {
                    let frac = pow2_frac_q8(x_q8 as u32); // 2^frac in Q8, [256, 512)
                    // 2^(−x) = 2^(−int) · 2^(−frac) = (2^8/frac) scaled:
                    // represent as Q24: (1<<24) >> int_part, then divide by
                    // the fractional factor (frac/256).
                    *o = ((1u64 << 32) / frac as u64 >> int_part) as u32;
                }
                sum += *o as u64;
            }
            let out_row = out.row_mut(r);
            for (o, &v) in out_row[..valid].iter_mut().zip(vals.iter()) {
                *o = (((255 * v as u64) * 2 + sum) / (2 * sum)) as u8;
            }
            for o in out_row[valid..].iter_mut() {
                *o = 0;
            }
        }
        out
    }

    /// Float view for fidelity metrics.
    pub fn forward_probs_f32(&self, logits: &MatI32, alpha: f32, mask: Mask) -> MatF32 {
        self.forward(logits, alpha, mask).map(|v| v as f32 / 255.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::index_softmax::IndexSoftmax;
    use crate::util::prng::Pcg64;

    fn gaussian_logits(rng: &mut Pcg64, rows: usize, cols: usize, std: f32) -> MatI32 {
        MatI32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal_ms(0.0, std) as i32).collect(),
        )
    }

    fn exact_probs(logits: &MatI32, alpha: f32) -> Vec<f32> {
        let mut out = Vec::new();
        for r in 0..logits.rows() {
            let f: Vec<f32> = logits.row(r).iter().map(|&a| a as f32 * alpha).collect();
            let m = f.iter().cloned().fold(f32::MIN, f32::max);
            let e: Vec<f32> = f.iter().map(|&x| (x - m).exp()).collect();
            let z: f32 = e.iter().sum();
            out.extend(e.iter().map(|&x| x / z));
        }
        out
    }

    #[test]
    fn pow2_frac_endpoints() {
        // 2^0 = 1.0 (Q8 = 256); 2^(255/256) ≈ 1.9946 (Q8 ≈ 511).
        assert_eq!(pow2_frac_q8(0), 256);
        let hi = pow2_frac_q8(255);
        assert!((500..=512).contains(&hi), "hi={hi}");
        // Monotone over the interval.
        let mut prev = 0;
        for f in 0..=255 {
            let v = pow2_frac_q8(f);
            assert!(v >= prev);
            prev = v;
        }
    }

    #[test]
    fn rows_sum_close_to_255() {
        let mut rng = Pcg64::seed_from_u64(1);
        let sm = Softermax;
        let logits = gaussian_logits(&mut rng, 8, 64, 400.0);
        let p = sm.forward(&logits, 0.004, Mask::None);
        for r in 0..8 {
            let s: i32 = p.row(r).iter().map(|&x| x as i32).sum();
            assert!((s - 255).abs() <= 20, "row {r} sum {s}");
        }
    }

    #[test]
    fn tracks_exact_softmax() {
        let mut rng = Pcg64::seed_from_u64(2);
        let sm = Softermax;
        let logits = gaussian_logits(&mut rng, 4, 256, 400.0);
        let p = sm.forward_probs_f32(&logits, 0.004, Mask::None);
        let want = exact_probs(&logits, 0.004);
        let cos = crate::util::stats::cosine_similarity(p.as_slice(), &want);
        assert!(cos > 0.98, "cos={cos}");
    }

    #[test]
    fn max_logit_dominates() {
        let sm = Softermax;
        let logits = MatI32::from_vec(1, 4, vec![5000, 100, 0, -400]);
        let p = sm.forward(&logits, 0.002, Mask::None);
        assert!(p.get(0, 0) > 200, "{:?}", p.row(0));
        assert_eq!(p.get(0, 3), 0);
    }

    #[test]
    fn causal_mask_respected() {
        let mut rng = Pcg64::seed_from_u64(3);
        let sm = Softermax;
        let logits = gaussian_logits(&mut rng, 5, 5, 300.0);
        let p = sm.forward(&logits, 0.004, Mask::Causal);
        for r in 0..5 {
            for c in (r + 1)..5 {
                assert_eq!(p.get(r, c), 0);
            }
        }
        assert_eq!(p.get(0, 0), 255);
    }

    #[test]
    fn comparable_fidelity_to_index_softmax_on_peaked_rows() {
        // Softermax's 2^x with polynomial correction is a *finer* pointwise
        // approximation than a 32-entry LUT; IndexSoftmax wins on cost, not
        // accuracy. Verify Softermax is at least in the same fidelity class.
        let mut rng = Pcg64::seed_from_u64(4);
        let logits = gaussian_logits(&mut rng, 8, 128, 500.0);
        let want = exact_probs(&logits, 0.004);
        let p_sm = Softermax.forward_probs_f32(&logits, 0.004, Mask::None);
        let p_ix = IndexSoftmax::default().forward_probs_f32(&logits, 0.004, Mask::None);
        let cos_sm = crate::util::stats::cosine_similarity(p_sm.as_slice(), &want);
        let cos_ix = crate::util::stats::cosine_similarity(p_ix.as_slice(), &want);
        assert!(cos_sm > 0.99, "softermax cos={cos_sm}");
        assert!(cos_ix > 0.99, "indexsoftmax cos={cos_ix}");
    }

    #[test]
    fn degenerate_uniform_rows() {
        let sm = Softermax;
        let logits = MatI32::from_vec(1, 8, vec![7; 8]);
        let p = sm.forward(&logits, 0.01, Mask::None);
        assert!(p.row(0).iter().all(|&v| (v as i32 - 32).abs() <= 1));
    }
}
