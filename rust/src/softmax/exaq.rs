//! EXAQ baseline (Shkolnik et al., NeurIPS-W 2024) — the closest LUT-only
//! comparator the paper ablates against (Tables 4–7, Figure 5).
//!
//! EXAQ quantizes the softmax *input* to ultra-low bit width (`b ∈ {2, 3}` →
//! 4 or 8 LUT entries) and picks the clipping range **dynamically** from
//! per-tensor statistics (a multiple of the logit standard deviation), which
//! costs an extra global reduction pass per tensor — exactly the overhead
//! IndexSoftmax's fixed `(b, c)` avoids (§3.1 "Among LUT-only methods...").
//!
//! Implementation notes: we follow the paper's characterization of EXAQ —
//! dynamic std-based clipping + a `2^b`-entry exponential LUT + high-precision
//! (f32) normalization; the normalization staying in float is what keeps
//! EXAQ's dataflow "mixed-precision" (§2.3).

use crate::softmax::index_softmax::Mask;
use crate::tensor::{MatF32, MatI32, MatU8};

/// EXAQ configuration: LUT resolution bits and the std multiplier for the
/// dynamic clipping range.
#[derive(Clone, Copy, Debug)]
pub struct ExaqConfig {
    /// 2 or 3 in the paper's ablation (INT2/INT3).
    pub bits: u32,
    /// Clipping range = `k_std · σ(Δ)`; EXAQ derives the optimal multiplier
    /// analytically — 3.0 is representative for attention logits.
    pub k_std: f32,
}

impl ExaqConfig {
    pub fn int2() -> Self {
        ExaqConfig { bits: 2, k_std: 3.0 }
    }
    pub fn int3() -> Self {
        ExaqConfig { bits: 3, k_std: 3.0 }
    }
}

/// The EXAQ softmax operator.
#[derive(Clone, Debug)]
pub struct ExaqSoftmax {
    pub cfg: ExaqConfig,
}

impl ExaqSoftmax {
    pub fn new(cfg: ExaqConfig) -> Self {
        assert!((1..=8).contains(&cfg.bits));
        ExaqSoftmax { cfg }
    }

    /// Number of LUT entries (`2^bits`).
    pub fn entries(&self) -> usize {
        1 << self.cfg.bits
    }

    /// Bytes of LUT storage at f32 resolution — EXAQ's tables are small
    /// enough that the paper compares *entry counts* under a 32 B budget
    /// (Fig. 5): INT3 → 8 entries × 4 B = 32 B.
    pub fn lut_bytes_f32(&self) -> usize {
        self.entries() * 4
    }

    /// Raw Δ statistics of one logit block: `(Σδ, Σδ², count)` of the
    /// α-scaled max-subtracted distances over the mask-valid entries. The
    /// one-shot path reduces these immediately; the stateful decode path
    /// merges them into the running per-sequence accumulator
    /// (`attention::state::ExaqRunningStats`) so the clip range stays O(1)
    /// per token instead of re-scanning history.
    pub fn delta_stats(logits: &MatI32, alpha: f32, mask: Mask) -> (f64, f64, u64) {
        let l = logits.cols();
        let mut n = 0u64;
        let mut sum = 0f64;
        let mut sumsq = 0f64;
        for r in 0..logits.rows() {
            let valid = mask.valid_cols(r, l);
            let row = &logits.row(r)[..valid];
            let m = *row.iter().max().expect("non-empty") as i64;
            for &a in row {
                let d = (m - a as i64) as f64 * alpha as f64;
                sum += d;
                sumsq += d * d;
                n += 1;
            }
        }
        (sum, sumsq, n)
    }

    /// Clip range from a Δ standard deviation: `k_std·σ`, floored away from
    /// zero for degenerate all-equal inputs.
    pub fn clip_from_sigma(&self, sigma: f32) -> f32 {
        (self.cfg.k_std * sigma).max(1e-3)
    }

    /// The dynamic clipping statistic: std-dev of the max-subtracted
    /// distances `Δ = m − a` over the whole tensor (the "global reduction
    /// and control overhead" IndexSoftmax eliminates).
    pub fn dynamic_clip(&self, logits: &MatI32, alpha: f32, mask: Mask) -> f32 {
        let (sum, sumsq, n) = Self::delta_stats(logits, alpha, mask);
        let mean = sum / n as f64;
        let var = (sumsq / n as f64 - mean * mean).max(0.0);
        self.clip_from_sigma(var.sqrt() as f32)
    }

    /// Forward: INT32 logits → UINT8 probabilities (so the output interface
    /// matches IndexSoftmax for pipeline plug-compatibility), but internally
    /// the normalization runs in f32 — EXAQ's mixed-precision dataflow.
    pub fn forward(&self, logits: &MatI32, alpha: f32, mask: Mask) -> MatU8 {
        let clip = self.dynamic_clip(logits, alpha, mask);
        self.forward_with_clip(logits, alpha, mask, clip)
    }

    /// f32 LUT over `[0, clip]`: `LUT[i] = exp(−clip·i/(n−1))`, last entry 0.
    /// Rebuilt whenever the dynamic clip moves (the per-tensor overhead the
    /// paper charges EXAQ for); shared by the two-pass and fused paths.
    pub fn lut_f32(&self, clip: f32) -> Vec<f32> {
        let clip = clip.max(1e-3);
        let n = self.entries();
        (0..n)
            .map(|i| {
                if i == n - 1 {
                    0.0
                } else {
                    (-(clip * i as f32 / (n - 1) as f32)).exp()
                }
            })
            .collect()
    }

    /// Forward with an externally supplied clip range (the stateful decode
    /// path derives it from running statistics rather than this block's).
    pub fn forward_with_clip(&self, logits: &MatI32, alpha: f32, mask: Mask, clip: f32) -> MatU8 {
        self.forward_with_clip_counted(logits, alpha, mask, clip).0
    }

    /// [`Self::forward_with_clip`] that also reports the nonzero-`P̂` count
    /// (the PV GEMM's exact work) so pipelines never re-scan the matrix.
    pub fn forward_with_clip_counted(
        &self,
        logits: &MatI32,
        alpha: f32,
        mask: Mask,
        clip: f32,
    ) -> (MatU8, u64) {
        let clip = clip.max(1e-3);
        let n = self.entries();
        let lut = self.lut_f32(clip);
        let l = logits.cols();
        let mut out = MatU8::zeros(logits.rows(), l);
        let clip_int = (clip / alpha).max(1.0);
        let mut nnz = 0u64;
        for r in 0..logits.rows() {
            let valid = mask.valid_cols(r, l);
            let row = &logits.row(r)[..valid];
            let m = *row.iter().max().unwrap() as i64;
            // Gather + float row sum.
            let mut e = vec![0f32; valid];
            let mut sum = 0f32;
            for (ev, &a) in e.iter_mut().zip(row) {
                let delta = (m - a as i64) as f32;
                let idx = ((delta / clip_int * (n - 1) as f32).round() as usize).min(n - 1);
                *ev = lut[idx];
                sum += *ev;
            }
            // Float normalization, then ×255 requantization of P.
            let inv = 1.0 / sum;
            let out_row = out.row_mut(r);
            for (o, &ev) in out_row[..valid].iter_mut().zip(&e) {
                let p = (ev * inv * 255.0).round().clamp(0.0, 255.0) as u8;
                *o = p;
                nnz += (p != 0) as u64;
            }
        }
        (out, nnz)
    }

    /// Δ statistics of one fully-valid row — the unfused decode hot path's
    /// slice-level [`Self::delta_stats`] (bit-identical accumulation order
    /// to a `1×L` matrix under `Mask::None`).
    pub fn delta_stats_row(row: &[i32], alpha: f32) -> (f64, f64, u64) {
        let m = *row.iter().max().expect("non-empty row") as i64;
        let mut sum = 0f64;
        let mut sumsq = 0f64;
        for &a in row {
            let d = (m - a as i64) as f64 * alpha as f64;
            sum += d;
            sumsq += d * d;
        }
        (sum, sumsq, row.len() as u64)
    }

    /// Single-row forward over a plain slice (the unfused decode hot path —
    /// a decode row is fully valid, so no mask argument). Writes `P̂` into
    /// `out` and returns the nonzero count, so callers never re-scan for op
    /// accounting. `lut` must come from [`Self::lut_f32`] at the same clip.
    pub fn forward_row_with_clip(
        &self,
        row: &[i32],
        alpha: f32,
        clip: f32,
        lut: &[f32],
        out: &mut [u8],
    ) -> u64 {
        assert_eq!(row.len(), out.len());
        let n = self.entries();
        debug_assert_eq!(lut.len(), n);
        let clip_int = (clip.max(1e-3) / alpha).max(1.0);
        let m = *row.iter().max().expect("non-empty row") as i64;
        let mut sum = 0f32;
        for (o, &a) in out.iter_mut().zip(row) {
            let delta = (m - a as i64) as f32;
            let idx = ((delta / clip_int * (n - 1) as f32).round() as usize).min(n - 1);
            // Stash the gather index; the normalize pass re-gathers — same
            // two-pass structure as forward_with_clip without a float row.
            *o = idx as u8;
            sum += lut[idx];
        }
        let inv = 1.0 / sum;
        let mut nnz = 0u64;
        for o in out.iter_mut() {
            let p = (lut[*o as usize] * inv * 255.0).round().clamp(0.0, 255.0) as u8;
            *o = p;
            nnz += (p != 0) as u64;
        }
        nnz
    }

    /// Begin a streamed row for the fused decode walk: a two-phase,
    /// bucketed online softmax over the EXAQ LUT plus **exact** integer
    /// Δ-moment accounting about the row max, so the per-sequence running
    /// statistics (and thus the next dynamic clip) come out of the same
    /// page walk.
    pub fn online_begin(&self, alpha: f32, clip: f32) -> ExaqOnlineRow {
        ExaqOnlineRow {
            clip_int: (clip.max(1e-3) / alpha).max(1.0),
            entries: self.entries(),
            m: 0,
            started: false,
            counts: [0; ExaqOnlineRow::MAX_ENTRIES],
            n: 0,
            dsum: 0,
            dsumsq: 0,
        }
    }

    /// Float view (`P̂/255`) for fidelity metrics.
    pub fn forward_probs_f32(&self, logits: &MatI32, alpha: f32, mask: Mask) -> MatF32 {
        self.forward(logits, alpha, mask).map(|v| v as f32 / 255.0)
    }
}

/// Streaming row state for EXAQ's fused decode walk, operated in two
/// phases like `OnlineIndexRow` (max phase, then gather phase) so that
/// partial states over disjoint page spans merge byte-identically. The
/// gather phase is *bucketed*: the EXAQ LUT holds at most
/// [`Self::MAX_ENTRIES`] distinct values, so each element only records its
/// LUT bucket — per-bucket counts here, per-bucket integer `V̂` lane sums
/// in the caller's accumulator — and the float combine
/// `Σ_t LUT[t]·(count_t, acc_t)` happens once, in fixed ascending-bucket
/// order, after every span has merged. Bucket counts, lane sums and the
/// Δ-moments `(n, ΣΔ, ΣΔ²)` about the row max are all plain integer adds,
/// so any split of the walk produces the same bytes; [`Self::stats`]
/// reproduces `delta_stats` semantics from the same walk with exact
/// integer arithmetic where the two-pass form sums rounded f64 terms.
#[derive(Clone, Copy, Debug)]
pub struct ExaqOnlineRow {
    clip_int: f32,
    entries: usize,
    m: i32,
    started: bool,
    counts: [u64; ExaqOnlineRow::MAX_ENTRIES],
    n: u64,
    dsum: i128,
    dsumsq: i128,
}

impl ExaqOnlineRow {
    /// Largest LUT the online form supports (int3 → 8 entries).
    pub const MAX_ENTRIES: usize = 8;

    /// Max phase: stream one logit, keeping the running row max.
    #[inline]
    pub fn observe_max(&mut self, a: i32) {
        if !self.started || a > self.m {
            self.m = a;
            self.started = true;
        }
    }

    /// Fold another span's max phase into this one (associative and
    /// commutative — every split and merge order yields the same max).
    #[inline]
    pub fn merge_max(&mut self, other: &Self) {
        if other.started {
            self.observe_max(other.m);
        }
    }

    /// Gather phase: classify one logit into its LUT bucket — returned so
    /// the caller can accumulate `V̂` into that bucket's integer lane sums
    /// (skip when it equals [`Self::zero_bucket`]) — updating the bucket
    /// counts and the exact Δ-moments. Requires `a ≤ m`, i.e. the max
    /// phase saw the span first (debug-asserted).
    #[inline]
    pub fn gather(&mut self, a: i32) -> usize {
        debug_assert!(self.started && a <= self.m, "gather before max phase");
        let delta = (self.m as i64 - a as i64) as u64;
        self.dsum += delta as i128;
        self.dsumsq += (delta as i128) * (delta as i128);
        self.n += 1;
        let idx = ((delta as f32 / self.clip_int * (self.entries - 1) as f32).round()
            as usize)
            .min(self.entries - 1);
        self.counts[idx] += 1;
        idx
    }

    /// Bucket index of the LUT's zero entry: gathers landing there carry
    /// no weight, so callers skip the `V̂` accumulate.
    #[inline]
    pub fn zero_bucket(&self) -> usize {
        self.entries - 1
    }

    /// Merge another span's partial state. Equal maxes only — the
    /// two-phase schedule guarantees them, and unlike the IndexSoftmax
    /// merge a lower-max span's LUT buckets cannot be re-binned exactly.
    /// Bucket counts and moments add as plain integers, so the merge is
    /// associative, commutative and byte-exact; the caller adds the
    /// per-bucket accumulator lanes the same way.
    pub fn merge(&mut self, other: &Self) {
        if !other.started {
            return;
        }
        if !self.started {
            *self = *other;
            return;
        }
        assert_eq!(self.m, other.m, "EXAQ span merge requires equal maxes");
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.n += other.n;
        self.dsum += other.dsum;
        self.dsumsq += other.dsumsq;
    }

    /// Per-bucket element counts (length `entries`).
    #[inline]
    pub fn counts(&self) -> &[u64] {
        &self.counts[..self.entries]
    }

    /// `Σe` of the merged row: the fixed ascending-bucket combine
    /// `Σ_t count_t·LUT[t]` — the same bytes for every split of the walk.
    /// `lut` is [`ExaqSoftmax::lut_f32`] at this row's clip.
    pub fn fsum(&self, lut: &[f32]) -> f32 {
        debug_assert_eq!(lut.len(), self.entries);
        let mut sum = 0f32;
        for (&c, &w) in self.counts[..self.entries].iter().zip(lut) {
            if c != 0 {
                sum += c as f32 * w;
            }
        }
        sum
    }

    /// Elements carrying nonzero weight — everything outside the LUT's
    /// zero bucket (`pv_gemm` op-count basis).
    #[inline]
    pub fn nnz(&self) -> u64 {
        self.n - self.counts[self.entries - 1]
    }

    /// The row's Δ-statistics in [`ExaqSoftmax::delta_stats`] units
    /// (`(Σδ·α, Σδ²·α², n)`), for merging into the running per-sequence
    /// accumulator after the walk.
    pub fn stats(&self, alpha: f32) -> (f64, f64, u64) {
        let a = alpha as f64;
        (self.dsum as f64 * a, self.dsumsq as f64 * a * a, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::index_softmax::{IndexSoftmax, Mask};
    use crate::util::prng::Pcg64;

    fn gaussian_logits(rng: &mut Pcg64, rows: usize, cols: usize, std: f32) -> MatI32 {
        MatI32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal_ms(0.0, std) as i32).collect(),
        )
    }

    fn exact_softmax_probs(logits: &MatI32, alpha: f32) -> Vec<f32> {
        let mut out = Vec::with_capacity(logits.len());
        for r in 0..logits.rows() {
            let f: Vec<f32> = logits.row(r).iter().map(|&a| a as f32 * alpha).collect();
            let m = f.iter().cloned().fold(f32::MIN, f32::max);
            let e: Vec<f32> = f.iter().map(|&x| (x - m).exp()).collect();
            let z: f32 = e.iter().sum();
            out.extend(e.iter().map(|&x| x / z));
        }
        out
    }

    #[test]
    fn entry_counts_match_bit_widths() {
        assert_eq!(ExaqSoftmax::new(ExaqConfig::int2()).entries(), 4);
        assert_eq!(ExaqSoftmax::new(ExaqConfig::int3()).entries(), 8);
        // Fig. 5's byte-budget framing: INT3 f32 LUT = 32 B, same budget as
        // our 32-entry u8 LUT.
        assert_eq!(ExaqSoftmax::new(ExaqConfig::int3()).lut_bytes_f32(), 32);
    }

    #[test]
    fn rows_sum_close_to_255() {
        let mut rng = Pcg64::seed_from_u64(1);
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let logits = gaussian_logits(&mut rng, 8, 64, 300.0);
        let p = ex.forward(&logits, 0.004, Mask::None);
        for r in 0..8 {
            let s: i32 = p.row(r).iter().map(|&x| x as i32).sum();
            assert!((s - 255).abs() <= 20, "row {r} sum {s}");
        }
    }

    #[test]
    fn dynamic_clip_is_positive_and_scales_with_spread() {
        let mut rng = Pcg64::seed_from_u64(2);
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let narrow = gaussian_logits(&mut rng, 4, 64, 100.0);
        let wide = gaussian_logits(&mut rng, 4, 64, 1000.0);
        let c_n = ex.dynamic_clip(&narrow, 0.004, Mask::None);
        let c_w = ex.dynamic_clip(&wide, 0.004, Mask::None);
        assert!(c_n > 0.0);
        assert!(c_w > c_n * 3.0, "clip must track spread: {c_n} vs {c_w}");
    }

    #[test]
    fn degenerate_uniform_rows_do_not_crash() {
        let ex = ExaqSoftmax::new(ExaqConfig::int2());
        let logits = MatI32::from_vec(2, 4, vec![7; 8]);
        let p = ex.forward(&logits, 0.01, Mask::None);
        for r in 0..2 {
            let s: i32 = p.row(r).iter().map(|&x| x as i32).sum();
            assert!((s - 255).abs() <= 8, "sum {s}");
        }
    }

    #[test]
    fn int3_beats_int2_and_indexsoftmax_beats_int3() {
        // The ablation ordering of Tables 5–7 at operator level: fidelity
        // (cosine sim to exact softmax) must rank IndexSoftmax > EXAQ-INT3 >
        // EXAQ-INT2 on realistic logits.
        let mut rng = Pcg64::seed_from_u64(3);
        let alpha = 0.004f32;
        let mut cos2 = 0.0;
        let mut cos3 = 0.0;
        let mut cos_ix = 0.0;
        let trials = 12;
        for _ in 0..trials {
            let logits = gaussian_logits(&mut rng, 4, 256, 500.0);
            let p_ref = exact_softmax_probs(&logits, alpha);
            let p2 = ExaqSoftmax::new(ExaqConfig::int2())
                .forward_probs_f32(&logits, alpha, Mask::None);
            let p3 = ExaqSoftmax::new(ExaqConfig::int3())
                .forward_probs_f32(&logits, alpha, Mask::None);
            let pix = IndexSoftmax::default().forward_probs_f32(&logits, alpha, Mask::None);
            cos2 += crate::util::stats::cosine_similarity(p2.as_slice(), &p_ref);
            cos3 += crate::util::stats::cosine_similarity(p3.as_slice(), &p_ref);
            cos_ix += crate::util::stats::cosine_similarity(pix.as_slice(), &p_ref);
        }
        cos2 /= trials as f64;
        cos3 /= trials as f64;
        cos_ix /= trials as f64;
        assert!(cos3 > cos2, "INT3 {cos3} must beat INT2 {cos2}");
        assert!(cos_ix > cos3, "IndexSoftmax {cos_ix} must beat INT3 {cos3}");
        assert!(cos_ix > 0.995, "cos_ix={cos_ix}");
    }

    #[test]
    fn forward_with_clip_round_trips_through_stats() {
        let mut rng = Pcg64::seed_from_u64(5);
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let logits = gaussian_logits(&mut rng, 4, 32, 400.0);
        let alpha = 0.004f32;
        let clip = ex.dynamic_clip(&logits, alpha, Mask::None);
        // Supplying the same clip externally reproduces forward() exactly.
        assert_eq!(
            ex.forward(&logits, alpha, Mask::None),
            ex.forward_with_clip(&logits, alpha, Mask::None, clip)
        );
        // And the raw stats reduce to the same clip value.
        let (s, ss, n) = ExaqSoftmax::delta_stats(&logits, alpha, Mask::None);
        let mean = s / n as f64;
        let sigma = ((ss / n as f64 - mean * mean).max(0.0)).sqrt() as f32;
        assert!((ex.clip_from_sigma(sigma) - clip).abs() < 1e-6);
    }

    #[test]
    fn causal_mask_respected() {
        let mut rng = Pcg64::seed_from_u64(4);
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let logits = gaussian_logits(&mut rng, 5, 5, 400.0);
        let p = ex.forward(&logits, 0.004, Mask::Causal);
        for r in 0..5 {
            for c in (r + 1)..5 {
                assert_eq!(p.get(r, c), 0);
            }
        }
    }

    #[test]
    fn row_forward_bit_identical_to_two_pass_and_counts_nnz() {
        let mut rng = Pcg64::seed_from_u64(6);
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let alpha = 0.004f32;
        for l in [1usize, 7, 64] {
            let logits = gaussian_logits(&mut rng, 1, l, 500.0);
            let clip = 1.7f32;
            let want = ex.forward_with_clip(&logits, alpha, Mask::None, clip);
            let lut = ex.lut_f32(clip);
            let mut out = vec![0u8; l];
            let nnz = ex.forward_row_with_clip(logits.row(0), alpha, clip, &lut, &mut out);
            assert_eq!(&out[..], want.row(0), "l={l}");
            assert_eq!(nnz, out.iter().filter(|&&x| x != 0).count() as u64);
        }
    }

    #[test]
    fn counted_forward_matches_rescan_and_row_stats_match_matrix() {
        let mut rng = Pcg64::seed_from_u64(7);
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let alpha = 0.004f32;
        let logits = gaussian_logits(&mut rng, 6, 40, 500.0);
        let (p, nnz) = ex.forward_with_clip_counted(&logits, alpha, Mask::Causal, 1.5);
        assert_eq!(nnz, p.as_slice().iter().filter(|&&x| x != 0).count() as u64);
        // Slice-level Δ stats reproduce the matrix reduction bit-for-bit on
        // a single fully-valid row.
        let one = gaussian_logits(&mut rng, 1, 33, 500.0);
        assert_eq!(
            ExaqSoftmax::delta_stats_row(one.row(0), alpha),
            ExaqSoftmax::delta_stats(&one, alpha, Mask::None)
        );
    }

    #[test]
    fn online_stats_match_delta_stats_exactly() {
        // Two-phase gather about the global max must equal a direct
        // final-max reduction (delta_stats) to the last bit of the integer
        // sums, however the values are ordered.
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let alpha = 0.004f32;
        let vals = [100i32, -50, 900, 250, 1800, 1800 - 3, -2000];
        let clip = 2.0f32;
        let mut row = ex.online_begin(alpha, clip);
        for &a in &vals {
            row.observe_max(a);
        }
        for &a in &vals {
            let _ = row.gather(a);
        }
        let (sum, sumsq, n) = row.stats(alpha);
        let m = *vals.iter().max().unwrap() as i64;
        let dsum: i64 = vals.iter().map(|&a| m - a as i64).sum();
        let dsumsq: i64 = vals.iter().map(|&a| (m - a as i64).pow(2)).sum();
        assert_eq!(n, vals.len() as u64);
        assert_eq!(sum, dsum as f64 * alpha as f64);
        assert_eq!(sumsq, dsumsq as f64 * (alpha as f64) * (alpha as f64));
    }

    #[test]
    fn online_buckets_match_two_pass_gathers_and_merge_exactly() {
        // Gather indices must equal the two-pass form's, the bucketed fsum
        // must equal the ascending-bucket combine of those gathers, and a
        // span-split walk (merge_max + merge) must reproduce the sequential
        // state byte-for-byte.
        let ex = ExaqSoftmax::new(ExaqConfig::int2());
        let alpha = 0.01f32;
        let vals = [400i32, 500, 100, 480, -100, 20, 499];
        let clip = 3.0f32;
        let lut = ex.lut_f32(clip);
        let mut seq = ex.online_begin(alpha, clip);
        for &a in &vals {
            seq.observe_max(a);
        }
        let idxs: Vec<usize> = vals.iter().map(|&a| seq.gather(a)).collect();
        let clip_int = (clip / alpha).max(1.0);
        let n = ex.entries();
        let mut want_counts = vec![0u64; n];
        for (&a, &got) in vals.iter().zip(&idxs) {
            let delta = (500 - a) as f32;
            let idx = ((delta / clip_int * (n - 1) as f32).round() as usize).min(n - 1);
            assert_eq!(got, idx);
            want_counts[idx] += 1;
        }
        assert_eq!(seq.counts(), &want_counts[..]);
        let want_fsum: f32 =
            want_counts.iter().zip(&lut).map(|(&c, &w)| c as f32 * w).sum();
        assert_eq!(seq.fsum(&lut), want_fsum);
        assert_eq!(seq.nnz(), vals.len() as u64 - want_counts[n - 1]);

        for split in 1..vals.len() {
            let (lo, hi) = vals.split_at(split);
            let mut a = ex.online_begin(alpha, clip);
            let mut b = ex.online_begin(alpha, clip);
            for &x in lo {
                a.observe_max(x);
            }
            for &x in hi {
                b.observe_max(x);
            }
            let mut root = a;
            root.merge_max(&b);
            let (mut a, mut b) = (root, root);
            for &x in lo {
                let _ = a.gather(x);
            }
            for &x in hi {
                let _ = b.gather(x);
            }
            a.merge(&b);
            assert_eq!(a.counts(), seq.counts(), "split {split}");
            assert_eq!(a.stats(alpha), seq.stats(alpha), "split {split}");
            assert_eq!(a.fsum(&lut).to_bits(), seq.fsum(&lut).to_bits(), "split {split}");
        }
    }
}
