//! The softmax-path operators — where the paper's contribution lives.
//!
//! * [`lut`] — lookup-table construction (paper eq. 10 and eq. 13).
//! * [`index_softmax`] — **IndexSoftmax**: integer-domain clipping, LUT
//!   exponentiation and integer scale normalization (paper eq. 7–15, §3.1–3.2).
//! * [`float_softmax`] — numerically stable FP32/FP16 softmax (paper eq. 6),
//!   the baseline operator in the FP32/FP16/Quant-Only pipelines.
//! * [`exaq`] — the EXAQ comparator (Shkolnik et al. 2024): ultra-low-bit
//!   LUT (INT2/INT3) with dynamic, statistics-driven clipping.
//! * [`softermax`] — the hardware-co-design comparator (Stevens et al.
//!   2021): `2^x` via shift + fixed-point fractional correction.

pub mod lut;
pub mod index_softmax;
pub mod float_softmax;
pub mod exaq;
pub mod softermax;

pub use index_softmax::{IndexSoftmax, IndexSoftmaxConfig};
pub use lut::{ExpLut, DEFAULT_B, DEFAULT_C};
