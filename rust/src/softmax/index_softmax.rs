//! **IndexSoftmax** — the paper's integer-domain softmax surrogate
//! (§3.1–3.2, eq. 7–15).
//!
//! Pipeline per row of the INT32 logit matrix `Â = Q̂K̂ᵀ`:
//!
//! 1. `Δ̂ = rowMax(Â) − Â` — nonnegative distances from the row max (eq. 7;
//!    the paper's `m − A` sign convention keeps `exp(−x)` arguments in
//!    `[0, c]`).
//! 2. Clip: `Δ̂' = min(Δ̂, c_int)` with `c_int = round(c/α)`, `α = s_Q·s_K/√d`
//!    (eq. 8–9). Entries at `c_int` land in the LUT's zero bucket — the
//!    sparsity-aware pruning of Fig. 4.
//! 3. Index: `idx = round(Δ̂'·(2^b−1)/c_int)` (eq. 11), computed with an
//!    exact multiply–shift division (no hardware divide on the hot path).
//! 4. Gather: `Ê = LÛT[idx]` from the UINT8 table (eq. 13–14).
//! 5. Normalize in integers: `P̂ = round(255·Ê / rowSum Ê)` with a widened
//!    accumulator (eq. 15).
//!
//! No floating-point operation occurs between the INT32 logits and the UINT8
//! probability matrix. The only float input is the *scalar* `α`, used once
//! per tensor (or per group, §3.3) to derive `c_int`.
//!
//! ## Online (fused-decode) form
//!
//! The fused decode path walks the KV page list without ever materializing
//! the L-length row, and the page-parallel driver additionally splits that
//! list into spans walked by different workers — so the softmax state must
//! be *mergeable*: partial results over disjoint spans combine in any
//! order with no change to the bytes. [`OnlineIndexRow`] is that state,
//! operated in two phases:
//!
//! * **Max phase** ([`OnlineIndexRow::observe_max`]): stream a span's
//!   logits keeping the running row max. Span maxes combine with
//!   [`OnlineIndexRow::merge_max`] — `max` is associative and commutative,
//!   so every split and merge order yields the same global max.
//! * **Gather phase** ([`OnlineIndexRow::gather`]): with the merged row
//!   max pinned, re-walk the span gathering `Ê = LÛT[idx(m − a)]` exactly
//!   as the two-pass form would (zero-bucket entries skipped — the same
//!   §3.1 sparsity), accumulating the span's `ΣÊ`/`nnz` and handing the
//!   caller each `Ê` for its `Ê·V̂` accumulator lanes.
//!
//! Partial `(max, ΣÊ, acc)` triples combine with [`OnlineIndexRow::merge`].
//! At equal maxes — which the two-phase schedule guarantees, every span
//! having been pinned to the merged global max before gathering — the
//! carry factor is `LÛT[0] = 255` and the merge is a pure integer add:
//! associative, commutative, and byte-identical to the width-1 sequential
//! walk for any split points. The operator also accepts unequal maxes,
//! scaling the lower-max side by `Ê(Δm)/255` — one LUT gather plus one
//! rounded integer multiply per lane ([`rescale_lane_i64`]), the integer
//! analogue of online softmax's `e^{m_old − m_new}` carry factor; that
//! general form composes a LUT-quantized factor and is therefore only
//! ε-accurate, so the drivers never rely on it.
//!
//! The final outputs are produced by a single `round(255·acc / ΣÊ)` per
//! lane ([`OnlineIndexRow::norm_div`]) instead of rounding each `P̂` before
//! the `P̂V̂` sum. That reordering is why the fused path is ε-bounded rather
//! than bit-identical against the two-pass oracle except in degenerate rows
//! (single surviving entry); the exact contract lives in the `attention`
//! module docs and is asserted in `tests/decode_equivalence.rs`.

use crate::softmax::lut::ExpLut;
use crate::tensor::{MatF32, MatI32, MatU8};

/// Exact rounded division by a positive runtime constant via multiply–shift
/// (Granlund–Montgomery): precompute once per row/tensor, then each element
/// costs one widening multiply and a shift — the "add, multiply, shift"
/// primitive set the paper's design goal 3 allows.
#[derive(Clone, Copy, Debug)]
pub struct MulShiftDiv {
    /// u64 magic for the fast path (valid when `wide` is false).
    magic64: u64,
    /// u128 magic for the guaranteed-exact wide path.
    magic128: u128,
    shift64: u32,
    shift128: u32,
    divisor: u64,
    /// Use the u128 path (divisor too large for the proven-exact u64 bound).
    wide: bool,
}

impl MulShiftDiv {
    pub fn new(divisor: u64) -> Self {
        assert!(divisor > 0);
        let l = 64 - (divisor - 1).leading_zeros().min(63); // ceil(log2 d)
        // Wide path: s = 64 + l is exact for every x < 2^64 (Granlund–
        // Montgomery: the error term x·e/(d·2^s) with e < d ≤ 2^l stays
        // below x/2^64 < 1/d's slack) — in u128 arithmetic the x·magic
        // product additionally caps the domain at x < 2^63 (see div_floor).
        let shift128 = 64 + l;
        let magic128 = ((1u128 << shift128) + divisor as u128 - 1) / divisor as u128;
        // Fast u64 path: with s = 31 + l the same argument gives exactness
        // for all x < 2^31, and x·magic ≤ 2^31·2^(s-l+1) = 2^63 fits u64.
        // The `wide` flag pre-selects the u128 path for large divisors;
        // `div_floor` additionally routes any numerator ≥ 2^31 (possible
        // even for fast-path divisors, e.g. delta·n1 with delta near a
        // large c_int) to the u128 path at call time.
        let wide = l > 25;
        let shift64 = 31 + l;
        let magic64 = if wide {
            0
        } else {
            (((1u128 << shift64) + divisor as u128 - 1) / divisor as u128) as u64
        };
        MulShiftDiv { magic64, magic128, shift64, shift128, divisor, wide }
    }

    /// Numerators at or above this bound take the u128 path even when the
    /// divisor qualifies for the u64 fast path: the fast path's exactness
    /// proof (and its u64 headroom) holds only for `x < 2^31`.
    const FAST_PATH_MAX: u64 = 1 << 31;

    /// `floor(x / d)` — exact for every `x < 2^63`. The u64 fast path
    /// serves `x < 2^31`; larger numerators (including
    /// [`Self::div_round`]'s `+d/2` pushing a near-bound `x` over the
    /// line, which previously wrapped silently in release builds) route
    /// to the u128 path. That path's `x·magic` product needs
    /// `x·2^65` ≤ `2^128`, hence the `2^63` domain bound
    /// (debug-asserted; IndexSoftmax numerators stay below ~2^34).
    #[inline]
    pub fn div_floor(&self, x: u64) -> u64 {
        if self.wide || x >= Self::FAST_PATH_MAX {
            debug_assert!(x < (1 << 63), "wide-path numerator bound");
            ((x as u128 * self.magic128) >> self.shift128) as u64
        } else {
            (x.wrapping_mul(self.magic64)) >> self.shift64
        }
    }

    /// `round(x / d)` (ties away from zero, matching `f32::round` on the
    /// nonnegative domain used here). The rounding bias is added *before*
    /// [`Self::div_floor`]'s path selection, so a numerator that crosses
    /// the fast-path bound lands on the wide path instead of wrapping.
    #[inline]
    pub fn div_round(&self, x: u64) -> u64 {
        self.div_floor(x + self.divisor / 2)
    }
}

/// Masking mode for the logit matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mask {
    /// All positions attend to all positions (encoder / vision mode).
    None,
    /// Row `i` attends to columns `0..=i` (decoder prefill mode, square).
    Causal,
    /// Causal masking with a position offset: query row `r` sits at absolute
    /// position `offset + r` and attends to key columns `0..=offset + r`.
    /// This is the chunked-prefill / cached-decode generalization —
    /// `CausalFrom(0)` is identical to [`Mask::Causal`], and a single query
    /// row at offset `L - 1` sees the whole cache (like [`Mask::None`]).
    CausalFrom(usize),
}

impl Mask {
    /// Number of valid columns in row `r` of an `L`-column matrix.
    #[inline]
    pub fn valid_cols(self, r: usize, l: usize) -> usize {
        match self {
            Mask::None => l,
            Mask::Causal => (r + 1).min(l),
            Mask::CausalFrom(offset) => (offset + r + 1).min(l),
        }
    }

    /// The position offset of the first query row (0 unless `CausalFrom`).
    #[inline]
    pub fn offset(self) -> usize {
        match self {
            Mask::CausalFrom(o) => o,
            _ => 0,
        }
    }
}

/// Hyperparameters of IndexSoftmax (paper §4.4 recommends `(b, c) = (5, 6.6)`).
#[derive(Clone, Copy, Debug)]
pub struct IndexSoftmaxConfig {
    pub b: u32,
    pub c: f32,
}

impl Default for IndexSoftmaxConfig {
    fn default() -> Self {
        IndexSoftmaxConfig { b: crate::softmax::lut::DEFAULT_B, c: crate::softmax::lut::DEFAULT_C }
    }
}

/// The IndexSoftmax operator. Construction builds the fixed LUT once; the
/// operator is then reused across rows, heads, layers and requests.
#[derive(Clone, Debug)]
pub struct IndexSoftmax {
    pub cfg: IndexSoftmaxConfig,
    pub lut: ExpLut,
}

impl Default for IndexSoftmax {
    fn default() -> Self {
        Self::new(IndexSoftmaxConfig::default())
    }
}

impl IndexSoftmax {
    pub fn new(cfg: IndexSoftmaxConfig) -> Self {
        IndexSoftmax { cfg, lut: ExpLut::new(cfg.b, cfg.c) }
    }

    /// Quantization-aligned integer clipping threshold (eq. 8):
    /// `c_int = round(c / α)`, clamped to at least 1 so the index mapping is
    /// well defined even for extreme scales.
    pub fn c_int(&self, alpha: f32) -> i32 {
        assert!(alpha > 0.0, "alpha must be positive");
        let c_int = (self.cfg.c / alpha).round();
        c_int.clamp(1.0, i32::MAX as f32) as i32
    }

    /// Full forward: INT32 logits → UINT8 probability matrix `P̂` (rows sum
    /// to ≈255; exactly 0 in masked-out columns).
    pub fn forward(&self, logits: &MatI32, alpha: f32, mask: Mask) -> MatU8 {
        let mut out = MatU8::zeros(logits.rows(), logits.cols());
        let _ = self.forward_into(logits, alpha, mask, &mut out);
        out
    }

    /// Allocation-free forward for the serving hot path. Returns the number
    /// of nonzero `P̂` entries written — the exact PV-GEMM work the §3.1
    /// sparsity leaves behind — so callers never re-scan the matrix for op
    /// accounting.
    pub fn forward_into(&self, logits: &MatI32, alpha: f32, mask: Mask, out: &mut MatU8) -> u64 {
        // AUDIT: int-only begin index-softmax-forward
        assert_eq!((out.rows(), out.cols()), (logits.rows(), logits.cols()));
        let c_int = self.c_int(alpha);
        let l = logits.cols();
        let n1 = self.lut.max_index() as u64;
        // idx = round(Δ'·n1 / c_int): one MulShiftDiv per tensor.
        let idx_div = MulShiftDiv::new(c_int as u64);
        let table = &self.lut.u8_table;
        let mut scratch: Vec<u8> = vec![0; l];
        let mut nnz = 0u64;

        for r in 0..logits.rows() {
            let valid = mask.valid_cols(r, l);
            let row = &logits.row(r)[..valid];
            // (1) row max over valid columns.
            let m = *row.iter().max().expect("non-empty row");
            // (2)–(4) clip, index, gather; accumulate the row sum (eq. 15's
            // widened accumulator: u32 holds 255·L for any L ≤ 16.8M).
            let mut sum: u32 = 0;
            let e_row = &mut scratch[..valid];
            for (e, &a) in e_row.iter_mut().zip(row) {
                // Δ̂ = m − a ≥ 0; saturating guard for adversarial i32 ranges.
                let delta = (m as i64 - a as i64) as u64;
                let v = if delta >= c_int as u64 {
                    // Clipped to the zero bucket — no gather needed.
                    0u8
                } else {
                    let idx = idx_div.div_round(delta * n1) as usize;
                    table[idx]
                };
                *e = v;
                sum += v as u32;
            }
            // (5) integer normalization: P̂ = round(255·Ê / Σ Ê).
            // Σ ≥ 255 always (the max element has Δ=0 → LUT[0]=255), so the
            // division is well defined. One MulShiftDiv per row.
            debug_assert!(sum >= 255);
            let norm_div = MulShiftDiv::new(sum as u64);
            let out_row = out.row_mut(r);
            for (o, &e) in out_row[..valid].iter_mut().zip(e_row.iter()) {
                let p = norm_div.div_round(255 * e as u64) as u8;
                *o = p;
                nnz += (p != 0) as u64;
            }
            for o in out_row[valid..].iter_mut() {
                *o = 0;
            }
        }
        nnz
        // AUDIT: int-only end
    }

    /// Single fully-valid row over plain slices (the unfused decode hot
    /// path — a decode row attends to the whole history, so no mask
    /// argument and no matrix wrapper). Stashes `Ê` in `out`, normalizes in
    /// place, and returns the nonzero-`P̂` count. Bit-identical to
    /// [`Self::forward_into`] on the same row as a `1×L` matrix.
    pub fn forward_row_into(&self, row: &[i32], alpha: f32, out: &mut [u8]) -> u64 {
        // AUDIT: int-only begin index-softmax-row
        assert_eq!(row.len(), out.len());
        let c_int = self.c_int(alpha);
        let n1 = self.lut.max_index() as u64;
        let idx_div = MulShiftDiv::new(c_int as u64);
        let table = &self.lut.u8_table;
        let m = *row.iter().max().expect("non-empty row");
        let mut sum: u32 = 0;
        for (e, &a) in out.iter_mut().zip(row) {
            let delta = (m as i64 - a as i64) as u64;
            let v = if delta >= c_int as u64 {
                0u8
            } else {
                table[idx_div.div_round(delta * n1) as usize]
            };
            *e = v;
            sum += v as u32;
        }
        debug_assert!(sum >= 255);
        let norm_div = MulShiftDiv::new(sum as u64);
        let mut nnz = 0u64;
        for o in out.iter_mut() {
            let p = norm_div.div_round(255 * *o as u64) as u8;
            *o = p;
            nnz += (p != 0) as u64;
        }
        nnz
        // AUDIT: int-only end
    }

    /// Group-wise forward (§3.3, eq. 16–18): `alphas[g]` is `α^(g)` for the
    /// Q-row group of each row (e.g. per-row or per-row-block Q scales); the
    /// LUT is shared, only `c_int^(g)` varies. Also returns the nonzero-`P̂`
    /// count, like [`Self::forward_into`].
    pub fn forward_grouped(
        &self,
        logits: &MatI32,
        row_group: impl Fn(usize) -> usize,
        alphas: &[f32],
        mask: Mask,
    ) -> (MatU8, u64) {
        let mut out = MatU8::zeros(logits.rows(), logits.cols());
        let l = logits.cols();
        let n1 = self.lut.max_index() as u64;
        let table = &self.lut.u8_table;
        // Precompute per-group dividers (eq. 16's only extra bookkeeping).
        let dividers: Vec<(i32, MulShiftDiv)> = alphas
            .iter()
            .map(|&a| {
                let ci = self.c_int(a);
                (ci, MulShiftDiv::new(ci as u64))
            })
            .collect();
        let mut scratch: Vec<u8> = vec![0; l];
        let mut nnz = 0u64;
        for r in 0..logits.rows() {
            let (c_int, idx_div) = dividers[row_group(r)];
            let valid = mask.valid_cols(r, l);
            let row = &logits.row(r)[..valid];
            let m = *row.iter().max().expect("non-empty row");
            let mut sum: u32 = 0;
            let e_row = &mut scratch[..valid];
            for (e, &a) in e_row.iter_mut().zip(row) {
                let delta = (m as i64 - a as i64) as u64;
                let v = if delta >= c_int as u64 {
                    0u8
                } else {
                    table[idx_div.div_round(delta * n1) as usize]
                };
                *e = v;
                sum += v as u32;
            }
            let norm_div = MulShiftDiv::new(sum as u64);
            let out_row = out.row_mut(r);
            for (o, &e) in out_row[..valid].iter_mut().zip(e_row.iter()) {
                let p = norm_div.div_round(255 * e as u64) as u8;
                *o = p;
                nnz += (p != 0) as u64;
            }
        }
        (out, nnz)
    }

    /// Float view of the produced probabilities (`P̂/255`) — used by the
    /// fidelity evaluations, never by the runtime path.
    pub fn forward_probs_f32(&self, logits: &MatI32, alpha: f32, mask: Mask) -> MatF32 {
        self.forward(logits, alpha, mask).map(|v| v as f32 / 255.0)
    }

    /// Begin a streamed row for the fused decode path (see module docs).
    /// One per (sequence, decode step) — or one per page span on the
    /// page-parallel path, the span states combined afterwards with
    /// [`OnlineIndexRow::merge_max`] and [`OnlineIndexRow::merge`].
    pub fn online_begin(&self, alpha: f32) -> OnlineIndexRow {
        let c_int = self.c_int(alpha) as u64;
        OnlineIndexRow {
            c_int,
            n1: self.lut.max_index() as u64,
            idx_div: MulShiftDiv::new(c_int),
            m: 0,
            esum: 0,
            nnz: 0,
            started: false,
        }
    }
}

/// Streaming (online) row state for the fused decode walk: running row max,
/// running `ΣÊ`, and the sparsity accounting the op counters need. Operated
/// in two phases — max, then gather (see the module docs) — so that partial
/// states over disjoint page spans merge exactly. The LUT is passed per
/// [`Self::gather`] so the state stays `'static` and `Copy` and can live
/// inside per-span job descriptors.
#[derive(Clone, Copy, Debug)]
pub struct OnlineIndexRow {
    c_int: u64,
    n1: u64,
    idx_div: MulShiftDiv,
    m: i32,
    esum: u64,
    nnz: u64,
    started: bool,
}

impl OnlineIndexRow {
    /// Max phase: stream one logit, keeping the running row max.
    #[inline]
    pub fn observe_max(&mut self, a: i32) {
        // AUDIT: int-only begin index-softmax-observe-max
        if !self.started || a > self.m {
            self.m = a;
            self.started = true;
        }
        // AUDIT: int-only end
    }

    /// Fold another span's max phase into this one. `max` is associative
    /// and commutative, so every split and merge order yields the same
    /// global max.
    #[inline]
    pub fn merge_max(&mut self, other: &Self) {
        if other.started {
            self.observe_max(other.m);
        }
    }

    /// Gather phase: with the row max pinned, stream one logit and return
    /// its `Ê` weight (0 when clipped or in the LUT's zero bucket — nothing
    /// to accumulate). `table` is the operator's `lut.u8_table`.
    ///
    /// Requires `a ≤ m`, i.e. every logit of the span was first seen by the
    /// max phase (debug-asserted).
    #[inline]
    pub fn gather(&mut self, a: i32, table: &[u8]) -> u8 {
        // AUDIT: int-only begin index-softmax-gather
        debug_assert!(self.started && a <= self.m, "gather before max phase");
        let delta = (self.m as i64 - a as i64) as u64;
        let e = if delta >= self.c_int {
            0
        } else {
            table[self.idx_div.div_round(delta * self.n1) as usize]
        };
        if e != 0 {
            self.esum += e as u64;
            self.nnz += 1;
        }
        e
        // AUDIT: int-only end
    }

    /// Merge another span's partial `(max, ΣÊ, acc)` triple into this one —
    /// the page-parallel combine. At equal maxes (what the two-phase
    /// schedule always produces) the carry factor is `LÛT[0] = 255` and the
    /// merge is a pure integer add — associative, commutative, and
    /// byte-identical to the sequential walk for any split points. With
    /// unequal maxes the lower-max side's `ΣÊ` and lanes are first scaled
    /// by `Ê(Δm)/255` ([`rescale_lane_i64`]); that general form composes a
    /// LUT-quantized factor and is only ε-accurate.
    pub fn merge(&mut self, other: &Self, acc: &mut [i64], other_acc: &[i64], table: &[u8]) {
        // AUDIT: int-only begin index-softmax-merge
        debug_assert_eq!(acc.len(), other_acc.len());
        if !other.started {
            return;
        }
        if !self.started {
            self.started = true;
            self.m = other.m;
            self.esum = other.esum;
            self.nnz = other.nnz;
            acc.copy_from_slice(other_acc);
            return;
        }
        // `nnz` counts accumulated elements (the MACs already spent), so it
        // adds regardless of which side holds the joint max.
        self.nnz += other.nnz;
        let (self_holds_max, dm) = if other.m > self.m {
            (false, (other.m as i64 - self.m as i64) as u64)
        } else {
            (true, (self.m as i64 - other.m as i64) as u64)
        };
        let factor = if dm == 0 {
            255 // LUT[0]: the exact-identity carry of the equal-max case
        } else if dm >= self.c_int {
            0
        } else {
            table[self.idx_div.div_round(dm * self.n1) as usize]
        };
        if self_holds_max {
            if factor == 255 {
                self.esum += other.esum;
                for (x, &y) in acc.iter_mut().zip(other_acc) {
                    *x += y;
                }
            } else {
                self.esum += (other.esum * factor as u64 + 127) / 255;
                for (x, &y) in acc.iter_mut().zip(other_acc) {
                    *x += rescale_lane_i64(y, factor);
                }
            }
        } else {
            self.m = other.m;
            self.esum = (self.esum * factor as u64 + 127) / 255 + other.esum;
            for (x, &y) in acc.iter_mut().zip(other_acc) {
                *x = rescale_lane_i64(*x, factor) + y;
            }
        }
        // AUDIT: int-only end
    }

    /// Running `ΣÊ` (≥ 255 on any state whose span holds the row max, since
    /// the max element gathers `LÛT[0] = 255`).
    #[inline]
    pub fn esum(&self) -> u64 {
        self.esum
    }

    /// Elements accumulated with a nonzero weight — the fused path's
    /// `pv_gemm` op-count basis (each one cost `d` MACs).
    #[inline]
    pub fn nnz(&self) -> u64 {
        self.nnz
    }

    /// Divider for the final `P̂V̂ = round(255·acc / ΣÊ)` normalization —
    /// one per row, like the two-pass form's `norm_div`. Call only on the
    /// fully merged root state (a partial span may hold `ΣÊ < 255`).
    pub fn norm_div(&self) -> MulShiftDiv {
        debug_assert!(self.esum >= 255, "norm_div before the max span was merged");
        MulShiftDiv::new(self.esum)
    }
}

/// `round(x · factor / 255)` on a signed accumulator lane — the integer
/// rescale applied when the running max moves (ties away from zero, the
/// same convention as [`MulShiftDiv::div_round`]).
#[inline]
pub fn rescale_lane_i64(x: i64, factor: u8) -> i64 {
    // AUDIT: int-only begin index-softmax-rescale-lane
    let p = x * factor as i64;
    if p >= 0 {
        (p + 127) / 255
    } else {
        -((-p + 127) / 255)
    }
    // AUDIT: int-only end
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    /// Scalar reference implementing eq. 7–15 with plain `/` and `f32::round`.
    fn reference(logits: &MatI32, alpha: f32, cfg: IndexSoftmaxConfig, mask: Mask) -> MatU8 {
        let lut = ExpLut::new(cfg.b, cfg.c);
        let c_int = ((cfg.c / alpha).round() as i64).max(1);
        let n1 = lut.max_index() as i64;
        let l = logits.cols();
        let mut out = MatU8::zeros(logits.rows(), l);
        for r in 0..logits.rows() {
            let valid = mask.valid_cols(r, l);
            let row = &logits.row(r)[..valid];
            let m = *row.iter().max().unwrap() as i64;
            let e: Vec<u8> = row
                .iter()
                .map(|&a| {
                    let delta = (m - a as i64).min(c_int);
                    // round(delta·n1/c_int), ties away from zero:
                    let idx = (delta * n1 * 2 + c_int) / (2 * c_int);
                    lut.u8_table[idx as usize]
                })
                .collect();
            let sum: i64 = e.iter().map(|&x| x as i64).sum();
            for (c, &ev) in e.iter().enumerate() {
                let p = (255 * ev as i64 * 2 + sum) / (2 * sum);
                out.set(r, c, p as u8);
            }
        }
        out
    }

    fn random_logits(rng: &mut Pcg64, rows: usize, cols: usize, spread: i32) -> MatI32 {
        MatI32::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| rng.range_i64(-(spread as i64), spread as i64 + 1) as i32)
                .collect(),
        )
    }

    #[test]
    fn mulshift_div_matches_hardware_div() {
        let mut rng = Pcg64::seed_from_u64(1);
        // Fast path: d < 2^25, x < 2^31 (minus headroom for div_round's +d/2).
        for _ in 0..500 {
            let d = rng.below(1 << 25).max(1);
            let ms = MulShiftDiv::new(d);
            for _ in 0..20 {
                let x = rng.below((1 << 31) - (1 << 25));
                assert_eq!(ms.div_floor(x), x / d, "x={x} d={d}");
                assert_eq!(ms.div_round(x), (x + d / 2) / d, "x={x} d={d}");
            }
        }
        // Wide path: large divisors, numerators up to 2^45.
        for _ in 0..200 {
            let d = (1 << 25) + rng.below(1 << 40);
            let ms = MulShiftDiv::new(d);
            for _ in 0..20 {
                let x = rng.below(1 << 45);
                assert_eq!(ms.div_floor(x), x / d, "x={x} d={d}");
                assert_eq!(ms.div_round(x), (x + d / 2) / d, "x={x} d={d}");
            }
        }
    }

    #[test]
    fn div_round_exact_across_fast_path_boundary() {
        // Regression: `div_round` adds `d/2` *before* the bound check inside
        // `div_floor`, so numerators just below 2^31 used to cross the
        // fast-path bound and silently wrap in release builds. Both entry
        // points must now be exact on, at, and above the boundary.
        for d in [3u64, 255, (1 << 20) + 7, (1 << 25) - 1] {
            let ms = MulShiftDiv::new(d);
            let xs = [
                (1u64 << 31) - 1 - d / 2, // div_round numerator lands exactly at 2^31 - 1
                (1 << 31) - 1,
                1 << 31,
                (1 << 31) + d,
                (1 << 32) - 1,
                (1 << 33) + 12345, // e.g. delta·n1 with a large c_int
            ];
            for &x in &xs {
                assert_eq!(ms.div_floor(x), x / d, "floor x={x} d={d}");
                assert_eq!(ms.div_round(x), (x + d / 2) / d, "round x={x} d={d}");
            }
        }
    }

    #[test]
    fn large_c_int_index_numerators_are_exact() {
        // An IndexSoftmax-shaped stress of the same bug: with c_int just
        // under the wide-divisor threshold, delta·n1 reaches ~2^33 — far
        // past the u64 fast-path bound — and must still divide exactly.
        let c_int = (1u64 << 25) - 3;
        let ms = MulShiftDiv::new(c_int);
        let n1 = 255u64;
        for delta in [c_int - 1, c_int / 2, c_int / 3 + 1, 1] {
            let x = delta * n1;
            assert_eq!(ms.div_round(x), (x + c_int / 2) / c_int, "delta={delta}");
        }
    }

    #[test]
    fn c_int_formula() {
        let ix = IndexSoftmax::default();
        // α = s_Q·s_K/√d with c=6.6: c_int = round(6.6/α).
        let alpha = 0.001f32;
        assert_eq!(ix.c_int(alpha), 6600);
        // Degenerate huge alpha still yields ≥ 1.
        assert_eq!(ix.c_int(1e9), 1);
    }

    #[test]
    fn matches_scalar_reference_randomized() {
        let mut rng = Pcg64::seed_from_u64(2);
        let ix = IndexSoftmax::default();
        for trial in 0..30 {
            let rows = 1 + rng.below(8) as usize;
            let cols = 1 + rng.below(64) as usize;
            let spread = 1 + rng.below(30_000) as i32;
            let alpha = rng.uniform(1e-5, 0.3);
            let logits = random_logits(&mut rng, rows, cols, spread);
            let got = ix.forward(&logits, alpha, Mask::None);
            let want = reference(&logits, alpha, ix.cfg, Mask::None);
            assert_eq!(got, want, "trial {trial} alpha={alpha}");
        }
    }

    #[test]
    fn rows_sum_close_to_255() {
        let mut rng = Pcg64::seed_from_u64(3);
        let ix = IndexSoftmax::default();
        let logits = random_logits(&mut rng, 16, 128, 20_000);
        let p = ix.forward(&logits, 0.001, Mask::None);
        for r in 0..16 {
            let s: i32 = p.row(r).iter().map(|&x| x as i32).sum();
            // Integer rounding wobbles the sum slightly around 255.
            assert!((s - 255).abs() <= 16, "row {r} sums to {s}");
        }
    }

    #[test]
    fn max_logit_gets_max_probability() {
        let ix = IndexSoftmax::default();
        let logits = MatI32::from_vec(1, 5, vec![10, 5000, 20, -3, 400]);
        let p = ix.forward(&logits, 0.001, Mask::None);
        let row = p.row(0);
        let argmax = row.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        assert_eq!(argmax, 1);
        assert!(row[1] > 200);
    }

    #[test]
    fn clipped_tail_is_exactly_zero() {
        let ix = IndexSoftmax::default();
        // alpha=0.01 → c_int=660; distances ≥ 660 must produce P̂=0, and
        // distances near the top of the range land in the zero bucket too.
        let logits = MatI32::from_vec(1, 4, vec![1000, 900, 341, 0]);
        let p = ix.forward(&logits, 0.01, Mask::None);
        assert_eq!(p.get(0, 3), 0, "distance 1000 ≥ c_int clipped to zero");
        assert_eq!(p.get(0, 2), 0, "distance 659 rounds into the zero bucket");
        assert!(p.get(0, 1) > 0, "distance 100 survives: {:?}", p.row(0));
    }

    #[test]
    fn uniform_logits_give_uniform_probs() {
        let ix = IndexSoftmax::default();
        let logits = MatI32::from_vec(1, 8, vec![42; 8]);
        let p = ix.forward(&logits, 0.001, Mask::None);
        let row = p.row(0);
        assert!(row.iter().all(|&v| v == row[0]));
        // 255/8 ≈ 31.9 → 32 after rounding.
        assert!((row[0] as i32 - 32).abs() <= 1, "{:?}", row);
    }

    #[test]
    fn causal_mask_zeroes_future_positions() {
        let mut rng = Pcg64::seed_from_u64(4);
        let ix = IndexSoftmax::default();
        let logits = random_logits(&mut rng, 6, 6, 10_000);
        let p = ix.forward(&logits, 0.001, Mask::Causal);
        for r in 0..6 {
            for c in 0..6 {
                if c > r {
                    assert_eq!(p.get(r, c), 0, "({r},{c}) must be masked");
                }
            }
            let s: i32 = p.row(r).iter().map(|&x| x as i32).sum();
            assert!((s - 255).abs() <= 16, "row {r} sum {s}");
        }
        // First row attends only to itself.
        assert_eq!(p.get(0, 0), 255);
    }

    #[test]
    fn causal_from_offsets_the_valid_window() {
        let mut rng = Pcg64::seed_from_u64(41);
        let ix = IndexSoftmax::default();
        let logits = random_logits(&mut rng, 3, 8, 10_000);
        // Query rows at absolute positions 5, 6, 7 over 8 keys.
        let p = ix.forward(&logits, 0.001, Mask::CausalFrom(5));
        for r in 0..3 {
            for c in 0..8 {
                if c > 5 + r {
                    assert_eq!(p.get(r, c), 0, "({r},{c}) beyond offset window");
                }
            }
            let s: i32 = p.row(r).iter().map(|&x| x as i32).sum();
            assert!((s - 255).abs() <= 16, "row {r} sum {s}");
        }
        // Offset 0 is exactly the square causal mask.
        let sq = random_logits(&mut rng, 6, 6, 10_000);
        assert_eq!(
            ix.forward(&sq, 0.002, Mask::Causal),
            ix.forward(&sq, 0.002, Mask::CausalFrom(0))
        );
        // A 1-row block at offset L-1 sees everything, like Mask::None.
        let one = random_logits(&mut rng, 1, 7, 10_000);
        assert_eq!(
            ix.forward(&one, 0.002, Mask::None),
            ix.forward(&one, 0.002, Mask::CausalFrom(6))
        );
    }

    #[test]
    fn approximates_float_softmax() {
        // Fidelity: cosine similarity with the exact softmax must be high
        // for realistic attention-logit magnitudes.
        let mut rng = Pcg64::seed_from_u64(5);
        let ix = IndexSoftmax::default();
        let l = 256;
        let alpha = 0.004f32; // typical s_Q·s_K/√d for unit-normal Q,K @ d=64
        let logits = MatI32::from_vec(
            1,
            l,
            (0..l).map(|_| rng.normal_ms(0.0, 400.0) as i32).collect(),
        );
        let p_int = ix.forward_probs_f32(&logits, alpha, Mask::None);
        // exact softmax of alpha-scaled logits:
        let f: Vec<f32> = logits.as_slice().iter().map(|&a| a as f32 * alpha).collect();
        let m = f.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> = f.iter().map(|&x| (x - m).exp()).collect();
        let z: f32 = exps.iter().sum();
        let p_ref: Vec<f32> = exps.iter().map(|&e| e / z).collect();
        let cos = crate::util::stats::cosine_similarity(p_int.as_slice(), &p_ref);
        assert!(cos > 0.985, "cos={cos}");
    }

    #[test]
    fn grouped_matches_per_tensor_when_single_group() {
        let mut rng = Pcg64::seed_from_u64(6);
        let ix = IndexSoftmax::default();
        let logits = random_logits(&mut rng, 8, 32, 15_000);
        let alpha = 0.002;
        let a = ix.forward(&logits, alpha, Mask::None);
        let (b, _) = ix.forward_grouped(&logits, |_| 0, &[alpha], Mask::None);
        assert_eq!(a, b);
    }

    #[test]
    fn grouped_uses_per_group_thresholds() {
        let mut rng = Pcg64::seed_from_u64(7);
        let ix = IndexSoftmax::default();
        let logits = random_logits(&mut rng, 4, 32, 15_000);
        // Two groups with very different alphas must differ from forcing
        // either single alpha everywhere.
        let (grouped, _) = ix.forward_grouped(&logits, |r| r / 2, &[0.001, 0.05], Mask::None);
        let all_a = ix.forward(&logits, 0.001, Mask::None);
        let all_b = ix.forward(&logits, 0.05, Mask::None);
        assert_eq!(grouped.row(0), all_a.row(0));
        assert_eq!(grouped.row(3), all_b.row(3));
        assert_ne!(grouped.row(2), all_a.row(2));
    }

    #[test]
    fn extreme_i32_logits_do_not_overflow() {
        let ix = IndexSoftmax::default();
        let logits = MatI32::from_vec(1, 3, vec![i32::MAX, i32::MIN, 0]);
        let p = ix.forward(&logits, 0.001, Mask::None);
        assert_eq!(p.get(0, 0), 255);
        assert_eq!(p.get(0, 1), 0);
    }

    #[test]
    fn single_column_row_is_certain() {
        let ix = IndexSoftmax::default();
        let logits = MatI32::from_vec(1, 1, vec![-12345]);
        let p = ix.forward(&logits, 0.01, Mask::None);
        assert_eq!(p.get(0, 0), 255);
    }

    #[test]
    fn forward_into_nnz_matches_rescan() {
        let mut rng = Pcg64::seed_from_u64(8);
        let ix = IndexSoftmax::default();
        for mask in [Mask::None, Mask::Causal] {
            let logits = random_logits(&mut rng, 12, 48, 25_000);
            let mut out = MatU8::zeros(12, 48);
            let nnz = ix.forward_into(&logits, 0.001, mask, &mut out);
            let scan = out.as_slice().iter().filter(|&&x| x != 0).count() as u64;
            assert_eq!(nnz, scan, "{mask:?}");
        }
        // Grouped path reports the same count as a rescan, too.
        let logits = random_logits(&mut rng, 6, 32, 25_000);
        let (p, nnz) = ix.forward_grouped(&logits, |r| r / 3, &[0.001, 0.02], Mask::Causal);
        assert_eq!(nnz, p.as_slice().iter().filter(|&&x| x != 0).count() as u64);
    }

    #[test]
    fn row_forward_bit_identical_to_matrix_forward() {
        let mut rng = Pcg64::seed_from_u64(9);
        let ix = IndexSoftmax::default();
        for l in [1usize, 5, 80] {
            let logits = random_logits(&mut rng, 1, l, 20_000);
            let mut want = MatU8::zeros(1, l);
            let want_nnz = ix.forward_into(&logits, 0.0015, Mask::None, &mut want);
            let mut out = vec![0u8; l];
            let nnz = ix.forward_row_into(logits.row(0), 0.0015, &mut out);
            assert_eq!(&out[..], want.row(0), "l={l}");
            assert_eq!(nnz, want_nnz, "l={l}");
        }
    }

    #[test]
    fn online_gather_matches_two_pass_e_values() {
        // Max phase over the whole stream, then gathers: every Ê (and the
        // final ΣÊ) must equal the two-pass form's, in any stream order.
        let ix = IndexSoftmax::default();
        let alpha = 0.002f32;
        let vals = [2000i32, 9000, 8999, -500, 5000, 9000 - 3200];
        let mut row = ix.online_begin(alpha);
        for &a in &vals {
            row.observe_max(a);
        }
        let got_e: Vec<u8> = vals.iter().map(|&a| row.gather(a, &ix.lut.u8_table)).collect();
        // Two-pass reference over the same values.
        let c_int = ix.c_int(alpha) as i64;
        let n1 = ix.lut.max_index() as i64;
        let m = *vals.iter().max().unwrap() as i64;
        let mut esum = 0u64;
        let mut nnz = 0u64;
        for (i, &a) in vals.iter().enumerate() {
            let delta = m - a as i64;
            let want = if delta >= c_int {
                0
            } else {
                ix.lut.u8_table[((delta * n1 * 2 + c_int) / (2 * c_int)) as usize]
            };
            assert_eq!(got_e[i], want, "element {i}");
            esum += want as u64;
            nnz += (want != 0) as u64;
        }
        assert_eq!(row.esum(), esum);
        assert_eq!(row.nnz(), nnz);
    }

    #[test]
    fn online_merge_is_exact_at_equal_maxes_and_rescales_otherwise() {
        let ix = IndexSoftmax::default();
        let alpha = 0.002f32; // c_int = 3300
        let table = &ix.lut.u8_table;
        let vals = [9000i32, 2000, 8999, -500, 5000, 9000 - 3200];

        // Sequential walk: max phase + gathers over the whole stream, with
        // a toy 2-lane accumulator weighting each element by (1, i).
        let mut seq = ix.online_begin(alpha);
        for &a in &vals {
            seq.observe_max(a);
        }
        let mut seq_acc = [0i64; 2];
        for (i, &a) in vals.iter().enumerate() {
            let e = seq.gather(a, table) as i64;
            seq_acc[0] += e;
            seq_acc[1] += e * i as i64;
        }

        // Split into two spans, merge maxes, rebroadcast, gather, merge the
        // partial triples: byte-identical to the sequential walk.
        for split in 1..vals.len() {
            let (lo, hi) = vals.split_at(split);
            let mut a = ix.online_begin(alpha);
            let mut b = ix.online_begin(alpha);
            for &x in lo {
                a.observe_max(x);
            }
            for &x in hi {
                b.observe_max(x);
            }
            let mut root = a;
            root.merge_max(&b);
            let (mut a, mut b) = (root, root);
            let (mut acc_a, mut acc_b) = ([0i64; 2], [0i64; 2]);
            for (i, &x) in lo.iter().enumerate() {
                let e = a.gather(x, table) as i64;
                acc_a[0] += e;
                acc_a[1] += e * i as i64;
            }
            for (i, &x) in hi.iter().enumerate() {
                let e = b.gather(x, table) as i64;
                acc_b[0] += e;
                acc_b[1] += e * (split + i) as i64;
            }
            a.merge(&b, &mut acc_a, &acc_b, table);
            assert_eq!(a.esum(), seq.esum(), "split {split}");
            assert_eq!(a.nnz(), seq.nnz(), "split {split}");
            assert_eq!(acc_a, seq_acc, "split {split}");
        }

        // General (unequal-max) operator: the lower-max side's ΣÊ and lanes
        // scale by LUT[idx(Δm)]/255 with div_round rounding, then add.
        let mut lo = ix.online_begin(alpha);
        lo.observe_max(100);
        let mut lo_acc = [0i64; 2];
        let e = lo.gather(100, table) as i64; // Δ=0 → 255
        lo_acc[0] += e;
        let mut hi = ix.online_begin(alpha);
        hi.observe_max(1100);
        let mut hi_acc = [0i64; 2];
        let e = hi.gather(1100, table) as i64;
        hi_acc[0] += e;
        // Δm = 1000 → factor = LUT[round(1000·31/3300)] = LUT[9].
        let f = table[9] as u64;
        let mut merged = hi;
        merged.merge(&lo, &mut hi_acc, &lo_acc, table);
        assert_eq!(merged.esum(), (255 * f + 127) / 255 + 255);
        assert_eq!(hi_acc[0], rescale_lane_i64(255, f as u8) + 255);
        // A gap past c_int clips the lower side away entirely.
        let mut far = ix.online_begin(alpha);
        far.observe_max(100 + 3300);
        let mut far_acc = [0i64; 2];
        let _ = far.gather(100 + 3300, table);
        far_acc[0] = 255;
        far.merge(&lo, &mut far_acc, &lo_acc, table);
        assert_eq!(far.esum(), 255);
        assert_eq!(far_acc[0], 255);
    }

    #[test]
    fn rescale_lane_rounds_ties_away_from_zero() {
        assert_eq!(rescale_lane_i64(255, 255), 255);
        assert_eq!(rescale_lane_i64(-255, 255), -255);
        assert_eq!(rescale_lane_i64(1, 128), 1); // 128/255 ≈ 0.502 → 1
        assert_eq!(rescale_lane_i64(1, 127), 0); // 127/255 ≈ 0.498 → 0
        assert_eq!(rescale_lane_i64(-1, 128), -1);
        assert_eq!(rescale_lane_i64(1000, 0), 0);
        // Exact halves round away from zero, matching div_round.
        assert_eq!(rescale_lane_i64(1, 255), 1);
        assert_eq!(rescale_lane_i64(3, 85), 1); // 255/255 = 1 exactly
    }
}
