//! Exponential lookup tables (paper eq. 10 and eq. 13).
//!
//! Over the clipped interval `[0, c]` the function `exp(−x)` is bounded, so a
//! fixed-resolution table approximates it well. The table has `2^b` entries:
//!
//! ```text
//! LUT[i] = exp(−c·i / (2^b − 1))   for 0 ≤ i < 2^b − 1
//! LUT[2^b − 1] = 0                 (the "clipped away" bucket)
//! ```
//!
//! and is additionally quantized to UINT8 (`round(255·LUT)`, eq. 13) so the
//! whole softmax path stays 8-bit. With the paper's recommended `(b, c) =
//! (5, 6.6)` this is a 32-entry, 32-byte table.

/// Paper-recommended LUT resolution: `b = 5` → 32 entries (§4.4).
pub const DEFAULT_B: u32 = 5;
/// Paper-recommended clipping threshold `c = 6.6` (§4.4, Fig. 9 ridge).
pub const DEFAULT_C: f32 = 6.6;

/// A float + UINT8 exponential LUT pair over `[0, c]`.
#[derive(Clone, Debug)]
pub struct ExpLut {
    /// Resolution exponent; table has `2^b` entries.
    pub b: u32,
    /// Continuous clipping bound `c`.
    pub c: f32,
    /// Float table (eq. 10).
    pub f32_table: Vec<f32>,
    /// UINT8 table (eq. 13): `round(255 · f32_table[i])`.
    pub u8_table: Vec<u8>,
}

impl ExpLut {
    /// Build the table for resolution `b` (entries = 2^b) and bound `c`.
    pub fn new(b: u32, c: f32) -> Self {
        assert!((1..=16).contains(&b), "b must be in 1..=16");
        assert!(c > 0.0, "clipping bound must be positive");
        let n = 1usize << b;
        let mut f32_table = Vec::with_capacity(n);
        for i in 0..n {
            if i == n - 1 {
                // Last entry is the saturation bucket: exactly zero (eq. 10).
                f32_table.push(0.0);
            } else {
                let x = c * i as f32 / (n - 1) as f32;
                f32_table.push((-x).exp());
            }
        }
        let u8_table = f32_table.iter().map(|&v| (255.0 * v).round() as u8).collect();
        ExpLut { b, c, f32_table, u8_table }
    }

    /// The paper's default 32-entry table.
    pub fn paper_default() -> Self {
        Self::new(DEFAULT_B, DEFAULT_C)
    }

    /// Number of entries.
    #[inline]
    pub fn len(&self) -> usize {
        self.f32_table.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Memory footprint of the UINT8 table in bytes (32 B at b=5 — the
    /// Figure 5 comparison point).
    pub fn u8_bytes(&self) -> usize {
        self.u8_table.len()
    }

    /// Max index (`2^b − 1`).
    #[inline]
    pub fn max_index(&self) -> u32 {
        (self.len() - 1) as u32
    }

    /// Worst-case absolute error of the UINT8 table against `exp(−x)` over a
    /// dense grid of `[0, c]` — the Figure 5 fidelity metric.
    pub fn max_abs_error_u8(&self) -> f64 {
        self.max_abs_error_of(|x| self.lookup_u8_cont(x) as f64 / 255.0)
    }

    /// Same for the float table.
    pub fn max_abs_error_f32(&self) -> f64 {
        self.max_abs_error_of(|x| self.lookup_f32_cont(x) as f64)
    }

    fn max_abs_error_of(&self, approx: impl Fn(f32) -> f64) -> f64 {
        let samples = 4096;
        let mut worst = 0.0f64;
        for s in 0..=samples {
            let x = self.c * s as f32 / samples as f32;
            let truth = (-x as f64).exp();
            let got = approx(x);
            worst = worst.max((truth - got).abs());
        }
        worst
    }

    /// Continuous lookup helpers (for error analysis, not the hot path —
    /// the hot path indexes with precomputed integer indices).
    pub fn lookup_f32_cont(&self, x: f32) -> f32 {
        self.f32_table[self.index_of(x)]
    }

    pub fn lookup_u8_cont(&self, x: f32) -> u8 {
        self.u8_table[self.index_of(x)]
    }

    fn index_of(&self, x: f32) -> usize {
        let n1 = self.max_index() as f32;
        let idx = (x.clamp(0.0, self.c) / self.c * n1).round() as usize;
        idx.min(self.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_32_entries_32_bytes() {
        let lut = ExpLut::paper_default();
        assert_eq!(lut.len(), 32);
        assert_eq!(lut.u8_bytes(), 32);
        assert_eq!(lut.max_index(), 31);
    }

    #[test]
    fn first_entry_is_one_last_is_zero() {
        let lut = ExpLut::new(5, 6.6);
        assert_eq!(lut.f32_table[0], 1.0);
        assert_eq!(lut.u8_table[0], 255);
        assert_eq!(lut.f32_table[31], 0.0);
        assert_eq!(lut.u8_table[31], 0);
    }

    #[test]
    fn table_is_monotone_decreasing() {
        for b in [2u32, 3, 4, 5, 6, 8] {
            let lut = ExpLut::new(b, 6.6);
            for w in lut.f32_table.windows(2) {
                assert!(w[0] >= w[1], "b={b}");
            }
            for w in lut.u8_table.windows(2) {
                assert!(w[0] >= w[1], "b={b}");
            }
        }
    }

    #[test]
    fn entries_match_formula() {
        let lut = ExpLut::new(5, 6.6);
        for i in 0..31 {
            let expect = (-(6.6 * i as f32 / 31.0)).exp();
            assert!((lut.f32_table[i] - expect).abs() < 1e-6, "i={i}");
            assert_eq!(lut.u8_table[i], (255.0 * expect).round() as u8);
        }
    }

    #[test]
    fn error_decreases_with_resolution() {
        // Figure 5's claim: more entries under the same byte budget → better
        // fidelity. b=5 (ours) must beat b=3 (EXAQ INT3's 8 entries).
        let e3 = ExpLut::new(3, 6.6).max_abs_error_u8();
        let e5 = ExpLut::new(5, 6.6).max_abs_error_u8();
        let e8 = ExpLut::new(8, 6.6).max_abs_error_f32();
        assert!(e5 < e3, "b=5 err {e5} !< b=3 err {e3}");
        assert!(e8 < e5, "b=8 f32 err {e8} !< b=5 u8 err {e5}");
        // Quantitative: paper claims 4× resolution ⇒ roughly 4× finer error.
        assert!(e3 / e5 > 2.0, "ratio {}", e3 / e5);
    }

    #[test]
    fn u8_error_floor_is_half_lsb() {
        // With many entries, the u8 table error approaches the quantization
        // floor 1/510 ≈ 0.00196 — more float precision stops helping (the
        // paper's argument for not using an FP LUT at all).
        let e = ExpLut::new(10, 6.6).max_abs_error_u8();
        // bucket half-width (~c/2^10/2 ≈ 0.0032 near x=0) + u8 LSB/2
        assert!(e < 0.006, "e={e}");
        assert!(e >= 1.0 / 512.0 / 2.0, "e={e}");
    }

    #[test]
    fn continuous_lookup_clamps() {
        let lut = ExpLut::new(5, 6.6);
        assert_eq!(lut.lookup_f32_cont(-1.0), 1.0);
        assert_eq!(lut.lookup_f32_cont(100.0), 0.0);
    }

    #[test]
    #[should_panic(expected = "b must be")]
    fn rejects_zero_b() {
        let _ = ExpLut::new(0, 6.6);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive_c() {
        let _ = ExpLut::new(5, 0.0);
    }
}
