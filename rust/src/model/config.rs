//! Model hyperparameters, mirrored by `python/compile/train.py` (the JSON it
//! writes is parsed here, so both sides agree by construction).

use crate::util::json::Json;

/// Transformer LM configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    /// Vocabulary size (256 for the byte tokenizer).
    pub vocab: usize,
    /// Residual width.
    pub d_model: usize,
    /// Number of transformer blocks.
    pub n_layers: usize,
    /// Attention heads per block.
    pub n_heads: usize,
    /// Maximum (trained) context length.
    pub max_seq: usize,
    /// MLP hidden multiple (hidden = mlp_mult · d_model).
    pub mlp_mult: usize,
}

impl ModelConfig {
    /// The configuration `train.py` uses by default.
    pub fn tiny() -> Self {
        ModelConfig { vocab: 256, d_model: 128, n_layers: 4, n_heads: 4, max_seq: 256, mlp_mult: 4 }
    }

    /// Per-head dimension.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn d_mlp(&self) -> usize {
        self.mlp_mult * self.d_model
    }

    /// Total parameter count (embeddings + blocks + final LN; LM head tied).
    pub fn param_count(&self) -> usize {
        let emb = self.vocab * self.d_model + self.max_seq * self.d_model;
        let per_block = 4 * self.d_model * self.d_model          // wq wk wv wo
            + 4 * self.d_model                                    // ln1/ln2 g+b
            + 2 * self.d_model * self.d_mlp()                     // w1 w2
            + self.d_mlp() + self.d_model;                        // b1 b2
        emb + self.n_layers * per_block + 2 * self.d_model
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.d_model % self.n_heads == 0, "d_model % n_heads != 0");
        anyhow::ensure!(self.vocab > 0 && self.n_layers > 0 && self.max_seq > 0, "degenerate config");
        Ok(())
    }

    /// Parse from the `model_meta.json` the trainer writes.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let cfg = ModelConfig {
            vocab: j.req_usize("vocab")?,
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            max_seq: j.req_usize("max_seq")?,
            mlp_mult: j.req_usize("mlp_mult")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab", Json::num(self.vocab as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            ("mlp_mult", Json::num(self.mlp_mult as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_config_is_consistent() {
        let c = ModelConfig::tiny();
        c.validate().unwrap();
        assert_eq!(c.d_head(), 32);
        assert_eq!(c.d_mlp(), 512);
        assert!(c.param_count() > 100_000);
    }

    #[test]
    fn json_round_trip() {
        let c = ModelConfig::tiny();
        let j = c.to_json();
        let text = j.to_string();
        let back = ModelConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn bad_head_split_rejected() {
        let c = ModelConfig { n_heads: 3, ..ModelConfig::tiny() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn param_count_formula() {
        // hand-check on a minimal config
        let c = ModelConfig { vocab: 4, d_model: 2, n_layers: 1, n_heads: 1, max_seq: 3, mlp_mult: 2 };
        // emb: 4*2 + 3*2 = 14; block: 4*4 + 8 + 2*2*4 + 4 + 2 = 46; final ln 4
        assert_eq!(c.param_count(), 14 + 46 + 4);
    }
}
