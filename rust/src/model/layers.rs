//! Transformer layer primitives: layernorm, GELU MLP, and the multi-head
//! attention wrapper that routes each head through a configurable
//! [`AttentionPipeline`].

use crate::attention::{build_pipeline, AttentionConfig, KvState, PipelineKind};
use crate::energy::OpCounts;
use crate::gemm::gemm_f32;
use crate::model::weights::BlockWeights;
use crate::softmax::index_softmax::Mask;
use crate::tensor::MatF32;
use crate::util::threadpool::ParallelPool;
use crate::util::timer::StageTimes;

/// LayerNorm over the last dimension, standard eps.
pub fn layer_norm(x: &MatF32, gamma: &[f32], beta: &[f32]) -> MatF32 {
    let d = x.cols();
    assert_eq!(gamma.len(), d);
    assert_eq!(beta.len(), d);
    let mut out = MatF32::zeros(x.rows(), d);
    for r in 0..x.rows() {
        let row = x.row(r);
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let dst = out.row_mut(r);
        for ((o, &v), (&g, &b)) in dst.iter_mut().zip(row).zip(gamma.iter().zip(beta)) {
            *o = (v - mean) * inv * g + b;
        }
    }
    out
}

/// Tanh-approximation GELU (matches `jax.nn.gelu(approximate=True)`).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f32).tanh())
}

/// Linear layer `y = x·Wᵀ + b` with output-major W (see weights.rs layout).
pub fn linear(x: &MatF32, w: &MatF32, b: Option<&[f32]>) -> MatF32 {
    let mut y = MatF32::zeros(x.rows(), w.rows());
    gemm_f32(x, w, &mut y);
    if let Some(b) = b {
        assert_eq!(b.len(), w.rows());
        for r in 0..y.rows() {
            for (v, &bb) in y.row_mut(r).iter_mut().zip(b) {
                *v += bb;
            }
        }
    }
    y
}

/// Two-layer GELU MLP.
pub fn mlp(x: &MatF32, bw: &BlockWeights) -> MatF32 {
    let mut h = linear(x, &bw.w1, Some(&bw.b1));
    for v in h.as_mut_slice() {
        *v = gelu(*v);
    }
    linear(&h, &bw.w2, Some(&bw.b2))
}

/// Extract head `h`'s columns (`h·d_head .. (h+1)·d_head`) into a compact
/// `T×d_head` matrix.
pub fn slice_head(x: &MatF32, h: usize, d_head: usize) -> MatF32 {
    let t = x.rows();
    let mut out = MatF32::zeros(t, d_head);
    for r in 0..t {
        let src = &x.row(r)[h * d_head..(h + 1) * d_head];
        out.row_mut(r).copy_from_slice(src);
    }
    out
}

/// Write a head's output back into the concatenated layout.
pub fn unslice_head(dst: &mut MatF32, src: &MatF32, h: usize, d_head: usize) {
    for r in 0..src.rows() {
        dst.row_mut(r)[h * d_head..(h + 1) * d_head].copy_from_slice(src.row(r));
    }
}

/// Multi-head attention over a full (prefill) sequence, or over a KV cache
/// for incremental decode. Aggregates per-head stage times and op counts so
/// model-level breakdowns match the operator-level ones.
pub struct MultiHeadAttention {
    pub kind: PipelineKind,
    pub n_heads: usize,
    pub d_head: usize,
    /// Persistent parallel runtime shared by every head's GEMM launches
    /// (the serving path hands every layer [`ParallelPool::global`]).
    pub pool: &'static ParallelPool,
    /// Per-head pipelines for the stateful path, built lazily on the first
    /// prefill/decode call and reused for every subsequent one — a decode
    /// step must not reconstruct pipelines (and e.g. the IndexSoftmax LUT)
    /// per token. Keyed to `kind`/`pool` at build time; changing those
    /// fields after the first stateful call is not supported.
    state_pipes: Vec<Box<dyn AttentionPipeline>>,
    times: StageTimes,
    ops: OpCounts,
}

impl MultiHeadAttention {
    pub fn new(
        kind: PipelineKind,
        n_heads: usize,
        d_head: usize,
        pool: &'static ParallelPool,
    ) -> Self {
        MultiHeadAttention {
            kind,
            n_heads,
            d_head,
            pool,
            state_pipes: Vec::new(),
            times: StageTimes::new(),
            ops: OpCounts::default(),
        }
    }

    /// `q_all`: `M×d_model` projected queries; `k_all`, `v_all`: `L×d_model`.
    /// Causal masking requires `M == L`.
    pub fn forward(&mut self, q_all: &MatF32, k_all: &MatF32, v_all: &MatF32, mask: Mask) -> MatF32 {
        let m = q_all.rows();
        let l = k_all.rows();
        let d_model = self.n_heads * self.d_head;
        assert_eq!(q_all.cols(), d_model);
        assert_eq!(k_all.cols(), d_model);
        assert_eq!(v_all.cols(), d_model);
        let mut out = MatF32::zeros(m, d_model);
        for h in 0..self.n_heads {
            let qh = slice_head(q_all, h, self.d_head);
            let kh = slice_head(k_all, h, self.d_head);
            let vh = slice_head(v_all, h, self.d_head);
            let cfg = AttentionConfig {
                seq_len: l,
                head_dim: self.d_head,
                mask,
                pool: self.pool,
                isx: Default::default(),
            };
            let mut pipe = build_pipeline(self.kind, cfg);
            let oh = pipe.forward(&qh, &kh, &vh);
            self.times.merge(pipe.stage_times());
            self.ops.add(pipe.op_counts());
            unslice_head(&mut out, &oh, h, self.d_head);
        }
        out
    }

    /// Fresh per-head KV states for one sequence (pipeline-native storage:
    /// INT8 rows + scales for the integer kinds, raw rows for FP32/FP16).
    pub fn begin_states(&self) -> Vec<KvState> {
        (0..self.n_heads)
            .map(|_| KvState::new(self.kind, self.d_head))
            .collect()
    }

    /// Stateful prefill of one block: `q_all`/`k_all`/`v_all` are `m×d_model`
    /// projections for positions `states[h].len()..states[h].len()+m`; each
    /// head appends its K/V slice to its state and attends causally at that
    /// offset. Repeated calls implement chunked prefill.
    pub fn prefill(&mut self, states: &mut [KvState], q_all: &MatF32, k_all: &MatF32, v_all: &MatF32) -> MatF32 {
        self.run_stateful(states, q_all, k_all, v_all, false)
    }

    /// One decode step (`q_all`/`k_all`/`v_all` are `1×d_model`): append the
    /// new K/V row per head and attend the single query over the history.
    pub fn decode(&mut self, states: &mut [KvState], q_all: &MatF32, k_all: &MatF32, v_all: &MatF32) -> MatF32 {
        assert_eq!(q_all.rows(), 1, "decode takes a single position");
        self.run_stateful(states, q_all, k_all, v_all, true)
    }

    /// One batched decode step over `B` independent sequences: row `b` of
    /// `q_all`/`k_all`/`v_all` is sequence `b`'s single-position projection
    /// (`B×d_model`), `seq_states[b]` its per-head KV states. Row `b` of the
    /// result is bit-identical to a sequential [`decode`](Self::decode) call
    /// for that sequence — per head, the `B` per-sequence attention products
    /// run as one grouped-kernel launch instead of `B` separate ones.
    pub fn decode_batch(
        &mut self,
        seq_states: &mut [&mut [KvState]],
        q_all: &MatF32,
        k_all: &MatF32,
        v_all: &MatF32,
    ) -> MatF32 {
        let b = seq_states.len();
        let d_model = self.n_heads * self.d_head;
        assert_eq!(q_all.rows(), b, "one query row per sequence");
        assert_eq!(k_all.rows(), b, "one K row per sequence");
        assert_eq!(v_all.rows(), b, "one V row per sequence");
        assert_eq!(q_all.cols(), d_model);
        assert_eq!(k_all.cols(), d_model);
        assert_eq!(v_all.cols(), d_model);
        for s in seq_states.iter() {
            assert_eq!(s.len(), self.n_heads, "one KV state per head per sequence");
        }
        self.ensure_state_pipes();
        let mut out = MatF32::zeros(b, d_model);
        for h in 0..self.n_heads {
            let qh = slice_head(q_all, h, self.d_head);
            let kh = slice_head(k_all, h, self.d_head);
            let vh = slice_head(v_all, h, self.d_head);
            let mut head_states: Vec<&mut KvState> =
                seq_states.iter_mut().map(|s| &mut s[h]).collect();
            let pipe = &mut self.state_pipes[h];
            let oh = pipe.decode_step_batch(&mut head_states, &qh, &kh, &vh);
            self.times.merge(pipe.stage_times());
            self.ops.add(pipe.op_counts());
            pipe.reset_stats();
            unslice_head(&mut out, &oh, h, self.d_head);
        }
        out
    }

    /// Build the per-head stateful pipelines on first use (a decode step
    /// must not reconstruct pipelines — or the IndexSoftmax LUT — per token).
    fn ensure_state_pipes(&mut self) {
        if self.state_pipes.is_empty() {
            // seq_len/mask are per-call state in the stateful API (derived
            // from the KvState); the config only contributes head_dim, the
            // pool and the softmax hyperparameters here.
            let cfg = AttentionConfig {
                seq_len: 0,
                head_dim: self.d_head,
                mask: Mask::None,
                pool: self.pool,
                isx: Default::default(),
            };
            self.state_pipes = (0..self.n_heads).map(|_| build_pipeline(self.kind, cfg)).collect();
        }
    }

    fn run_stateful(
        &mut self,
        states: &mut [KvState],
        q_all: &MatF32,
        k_all: &MatF32,
        v_all: &MatF32,
        decode: bool,
    ) -> MatF32 {
        assert_eq!(states.len(), self.n_heads, "one KV state per head");
        let m = q_all.rows();
        let d_model = self.n_heads * self.d_head;
        assert_eq!(q_all.cols(), d_model);
        assert_eq!(k_all.cols(), d_model);
        assert_eq!(v_all.cols(), d_model);
        assert_eq!(k_all.rows(), m);
        assert_eq!(v_all.rows(), m);
        self.ensure_state_pipes();
        let mut out = MatF32::zeros(m, d_model);
        for (h, state) in states.iter_mut().enumerate() {
            let qh = slice_head(q_all, h, self.d_head);
            let kh = slice_head(k_all, h, self.d_head);
            let vh = slice_head(v_all, h, self.d_head);
            let pipe = &mut self.state_pipes[h];
            let oh = if decode {
                pipe.decode_step(state, &qh, &kh, &vh)
            } else {
                pipe.prefill(state, &qh, &kh, &vh)
            };
            self.times.merge(pipe.stage_times());
            self.ops.add(pipe.op_counts());
            pipe.reset_stats();
            unslice_head(&mut out, &oh, h, self.d_head);
        }
        out
    }

    pub fn stage_times(&self) -> &StageTimes {
        &self.times
    }

    pub fn op_counts(&self) -> &OpCounts {
        &self.ops
    }

    pub fn reset_stats(&mut self) {
        self.times.reset();
        self.ops = OpCounts::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
        MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut rng = Pcg64::seed_from_u64(1);
        let x = rand_mat(&mut rng, 4, 64);
        let g = vec![1.0; 64];
        let b = vec![0.0; 64];
        let y = layer_norm(&x, &g, &b);
        for r in 0..4 {
            let mean: f32 = y.row(r).iter().sum::<f32>() / 64.0;
            let var: f32 = y.row(r).iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-5, "mean={mean}");
            assert!((var - 1.0).abs() < 1e-3, "var={var}");
        }
    }

    #[test]
    fn layer_norm_gamma_beta_applied() {
        let x = MatF32::from_vec(1, 2, vec![1.0, -1.0]);
        let y = layer_norm(&x, &[2.0, 2.0], &[10.0, 10.0]);
        assert!((y.get(0, 0) - 12.0).abs() < 1e-3);
        assert!((y.get(0, 1) - 8.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_reference_points() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
        assert!(gelu(10.0) > 9.99);
    }

    #[test]
    fn linear_matches_manual() {
        let x = MatF32::from_vec(1, 2, vec![1.0, 2.0]);
        // W output-major: 3 outputs from 2 inputs
        let w = MatF32::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let y = linear(&x, &w, Some(&[0.5, 0.5, 0.5]));
        assert_eq!(y.as_slice(), &[1.5, 2.5, 3.5]);
    }

    #[test]
    fn head_slice_unslice_round_trip() {
        let mut rng = Pcg64::seed_from_u64(2);
        let x = rand_mat(&mut rng, 5, 12);
        let mut back = MatF32::zeros(5, 12);
        for h in 0..3 {
            let s = slice_head(&x, h, 4);
            assert_eq!((s.rows(), s.cols()), (5, 4));
            unslice_head(&mut back, &s, h, 4);
        }
        assert_eq!(back, x);
    }

    #[test]
    fn mha_shapes_and_stats() {
        let mut rng = Pcg64::seed_from_u64(3);
        let (t, d_model) = (16, 32);
        let q = rand_mat(&mut rng, t, d_model);
        let k = rand_mat(&mut rng, t, d_model);
        let v = rand_mat(&mut rng, t, d_model);
        let mut mha = MultiHeadAttention::new(PipelineKind::IntAttention, 4, 8, ParallelPool::sized(1));
        let o = mha.forward(&q, &k, &v, Mask::Causal);
        assert_eq!((o.rows(), o.cols()), (t, d_model));
        assert!(mha.stage_times().total_ns() > 0);
        assert!(mha.op_counts().int8_mac > 0);
    }

    #[test]
    fn mha_int_close_to_fp32() {
        let mut rng = Pcg64::seed_from_u64(4);
        let (t, d_model) = (24, 32);
        let q = rand_mat(&mut rng, t, d_model);
        let k = rand_mat(&mut rng, t, d_model);
        let v = rand_mat(&mut rng, t, d_model);
        let of = MultiHeadAttention::new(PipelineKind::Fp32, 4, 8, ParallelPool::sized(1))
            .forward(&q, &k, &v, Mask::Causal);
        let oi = MultiHeadAttention::new(PipelineKind::IntAttention, 4, 8, ParallelPool::sized(1))
            .forward(&q, &k, &v, Mask::Causal);
        let cos = crate::util::stats::cosine_similarity(of.as_slice(), oi.as_slice());
        assert!(cos > 0.99, "cos={cos}");
    }

    #[test]
    fn mha_stateful_matches_one_shot_causal() {
        let mut rng = Pcg64::seed_from_u64(6);
        let (t, d_model) = (20, 16);
        let q = rand_mat(&mut rng, t, d_model);
        let k = rand_mat(&mut rng, t, d_model);
        let v = rand_mat(&mut rng, t, d_model);
        for kind in [PipelineKind::Fp32, PipelineKind::IntAttention] {
            let want = MultiHeadAttention::new(kind, 2, 8, ParallelPool::sized(1)).forward(&q, &k, &v, Mask::Causal);
            let mut mha = MultiHeadAttention::new(kind, 2, 8, ParallelPool::sized(1));
            let mut states = mha.begin_states();
            let part = |m: &MatF32, r0: usize, r1: usize| {
                MatF32::from_vec(r1 - r0, d_model, m.as_slice()[r0 * d_model..r1 * d_model].to_vec())
            };
            // Prefill 12 rows in two chunks, then 8 decode steps.
            let mut got = Vec::new();
            for (r0, r1) in [(0, 8), (8, 12)] {
                let o = mha.prefill(&mut states, &part(&q, r0, r1), &part(&k, r0, r1), &part(&v, r0, r1));
                got.extend_from_slice(o.as_slice());
            }
            for r in 12..t {
                let o = mha.decode(&mut states, &part(&q, r, r + 1), &part(&k, r, r + 1), &part(&v, r, r + 1));
                got.extend_from_slice(o.as_slice());
            }
            assert!(states.iter().all(|s| s.len() == t));
            let cos = crate::util::stats::cosine_similarity(&got, want.as_slice());
            assert!(cos > 0.999, "{}: cos={cos}", kind.name());
        }
    }

    #[test]
    fn mha_decode_mode_single_query() {
        let mut rng = Pcg64::seed_from_u64(5);
        let d_model = 16;
        let q = rand_mat(&mut rng, 1, d_model);
        let k = rand_mat(&mut rng, 9, d_model);
        let v = rand_mat(&mut rng, 9, d_model);
        let mut mha = MultiHeadAttention::new(PipelineKind::IntAttention, 2, 8, ParallelPool::sized(1));
        let o = mha.forward(&q, &k, &v, Mask::None);
        assert_eq!((o.rows(), o.cols()), (1, d_model));
    }
}
