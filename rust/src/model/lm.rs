//! The tiny transformer LM: stateful prefill/decode over pipeline-owned KV
//! states, perplexity evaluation and sampling — everything the serving
//! engine and the fidelity experiments need.
//!
//! Pre-norm GPT-style blocks:
//! `x += attn(LN1(x)); x += mlp(LN2(x)); logits = LN_f(x)·tok_embᵀ` (tied head).
//!
//! ## The KV cache is pipeline-owned, **paged** state
//!
//! [`KvCache`] holds one [`KvState`] per (layer, head), created lazily in
//! the attention backend's native operand format the first time the cache is
//! filled. For the integer pipelines that means INT8 K̂/V̂ rows plus running
//! per-tensor scales — a decode step quantizes exactly one new row per
//! layer/head and **never** materializes or re-quantizes the FP32 history
//! (the old design's O(len·d_model) per-token conversion cost). For
//! FP32/FP16 backends the states hold native-dtype rows. Rows live in
//! fixed-size pages drawn from a process-wide recycling pool
//! ([`crate::attention::state::PagedRows`]): appends never re-copy history,
//! [`KvCache::bytes`] is exact allocated capacity, and dropping a finished
//! request's cache returns its pages to the pool for the next admission.
//! The engine budgets [`KvCache::pages_for_tokens`] pages per request.
//!
//! ## Chunked prefill
//!
//! [`TinyLm::forward`] with a cache may be called repeatedly: each call
//! embeds its tokens at the cache's current position offset and attends with
//! an offset-causal mask (`Mask::CausalFrom`), so a long prompt can be
//! prefilled in scheduler-friendly chunks. [`TinyLm::decode_step`] is the
//! 1-token special case.

use crate::attention::{kv_page_rows, KvState, PipelineKind};
use crate::energy::OpCounts;
use crate::gemm::gemm_f32;
use crate::model::config::ModelConfig;
use crate::model::layers::{layer_norm, linear, mlp, MultiHeadAttention};
use crate::model::weights::Weights;
use crate::softmax::index_softmax::Mask;
use crate::tensor::MatF32;
use crate::util::prng::Pcg64;
use crate::util::threadpool::ParallelPool;
use crate::util::timer::StageTimes;

/// Per-sequence KV cache: one pipeline-owned [`KvState`] per (layer, head).
#[derive(Clone, Debug, Default)]
pub struct KvCache {
    /// `layers[l]` holds the per-head states of layer `l`; empty until the
    /// first prefill reaches that layer (the model knows the pipeline kind
    /// and head geometry, the cache does not need to).
    pub layers: Vec<Vec<KvState>>,
    /// Cached positions (tokens fully absorbed into every layer).
    pub len: usize,
    pub d_model: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, d_model: usize) -> Self {
        KvCache { layers: vec![Vec::new(); n_layers], len: 0, d_model }
    }

    /// Layer `layer`'s per-head states, created on first use.
    fn layer_states(
        &mut self,
        layer: usize,
        kind: PipelineKind,
        n_heads: usize,
        d_head: usize,
    ) -> &mut [KvState] {
        debug_assert_eq!(n_heads * d_head, self.d_model, "head geometry vs cache d_model");
        let states = &mut self.layers[layer];
        if states.is_empty() {
            *states = (0..n_heads).map(|_| KvState::new(kind, d_head)).collect();
        }
        debug_assert_eq!(states.len(), n_heads);
        &mut states[..]
    }

    /// Actual memory footprint in bytes at each state's native element
    /// width — allocated page capacity (pages × page bytes), INT8 + scales
    /// for the integer pipelines, not a hardcoded 4 B/elem and not a
    /// `len`-derived estimate that hides growth slack.
    pub fn bytes(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|heads| heads.iter())
            .map(|s| s.bytes())
            .sum()
    }

    /// Pages allocated across every (layer, head, side) state — the unit
    /// the coordinator's admission budget charges and the retirement path
    /// frees back to the pool.
    pub fn pages(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|heads| heads.iter())
            .map(|s| s.pages())
            .sum()
    }

    /// Rows stored across every state (K and V sides both count).
    pub fn rows_stored(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|heads| heads.iter())
            .map(|s| s.rows_stored())
            .sum()
    }

    /// Row slots the allocated pages could hold — with [`Self::rows_stored`]
    /// this yields tail-page utilization (1.0 = every page full).
    pub fn capacity_rows(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|heads| heads.iter())
            .map(|s| s.capacity_rows())
            .sum()
    }

    /// Pages a sequence of `tokens` cached positions occupies for any
    /// pipeline under `cfg` (all layers × heads × K/V sides, each side
    /// `ceil(tokens / page_rows)` pages) — the projection the coordinator's
    /// page-budget admission charges per request before admitting it. Page
    /// count is dtype-independent; page *bytes* differ by pipeline.
    pub fn pages_for_tokens(tokens: usize, cfg: &ModelConfig) -> usize {
        cfg.n_layers * cfg.n_heads * 2 * tokens.div_ceil(kv_page_rows())
    }

    /// Pages (across every layer/head/side state) currently shared with
    /// another cache — the refcount view behind the `shared_pages` metric.
    pub fn shared_pages(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|heads| heads.iter())
            .map(|s| s.shared_pages())
            .sum()
    }

    /// A cache whose first `rows` positions alias this cache's pages
    /// copy-on-write ([`KvState::share_prefix`] per layer/head state) — how
    /// the coordinator's prefix index snapshots a prompt prefix and how an
    /// adopting request starts with it. Every layer must already be
    /// populated through `rows` positions, and byte-identity with unshared
    /// execution requires `rows == self.len` at snapshot time (the integer
    /// states' running scales then cover exactly the shared rows — the
    /// engine only snapshots at aligned prefill-chunk boundaries).
    pub fn share_prefix(&self, rows: usize) -> KvCache {
        assert!(rows <= self.len, "cannot share {rows} of {} cached positions", self.len);
        let layers = self
            .layers
            .iter()
            .map(|heads| {
                assert!(
                    rows == 0 || !heads.is_empty(),
                    "cannot share a prefix of an unpopulated layer"
                );
                heads.iter().map(|s| s.share_prefix(rows)).collect()
            })
            .collect();
        KvCache { layers, len: rows, d_model: self.d_model }
    }
}

/// The model. Cheap to clone conceptually but weights are large; the serving
/// engine shares one instance behind the scheduler.
pub struct TinyLm {
    pub weights: Weights,
    /// Attention backend. Fixed at construction (the per-layer attention
    /// wrappers below are built for it); do not change after `new`.
    pub attention_kind: PipelineKind,
    /// Persistent parallel runtime for every layer's attention GEMMs; the
    /// process-wide [`ParallelPool::global`] (sized once from
    /// `INTATTN_THREADS`) by default. Overriding is only supported
    /// **before the first forward/decode call**: each layer's stateful
    /// per-head pipelines are built lazily on first use and keep the pool
    /// they were built with.
    pub pool: &'static ParallelPool,
    /// One persistent multi-head wrapper per layer, so the stateful path's
    /// per-head pipelines (IndexSoftmax LUT etc.) are built once and reused
    /// across every prefill chunk and decode step.
    mhas: Vec<MultiHeadAttention>,
    times: StageTimes,
    ops: OpCounts,
}

impl TinyLm {
    pub fn new(weights: Weights, attention_kind: PipelineKind) -> Self {
        let pool = ParallelPool::global();
        let cfg = weights.cfg;
        let mhas = (0..cfg.n_layers)
            .map(|_| MultiHeadAttention::new(attention_kind, cfg.n_heads, cfg.d_head(), pool))
            .collect();
        TinyLm { weights, attention_kind, pool, mhas, times: StageTimes::new(), ops: OpCounts::default() }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.weights.cfg
    }

    /// Accumulated attention stage times across forwards.
    pub fn attention_times(&self) -> &StageTimes {
        &self.times
    }

    pub fn attention_ops(&self) -> &OpCounts {
        &self.ops
    }

    pub fn reset_stats(&mut self) {
        self.times.reset();
        self.ops = OpCounts::default();
    }

    fn embed(&self, tokens: &[u16], pos_offset: usize) -> MatF32 {
        self.embed_at(tokens, |i| pos_offset + i)
    }

    /// Embed `tokens[i]` at absolute position `pos(i)` (clamped at
    /// `max_seq − 1`, the seed's stateless-path behavior). The batched
    /// decode path uses per-row positions (one sequence per row).
    fn embed_at(&self, tokens: &[u16], pos: impl Fn(usize) -> usize) -> MatF32 {
        let cfg = &self.weights.cfg;
        let d = cfg.d_model;
        let mut x = MatF32::zeros(tokens.len(), d);
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            assert!(t < cfg.vocab, "token {t} out of vocab");
            let p = pos(i).min(cfg.max_seq - 1);
            let dst = x.row_mut(i);
            let te = self.weights.tok_emb.row(t);
            let pe = self.weights.pos_emb.row(p);
            for ((o, &a), &b) in dst.iter_mut().zip(te).zip(pe) {
                *o = a + b;
            }
        }
        x
    }

    /// Fresh KV cache sized for this model.
    pub fn new_cache(&self) -> KvCache {
        let cfg = self.weights.cfg;
        KvCache::new(cfg.n_layers, cfg.d_model)
    }

    /// Block forward (prefill). Returns logits `T×vocab`. With a cache the
    /// tokens are treated as the next `T` positions after `cache.len`:
    /// each layer's new K/V rows are appended to the pipeline-owned states
    /// (quantized once, in place) and attention runs with an offset-causal
    /// mask — so calling this repeatedly implements **chunked prefill**.
    /// Without a cache it is the stateless full-sequence forward.
    pub fn forward(&mut self, tokens: &[u16], mut cache: Option<&mut KvCache>) -> MatF32 {
        assert!(!tokens.is_empty());
        let cfg = self.weights.cfg;
        let offset = cache.as_deref().map_or(0, |c| c.len);
        if cache.is_some() {
            // Cached positions are real (offset-causal) positions; the
            // stateless path keeps the seed's clamp-at-max_seq behavior.
            assert!(
                offset + tokens.len() <= cfg.max_seq,
                "prefill beyond max_seq ({} + {} > {})",
                offset,
                tokens.len(),
                cfg.max_seq
            );
        }
        let mut x = self.embed(tokens, offset);
        for (li, bw) in self.weights.blocks.iter().enumerate() {
            let xn = layer_norm(&x, &bw.ln1_g, &bw.ln1_b);
            let q = linear(&xn, &bw.wq, None);
            let k = linear(&xn, &bw.wk, None);
            let v = linear(&xn, &bw.wv, None);
            let mha = &mut self.mhas[li];
            mha.pool = self.pool;
            let att = match cache.as_deref_mut() {
                Some(c) => {
                    let states =
                        c.layer_states(li, self.attention_kind, cfg.n_heads, cfg.d_head());
                    mha.prefill(states, &q, &k, &v)
                }
                None => mha.forward(&q, &k, &v, Mask::Causal),
            };
            self.times.merge(mha.stage_times());
            self.ops.add(mha.op_counts());
            mha.reset_stats();
            let att_o = linear(&att, &bw.wo, None);
            for (xv, &av) in x.as_mut_slice().iter_mut().zip(att_o.as_slice()) {
                *xv += av;
            }
            let xn2 = layer_norm(&x, &bw.ln2_g, &bw.ln2_b);
            let m = mlp(&xn2, bw);
            for (xv, &mv) in x.as_mut_slice().iter_mut().zip(m.as_slice()) {
                *xv += mv;
            }
        }
        if let Some(c) = cache {
            c.len += tokens.len();
        }
        let xf = layer_norm(&x, &self.weights.ln_f_g, &self.weights.ln_f_b);
        // Tied LM head: logits = xf · tok_embᵀ (tok_emb is vocab×d, i.e.
        // already the "bt" layout).
        let mut logits = MatF32::zeros(tokens.len(), cfg.vocab);
        gemm_f32(&xf, &self.weights.tok_emb, &mut logits);
        logits
    }

    /// One decode step: append `token` to the cache, return logits `1×vocab`.
    /// Each layer appends exactly one K/V row to its resident states —
    /// O(1) dtype-conversion work per token regardless of context length.
    pub fn decode_step(&mut self, token: u16, cache: &mut KvCache) -> MatF32 {
        let cfg = self.weights.cfg;
        let mut x = self.embed(&[token], cache.len);
        for (li, bw) in self.weights.blocks.iter().enumerate() {
            let xn = layer_norm(&x, &bw.ln1_g, &bw.ln1_b);
            let q = linear(&xn, &bw.wq, None);
            let k = linear(&xn, &bw.wk, None);
            let v = linear(&xn, &bw.wv, None);
            let mha = &mut self.mhas[li];
            mha.pool = self.pool;
            let states = cache.layer_states(li, self.attention_kind, cfg.n_heads, cfg.d_head());
            let att = mha.decode(states, &q, &k, &v);
            self.times.merge(mha.stage_times());
            self.ops.add(mha.op_counts());
            mha.reset_stats();
            let att_o = linear(&att, &bw.wo, None);
            for (xv, &av) in x.as_mut_slice().iter_mut().zip(att_o.as_slice()) {
                *xv += av;
            }
            let xn2 = layer_norm(&x, &bw.ln2_g, &bw.ln2_b);
            let m = mlp(&xn2, bw);
            for (xv, &mv) in x.as_mut_slice().iter_mut().zip(m.as_slice()) {
                *xv += mv;
            }
        }
        cache.len += 1;
        let xf = layer_norm(&x, &self.weights.ln_f_g, &self.weights.ln_f_b);
        let mut logits = MatF32::zeros(1, cfg.vocab);
        gemm_f32(&xf, &self.weights.tok_emb, &mut logits);
        logits
    }

    /// One decode step for each of `B` independent sequences: `tokens[b]` is
    /// sequence `b`'s last sampled token and `caches[b]` its KV cache (each
    /// advances by one position). Returns `B×vocab` logits, row `b` being
    /// **bit-identical** to what [`decode_step`](Self::decode_step) would
    /// produce for sequence `b` — every model op is row-independent. What
    /// changes is the kernel shape: the `B` 1-row Q/K/V (and MLP/logit)
    /// projections stack into single `B×d_model` GEMMs per layer, and each
    /// head's `B` attention products run as one grouped launch over the `B`
    /// resident KV states ([`MultiHeadAttention::decode_batch`]) instead of
    /// `B` memory-bound 1-row GEMM pairs.
    pub fn decode_step_batch(&mut self, tokens: &[u16], caches: &mut [&mut KvCache]) -> MatF32 {
        let b = tokens.len();
        assert!(b > 0, "empty decode batch");
        assert_eq!(caches.len(), b, "one cache per sequence");
        let cfg = self.weights.cfg;
        let kind = self.attention_kind;
        let positions: Vec<usize> = caches.iter().map(|c| c.len).collect();
        let mut x = self.embed_at(tokens, |i| positions[i]);
        for (li, bw) in self.weights.blocks.iter().enumerate() {
            let xn = layer_norm(&x, &bw.ln1_g, &bw.ln1_b);
            let q = linear(&xn, &bw.wq, None);
            let k = linear(&xn, &bw.wk, None);
            let v = linear(&xn, &bw.wv, None);
            let mha = &mut self.mhas[li];
            mha.pool = self.pool;
            let mut seq_states: Vec<&mut [KvState]> = caches
                .iter_mut()
                .map(|c| c.layer_states(li, kind, cfg.n_heads, cfg.d_head()))
                .collect();
            let att = mha.decode_batch(&mut seq_states, &q, &k, &v);
            self.times.merge(mha.stage_times());
            self.ops.add(mha.op_counts());
            mha.reset_stats();
            let att_o = linear(&att, &bw.wo, None);
            for (xv, &av) in x.as_mut_slice().iter_mut().zip(att_o.as_slice()) {
                *xv += av;
            }
            let xn2 = layer_norm(&x, &bw.ln2_g, &bw.ln2_b);
            let m = mlp(&xn2, bw);
            for (xv, &mv) in x.as_mut_slice().iter_mut().zip(m.as_slice()) {
                *xv += mv;
            }
        }
        for c in caches.iter_mut() {
            c.len += 1;
        }
        let xf = layer_norm(&x, &self.weights.ln_f_g, &self.weights.ln_f_b);
        let mut logits = MatF32::zeros(b, cfg.vocab);
        gemm_f32(&xf, &self.weights.tok_emb, &mut logits);
        logits
    }

    /// Mean next-token cross-entropy (nats) over the sequence; `exp` of this
    /// is the perplexity reported in the Table 1/3 reproductions.
    pub fn cross_entropy(&mut self, tokens: &[u16]) -> f64 {
        assert!(tokens.len() >= 2, "need at least 2 tokens");
        let logits = self.forward(tokens, None);
        let mut total = 0f64;
        for i in 0..tokens.len() - 1 {
            let row = logits.row(i);
            let target = tokens[i + 1] as usize;
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logsum: f64 =
                (row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>()).ln() + m as f64;
            total += logsum - row[target] as f64;
        }
        total / (tokens.len() - 1) as f64
    }

    pub fn perplexity(&mut self, tokens: &[u16]) -> f64 {
        self.cross_entropy(tokens).exp()
    }

    /// Per-position token losses (for the Table 10 stability stress test).
    pub fn token_losses(&mut self, tokens: &[u16]) -> Vec<f64> {
        let logits = self.forward(tokens, None);
        (0..tokens.len() - 1)
            .map(|i| {
                let row = logits.row(i);
                let target = tokens[i + 1] as usize;
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let logsum: f64 =
                    (row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>()).ln() + m as f64;
                logsum - row[target] as f64
            })
            .collect()
    }

    /// Sample `n` tokens after `prompt` with temperature + top-k.
    pub fn generate(
        &mut self,
        prompt: &[u16],
        n: usize,
        temperature: f32,
        top_k: usize,
        rng: &mut Pcg64,
    ) -> Vec<u16> {
        assert!(!prompt.is_empty());
        let mut cache = self.new_cache();
        let logits = self.forward(prompt, Some(&mut cache));
        let mut out = Vec::with_capacity(n);
        let mut last = sample_row(logits.row(logits.rows() - 1), temperature, top_k, rng);
        out.push(last);
        for _ in 1..n {
            let logits = self.decode_step(last, &mut cache);
            last = sample_row(logits.row(0), temperature, top_k, rng);
            out.push(last);
        }
        out
    }
}

/// Temperature + top-k sampling from a logit row.
pub fn sample_row(logits: &[f32], temperature: f32, top_k: usize, rng: &mut Pcg64) -> u16 {
    if temperature <= 0.0 {
        // Greedy.
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u16;
    }
    let k = top_k.clamp(1, logits.len());
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    idx.truncate(k);
    let m = logits[idx[0]];
    let weights: Vec<f32> = idx
        .iter()
        .map(|&i| ((logits[i] - m) / temperature).exp())
        .collect();
    idx[rng.categorical(&weights)] as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tiny() -> TinyLm {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 32, mlp_mult: 2 };
        TinyLm::new(Weights::random(cfg, 3), PipelineKind::Fp32)
    }

    #[test]
    fn forward_shapes() {
        let mut lm = tiny();
        let logits = lm.forward(&[1, 2, 3, 4], None);
        assert_eq!((logits.rows(), logits.cols()), (4, 32));
        assert!(logits.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_matches_prefill() {
        // Incremental decode with a KV cache must produce the same last-token
        // logits as a fresh full forward (the KV-cache correctness invariant).
        let mut lm = tiny();
        let tokens = [5u16, 9, 1, 30, 2, 17];
        // Path A: prefill first 5, decode token 6.
        let mut cache = KvCache::new(2, 16);
        let _ = lm.forward(&tokens[..5], Some(&mut cache));
        let inc = lm.decode_step(tokens[5], &mut cache);
        // Path B: full forward.
        let full = lm.forward(&tokens, None);
        let last = full.row(5);
        for (a, b) in inc.row(0).iter().zip(last) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn cache_len_tracks_positions() {
        let mut lm = tiny();
        let mut cache = KvCache::new(2, 16);
        let _ = lm.forward(&[1, 2, 3], Some(&mut cache));
        assert_eq!(cache.len, 3);
        let _ = lm.decode_step(4, &mut cache);
        assert_eq!(cache.len, 4);
        // FP32 states: 2 layers × 2 heads × (K+V) sides, each side
        // ceil(4 / page_rows) pages of page_rows × 8 dims × 4 B.
        let pr = crate::attention::kv_page_rows();
        let pages_per_side = 4usize.div_ceil(pr);
        assert_eq!(cache.pages(), 2 * 2 * 2 * pages_per_side);
        assert_eq!(cache.bytes(), 2 * 2 * 2 * pages_per_side * pr * 8 * 4);
        assert_eq!(cache.rows_stored(), 2 * 2 * 2 * 4);
        assert_eq!(cache.capacity_rows(), 2 * 2 * 2 * pages_per_side * pr);
        // The admission projection charges the same page count.
        let cfg = lm.config();
        assert_eq!(KvCache::pages_for_tokens(4, cfg), cache.pages());
    }

    #[test]
    fn chunked_prefill_matches_full_prefill() {
        // Prefilling a prompt in two chunks must leave the cache in a state
        // that decodes identically to a one-chunk prefill.
        for kind in [PipelineKind::Fp32, PipelineKind::IntAttention] {
            let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 32, mlp_mult: 2 };
            let w = Weights::random(cfg, 3);
            let tokens = [5u16, 9, 1, 30, 2, 17, 8, 4];
            let mut lm = TinyLm::new(w, kind);
            // Path A: one-chunk prefill + decode.
            let mut ca = lm.new_cache();
            let _ = lm.forward(&tokens[..7], Some(&mut ca));
            let la = lm.decode_step(tokens[7], &mut ca);
            // Path B: chunked prefill (4 + 3) + decode.
            let mut cb = lm.new_cache();
            let _ = lm.forward(&tokens[..4], Some(&mut cb));
            let _ = lm.forward(&tokens[4..7], Some(&mut cb));
            assert_eq!(cb.len, 7);
            let lb = lm.decode_step(tokens[7], &mut cb);
            let cos = crate::util::stats::cosine_similarity(la.as_slice(), lb.as_slice());
            // FP32 is exact; the integer pipelines differ only through the
            // per-chunk Q quantization granularity.
            assert!(cos > 0.999, "{:?}: cos={cos}", kind);
        }
    }

    #[test]
    fn integer_cache_stores_int8_not_fp32() {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 32, mlp_mult: 2 };
        let w = Weights::random(cfg, 3);
        let mut fp = TinyLm::new(w.clone(), PipelineKind::Fp32);
        let mut int = TinyLm::new(w, PipelineKind::IntAttention);
        let mut cf = fp.new_cache();
        let mut ci = int.new_cache();
        let _ = fp.forward(&[1, 2, 3, 4, 5, 6, 7, 8], Some(&mut cf));
        let _ = int.forward(&[1, 2, 3, 4, 5, 6, 7, 8], Some(&mut ci));
        // INT8 pages are 4× smaller than FP32 pages of the same geometry;
        // allow the states' fixed scale bookkeeping on top.
        let payload_fp32 = cf.bytes();
        let payload_int = ci.bytes();
        assert!(
            payload_int < payload_fp32 / 3,
            "int cache {payload_int} B not materially smaller than fp32 {payload_fp32} B"
        );
        // Allocated capacity is exact: pages × page bytes per side.
        let pr = crate::attention::kv_page_rows();
        let pages_per_side = 8usize.div_ceil(pr);
        assert_eq!(payload_fp32, 2 * 2 * 2 * pages_per_side * pr * 8 * 4);
        assert_eq!(cf.pages(), ci.pages(), "page count is dtype-independent");
    }

    #[test]
    fn decode_step_batch_bit_identical_to_sequential() {
        // The engine's batched rounds lean on this: stacking B sequences
        // into one decode_step_batch call must reproduce the B sequential
        // decode_step results *bit for bit* (and advance the caches the
        // same way), for a float and an integer backend, across ragged
        // context lengths.
        for kind in [PipelineKind::Fp32, PipelineKind::IntAttention] {
            let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 32, mlp_mult: 2 };
            let w = Weights::random(cfg, 3);
            let mut lm = TinyLm::new(w, kind);
            let prompts: [&[u16]; 3] = [&[1, 2, 3], &[4, 5, 6, 7, 8], &[9]];
            let mut caches_a: Vec<KvCache> = prompts.iter().map(|_| lm.new_cache()).collect();
            for (p, c) in prompts.iter().zip(caches_a.iter_mut()) {
                let _ = lm.forward(p, Some(c));
            }
            let mut caches_b = caches_a.clone();
            for round in 0..3 {
                let tokens: Vec<u16> = (0..3).map(|i| (10 + 3 * round + i) as u16).collect();
                // Sequential oracle.
                let mut want = Vec::new();
                for (t, c) in tokens.iter().zip(caches_a.iter_mut()) {
                    want.extend_from_slice(lm.decode_step(*t, c).row(0));
                }
                // Batched.
                let mut refs: Vec<&mut KvCache> = caches_b.iter_mut().collect();
                let got = lm.decode_step_batch(&tokens, &mut refs);
                assert_eq!(got.as_slice(), &want[..], "{} round {round}", kind.name());
            }
            for (a, b) in caches_a.iter().zip(&caches_b) {
                assert_eq!(a.len, b.len);
                assert_eq!(a.bytes(), b.bytes());
            }
        }
    }

    #[test]
    fn cache_share_prefix_is_invisible_to_decode() {
        // Adopting a shared prefix (refcounted pages + pinned scales) must
        // decode bit-identically to having prefilled the same tokens
        // directly — and the donor must be unaffected by the adopter.
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 32, mlp_mult: 2 };
        let w = Weights::random(cfg, 3);
        for kind in [PipelineKind::Fp32, PipelineKind::IntAttention] {
            let mut lm = TinyLm::new(w.clone(), kind);
            let prompt = [1u16, 9, 4, 22, 7, 13];
            let mut donor = lm.new_cache();
            let _ = lm.forward(&prompt, Some(&mut donor));
            let mut adopted = donor.share_prefix(donor.len);
            assert_eq!(adopted.len, prompt.len());
            assert!(adopted.shared_pages() > 0, "adoption must alias pages");
            // Oracle: an independent cache prefilled the same way.
            let mut fresh = lm.new_cache();
            let _ = lm.forward(&prompt, Some(&mut fresh));
            let a = lm.decode_step(7, &mut adopted);
            let b = lm.decode_step(7, &mut fresh);
            assert_eq!(a.as_slice(), b.as_slice(), "{}", kind.name());
            // The donor decodes as if the share never happened.
            let mut fresh2 = lm.new_cache();
            let _ = lm.forward(&prompt, Some(&mut fresh2));
            let c = lm.decode_step(11, &mut donor);
            let d = lm.decode_step(11, &mut fresh2);
            assert_eq!(c.as_slice(), d.as_slice(), "{}", kind.name());
        }
    }

    #[test]
    fn perplexity_of_random_model_near_vocab() {
        // An untrained model predicts ~uniformly: ppl ≈ vocab.
        let mut lm = tiny();
        let tokens: Vec<u16> = (0..31).map(|i| (i * 7 % 32) as u16).collect();
        let ppl = lm.perplexity(&tokens);
        assert!(ppl > 8.0 && ppl < 128.0, "ppl={ppl}");
    }

    #[test]
    fn token_losses_length_and_finiteness() {
        let mut lm = tiny();
        let tokens = [1u16, 2, 3, 4, 5];
        let losses = lm.token_losses(&tokens);
        assert_eq!(losses.len(), 4);
        assert!(losses.iter().all(|l| l.is_finite() && *l >= 0.0));
    }

    #[test]
    fn generate_emits_valid_tokens() {
        let mut lm = tiny();
        let mut rng = Pcg64::seed_from_u64(1);
        let out = lm.generate(&[1, 2, 3], 8, 1.0, 8, &mut rng);
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|&t| (t as usize) < 32));
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Pcg64::seed_from_u64(2);
        let logits = [0.1f32, 2.5, -1.0, 2.4];
        assert_eq!(sample_row(&logits, 0.0, 4, &mut rng), 1);
    }

    #[test]
    fn top_k_limits_support() {
        let mut rng = Pcg64::seed_from_u64(3);
        let logits = [10.0f32, 9.0, -50.0, -50.0];
        for _ in 0..50 {
            let t = sample_row(&logits, 1.0, 2, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn int_attention_model_close_to_fp32_model() {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 32, mlp_mult: 2 };
        let w = Weights::random(cfg, 3);
        let tokens: Vec<u16> = (0..16).map(|i| (i * 5 % 32) as u16).collect();
        let mut fp = TinyLm::new(w.clone(), PipelineKind::Fp32);
        let mut int = TinyLm::new(w, PipelineKind::IntAttention);
        let lf = fp.forward(&tokens, None);
        let li = int.forward(&tokens, None);
        let cos = crate::util::stats::cosine_similarity(lf.as_slice(), li.as_slice());
        assert!(cos > 0.98, "cos={cos}");
        // Perplexities should be in the same ballpark.
        let pf = fp.perplexity(&tokens);
        let pi = int.perplexity(&tokens);
        assert!((pf.ln() - pi.ln()).abs() < 0.5, "ppl {pf} vs {pi}");
    }
}
