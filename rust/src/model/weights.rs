//! Weight storage and the artifact loader.
//!
//! `python/compile/train.py` writes `artifacts/weights.bin` — all tensors as
//! little-endian f32, concatenated in the canonical order below — plus
//! `artifacts/model_meta.json` with the config and a checksum. The order is
//! the single source of truth shared by the trainer and this loader:
//!
//! ```text
//! tok_emb   [vocab, d_model]
//! pos_emb   [max_seq, d_model]
//! per layer i in 0..n_layers:
//!   ln1_g [d_model]  ln1_b [d_model]
//!   wq    [d_model, d_model]   (output-major: row o = weights of output o)
//!   wk, wv, wo same
//!   ln2_g [d_model]  ln2_b [d_model]
//!   w1    [d_mlp, d_model]  b1 [d_mlp]
//!   w2    [d_model, d_mlp]  b2 [d_model]
//! ln_f_g [d_model]  ln_f_b [d_model]
//! ```
//!
//! Projection matrices are stored **output-major** (pre-transposed), so the
//! Rust GEMM (`gemm_f32(a=x, bt=w)`) consumes them without a runtime
//! transpose. The LM head is tied to `tok_emb`.

use crate::model::config::ModelConfig;
use crate::tensor::MatF32;
use crate::util::json::Json;
use crate::util::prng::Pcg64;
use anyhow::{Context, Result};
use std::path::Path;

/// One transformer block's parameters.
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: MatF32,
    pub wk: MatF32,
    pub wv: MatF32,
    pub wo: MatF32,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: MatF32,
    pub b1: Vec<f32>,
    pub w2: MatF32,
    pub b2: Vec<f32>,
}

/// Full model parameters.
#[derive(Clone, Debug)]
pub struct Weights {
    pub cfg: ModelConfig,
    pub tok_emb: MatF32,
    pub pos_emb: MatF32,
    pub blocks: Vec<BlockWeights>,
    pub ln_f_g: Vec<f32>,
    pub ln_f_b: Vec<f32>,
}

/// Sequential reader over the flat f32 buffer.
struct Cursor<'a> {
    data: &'a [f32],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [f32]> {
        anyhow::ensure!(self.pos + n <= self.data.len(), "weights.bin truncated at {}", self.pos);
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn vec(&mut self, n: usize) -> Result<Vec<f32>> {
        Ok(self.take(n)?.to_vec())
    }

    fn mat(&mut self, r: usize, c: usize) -> Result<MatF32> {
        Ok(MatF32::from_vec(r, c, self.take(r * c)?.to_vec()))
    }
}

impl Weights {
    /// Load from an artifacts directory (`model_meta.json` + `weights.bin`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Weights> {
        let dir = dir.as_ref();
        let meta_text = std::fs::read_to_string(dir.join("model_meta.json"))
            .with_context(|| format!("read {}/model_meta.json", dir.display()))?;
        let meta = Json::parse(&meta_text).context("parse model_meta.json")?;
        let cfg = ModelConfig::from_json(&meta)?;
        let bytes = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("read {}/weights.bin", dir.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "weights.bin not a multiple of 4 bytes");
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let expected = meta.req_usize("param_count")?;
        anyhow::ensure!(
            floats.len() == expected,
            "weights.bin has {} params, meta says {}",
            floats.len(),
            expected
        );
        Self::from_flat(cfg, &floats)
    }

    /// Deserialize from the canonical flat order.
    pub fn from_flat(cfg: ModelConfig, flat: &[f32]) -> Result<Weights> {
        cfg.validate()?;
        anyhow::ensure!(
            flat.len() == cfg.param_count(),
            "flat buffer {} != param_count {}",
            flat.len(),
            cfg.param_count()
        );
        let d = cfg.d_model;
        let dm = cfg.d_mlp();
        let mut cur = Cursor { data: flat, pos: 0 };
        let tok_emb = cur.mat(cfg.vocab, d)?;
        let pos_emb = cur.mat(cfg.max_seq, d)?;
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            blocks.push(BlockWeights {
                ln1_g: cur.vec(d)?,
                ln1_b: cur.vec(d)?,
                wq: cur.mat(d, d)?,
                wk: cur.mat(d, d)?,
                wv: cur.mat(d, d)?,
                wo: cur.mat(d, d)?,
                ln2_g: cur.vec(d)?,
                ln2_b: cur.vec(d)?,
                w1: cur.mat(dm, d)?,
                b1: cur.vec(dm)?,
                w2: cur.mat(d, dm)?,
                b2: cur.vec(d)?,
            });
        }
        let ln_f_g = cur.vec(d)?;
        let ln_f_b = cur.vec(d)?;
        debug_assert_eq!(cur.pos, flat.len());
        Ok(Weights { cfg, tok_emb, pos_emb, blocks, ln_f_g, ln_f_b })
    }

    /// Serialize to the canonical flat order (inverse of [`from_flat`]).
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.cfg.param_count());
        out.extend_from_slice(self.tok_emb.as_slice());
        out.extend_from_slice(self.pos_emb.as_slice());
        for b in &self.blocks {
            out.extend_from_slice(&b.ln1_g);
            out.extend_from_slice(&b.ln1_b);
            out.extend_from_slice(b.wq.as_slice());
            out.extend_from_slice(b.wk.as_slice());
            out.extend_from_slice(b.wv.as_slice());
            out.extend_from_slice(b.wo.as_slice());
            out.extend_from_slice(&b.ln2_g);
            out.extend_from_slice(&b.ln2_b);
            out.extend_from_slice(b.w1.as_slice());
            out.extend_from_slice(&b.b1);
            out.extend_from_slice(b.w2.as_slice());
            out.extend_from_slice(&b.b2);
        }
        out.extend_from_slice(&self.ln_f_g);
        out.extend_from_slice(&self.ln_f_b);
        out
    }

    /// Random initialization (for tests and the untrained-model paths).
    pub fn random(cfg: ModelConfig, seed: u64) -> Weights {
        let mut rng = Pcg64::seed_from_u64(seed);
        let d = cfg.d_model;
        let dm = cfg.d_mlp();
        let std = 0.02f32.max(1.0 / (d as f32).sqrt());
        let mat = |r: usize, c: usize, rng: &mut Pcg64| {
            MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal_ms(0.0, std)).collect())
        };
        let blocks = (0..cfg.n_layers)
            .map(|_| BlockWeights {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                wq: mat(d, d, &mut rng),
                wk: mat(d, d, &mut rng),
                wv: mat(d, d, &mut rng),
                wo: mat(d, d, &mut rng),
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                w1: mat(dm, d, &mut rng),
                b1: vec![0.0; dm],
                w2: mat(d, dm, &mut rng),
                b2: vec![0.0; d],
            })
            .collect();
        Weights {
            cfg,
            tok_emb: mat(cfg.vocab, d, &mut rng),
            pos_emb: mat(cfg.max_seq, d, &mut rng),
            blocks,
            ln_f_g: vec![1.0; d],
            ln_f_b: vec![0.0; d],
        }
    }

    /// Write to an artifacts directory (the format `load` reads); used by
    /// tests and by tooling that snapshots randomly initialized models.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let flat = self.to_flat();
        let mut bytes = Vec::with_capacity(flat.len() * 4);
        for f in &flat {
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        std::fs::write(dir.join("weights.bin"), bytes)?;
        let mut meta = self.cfg.to_json();
        if let Json::Obj(map) = &mut meta {
            map.insert("param_count".into(), Json::num(flat.len() as f64));
        }
        std::fs::write(dir.join("model_meta.json"), meta.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_round_trip_is_identity() {
        let cfg = ModelConfig { vocab: 8, d_model: 4, n_layers: 2, n_heads: 2, max_seq: 6, mlp_mult: 2 };
        let w = Weights::random(cfg, 42);
        let flat = w.to_flat();
        assert_eq!(flat.len(), cfg.param_count());
        let w2 = Weights::from_flat(cfg, &flat).unwrap();
        assert_eq!(w2.to_flat(), flat);
    }

    #[test]
    fn save_load_round_trip() {
        let cfg = ModelConfig { vocab: 8, d_model: 4, n_layers: 1, n_heads: 1, max_seq: 4, mlp_mult: 2 };
        let w = Weights::random(cfg, 7);
        let dir = std::env::temp_dir().join("intattn_weights_test");
        w.save(&dir).unwrap();
        let back = Weights::load(&dir).unwrap();
        assert_eq!(back.cfg, cfg);
        assert_eq!(back.to_flat(), w.to_flat());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_buffer_rejected() {
        let cfg = ModelConfig { vocab: 8, d_model: 4, n_layers: 1, n_heads: 1, max_seq: 4, mlp_mult: 2 };
        let w = Weights::random(cfg, 7);
        let mut flat = w.to_flat();
        flat.pop();
        assert!(Weights::from_flat(cfg, &flat).is_err());
    }

    #[test]
    fn corrupted_meta_rejected() {
        let cfg = ModelConfig { vocab: 8, d_model: 4, n_layers: 1, n_heads: 1, max_seq: 4, mlp_mult: 2 };
        let w = Weights::random(cfg, 7);
        let dir = std::env::temp_dir().join("intattn_weights_bad_meta");
        w.save(&dir).unwrap();
        // Lie about param_count.
        let meta = std::fs::read_to_string(dir.join("model_meta.json")).unwrap();
        std::fs::write(dir.join("model_meta.json"), meta.replace("\"param_count\":", "\"param_count\":1,\"x\":")).unwrap();
        assert!(Weights::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn random_layernorm_params_are_identity() {
        let w = Weights::random(ModelConfig::tiny(), 1);
        assert!(w.blocks[0].ln1_g.iter().all(|&x| x == 1.0));
        assert!(w.ln_f_b.iter().all(|&x| x == 0.0));
    }
}
