//! Byte-level tokenizer: every UTF-8 byte is a token id in `0..256`.
//! Matches `python/compile/train.py`'s corpus encoding exactly.

/// Encode text to byte tokens.
pub fn encode(text: &str) -> Vec<u16> {
    text.as_bytes().iter().map(|&b| b as u16).collect()
}

/// Decode tokens back to text (invalid UTF-8 becomes U+FFFD).
pub fn decode(tokens: &[u16]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Vocabulary size of the byte tokenizer.
pub const VOCAB: usize = 256;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_round_trip() {
        let s = "the quick brown fox 0123!";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn utf8_round_trip() {
        let s = "héllo ✓ 世界";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn tokens_in_vocab() {
        assert!(encode("日本語テスト").iter().all(|&t| (t as usize) < VOCAB));
    }

    #[test]
    fn invalid_sequences_are_replaced_not_panicking() {
        let out = decode(&[0xFF, 0xFE, b'a' as u16]);
        assert!(out.ends_with('a'));
    }
}
