//! A small byte-level transformer language model whose attention backend is
//! pluggable — the substitute for the paper's Llama/OPT/Qwen evaluations
//! (see DESIGN.md §2: no pretrained weights exist on this host, so a tiny LM
//! is trained at build time by `python/compile/train.py` and its weights are
//! loaded here).
//!
//! Only the attention block changes between pipelines — embeddings,
//! layernorms and MLPs stay FP32, matching the paper's drop-in scope (§3:
//! "transforms the conventional quantized attention block").

pub mod config;
pub mod weights;
pub mod layers;
pub mod lm;
pub mod tokenizer;

pub use config::ModelConfig;
pub use lm::TinyLm;
pub use weights::Weights;
