//! Workload generators: attention inputs with realistic statistics and
//! request traces for the serving engine.

use crate::tensor::MatF32;
use crate::util::prng::{Pcg64, Zipf};

/// Random Q, K, V with i.i.d. `N(0, std²)` entries — the distribution used
/// by the paper's operator-level speed benchmarks (Figures 6–7, Table 8).
pub fn random_qkv(rng: &mut Pcg64, l: usize, d: usize, std: f32) -> (MatF32, MatF32, MatF32) {
    let gen = |rng: &mut Pcg64| {
        MatF32::from_vec(l, d, (0..l * d).map(|_| rng.normal_ms(0.0, std)).collect())
    };
    (gen(rng), gen(rng), gen(rng))
}

/// Q, K, V with the *peaked* logit structure real attention exhibits
/// (Figure 4): keys form a few clusters, queries align with one cluster
/// each, so every logit row has a small dominant subset. `sharpness`
/// controls how dominant (≈2–4 is LLM-like).
pub fn clustered_qkv(
    rng: &mut Pcg64,
    l: usize,
    d: usize,
    clusters: usize,
    sharpness: f32,
) -> (MatF32, MatF32, MatF32) {
    let clusters = clusters.max(1);
    let centers: Vec<Vec<f32>> = (0..clusters).map(|_| rng.normal_vec(d)).collect();
    let mut build = |align: bool| {
        let mut m = MatF32::zeros(l, d);
        for r in 0..l {
            let c = &centers[rng.below(clusters as u64) as usize];
            let row = m.row_mut(r);
            for (i, x) in row.iter_mut().enumerate() {
                let base = if align { sharpness * c[i] } else { 0.0 };
                *x = base + rng.normal();
            }
        }
        m
    };
    let q = build(true);
    let k = build(true);
    let v = build(false);
    (q, k, v)
}

/// A single serving request for the coordinator workloads.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    /// Arrival time offset from trace start, in microseconds.
    pub arrival_us: u64,
    /// Prompt length (prefill tokens).
    pub prompt_len: usize,
    /// Tokens to generate (decode steps).
    pub gen_len: usize,
}

/// Poisson-arrival request trace with Zipf-bucketed prompt lengths —
/// the long-tail mix on-device serving sees.
pub fn request_trace(
    rng: &mut Pcg64,
    n: usize,
    rate_per_s: f64,
    len_buckets: &[usize],
    max_gen: usize,
) -> Vec<TraceRequest> {
    assert!(!len_buckets.is_empty());
    let zipf = Zipf::new(len_buckets.len(), 1.1);
    let mut t_us = 0f64;
    (0..n)
        .map(|_| {
            t_us += rng.exponential(rate_per_s) * 1e6;
            let bucket = zipf.sample(rng);
            let base = len_buckets[bucket];
            // jitter within ±25% of the bucket
            let jitter = (base as f64 * 0.25) as i64;
            let plen = (base as i64 + rng.range_i64(-jitter.max(1), jitter.max(1) + 1)).max(1);
            TraceRequest {
                arrival_us: t_us as u64,
                prompt_len: plen as usize,
                gen_len: 1 + rng.below(max_gen.max(1) as u64) as usize,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_qkv_shapes_and_stats() {
        let mut rng = Pcg64::seed_from_u64(1);
        let (q, k, v) = random_qkv(&mut rng, 64, 32, 2.0);
        assert_eq!((q.rows(), q.cols()), (64, 32));
        assert_eq!((k.rows(), v.rows()), (64, 64));
        let std = (q.frobenius() / (64f64 * 32.0).sqrt()) as f32;
        assert!((std - 2.0).abs() < 0.3, "std={std}");
    }

    #[test]
    fn clustered_logits_are_peaked() {
        // The Figure 4 premise: clustered inputs produce rows where the top
        // few logits dominate. Compare top-1 share vs uniform expectation.
        let mut rng = Pcg64::seed_from_u64(2);
        let (q, k, _) = clustered_qkv(&mut rng, 128, 32, 4, 3.0);
        // compute row softmax mass of the argmax logit
        let mut top_share = 0f64;
        for i in 0..q.rows() {
            let logits: Vec<f32> = (0..k.rows())
                .map(|j| {
                    (0..32).map(|c| q.get(i, c) * k.get(j, c)).sum::<f32>()
                        / (32f32).sqrt()
                })
                .collect();
            let m = logits.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
            let z: f32 = exps.iter().sum();
            top_share += exps.iter().cloned().fold(0f32, f32::max) as f64 / z as f64;
        }
        top_share /= q.rows() as f64;
        assert!(top_share > 0.2, "top-1 softmax share {top_share} not peaked");
    }

    #[test]
    fn trace_is_time_ordered_with_sane_lengths() {
        let mut rng = Pcg64::seed_from_u64(3);
        let trace = request_trace(&mut rng, 100, 50.0, &[64, 256, 1024], 32);
        assert_eq!(trace.len(), 100);
        for w in trace.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
        assert!(trace.iter().all(|r| r.prompt_len >= 1 && r.gen_len >= 1));
        assert!(trace.iter().all(|r| r.prompt_len <= 1024 + 256));
        // Zipf: the smallest bucket must be the most common.
        let small = trace.iter().filter(|r| r.prompt_len <= 80).count();
        let large = trace.iter().filter(|r| r.prompt_len > 800).count();
        assert!(small > large, "small={small} large={large}");
    }
}
