//! Fidelity evaluation helpers: operator-level (P̂ against the exact FP32
//! probability matrix — Table 9's metrics) and model-level (tiny-LM
//! perplexity and probe accuracy under each pipeline — the Table 1/2/3/5
//! substitutions, see DESIGN.md §2).

use crate::attention::PipelineKind;
use crate::model::lm::TinyLm;
use crate::model::weights::Weights;
use crate::softmax::index_softmax::Mask;
use crate::tensor::{MatF32, MatI32};
use crate::util::prng::Pcg64;
use crate::util::stats;

/// Exact FP32 softmax probabilities of scaled INT32 logits.
pub fn exact_probs(logits: &MatI32, alpha: f32, mask: Mask) -> MatF32 {
    crate::softmax::float_softmax::softmax_of_scaled_logits(logits, alpha, mask)
}

/// Operator-level fidelity record (the Table 9 row format).
#[derive(Clone, Copy, Debug, Default)]
pub struct ProbFidelity {
    pub cos_sim: f64,
    pub rel_l1: f64,
    pub rmse: f64,
}

impl ProbFidelity {
    pub fn of(reference: &MatF32, candidate: &MatF32) -> ProbFidelity {
        ProbFidelity {
            cos_sim: stats::cosine_similarity(reference.as_slice(), candidate.as_slice()),
            rel_l1: stats::relative_l1(reference.as_slice(), candidate.as_slice()),
            rmse: stats::rmse(reference.as_slice(), candidate.as_slice()),
        }
    }
}

/// Model-level fidelity of one pipeline on held-out token streams:
/// perplexity plus a synthetic "task accuracy" probe (next-token top-1
/// agreement with the FP32 model — the stand-in for the benchmark accuracy
/// columns of Tables 1–3).
#[derive(Clone, Debug, Default)]
pub struct LmFidelity {
    pub pipeline: String,
    pub perplexity: f64,
    /// Fraction of positions where this pipeline's argmax next-token matches
    /// the FP32 model's argmax (1.0 = identical predictions).
    pub top1_agreement: f64,
    /// Mean absolute difference in per-token loss vs FP32.
    pub loss_mad: f64,
}

/// Evaluate `kind` on `eval_seqs` against an FP32 reference of the same
/// weights. Sequences must each have ≥ 2 tokens.
pub fn eval_lm_fidelity(
    weights: &Weights,
    kind: PipelineKind,
    eval_seqs: &[Vec<u16>],
) -> LmFidelity {
    let mut fp = TinyLm::new(weights.clone(), PipelineKind::Fp32);
    let mut lm = TinyLm::new(weights.clone(), kind);
    let mut ce_total = 0f64;
    let mut ce_count = 0usize;
    let mut agree = 0usize;
    let mut positions = 0usize;
    let mut mad = 0f64;
    for seq in eval_seqs {
        let logits_fp = fp.forward(seq, None);
        let logits = lm.forward(seq, None);
        for i in 0..seq.len() - 1 {
            let row_fp = logits_fp.row(i);
            let row = logits.row(i);
            let am_fp = argmax(row_fp);
            let am = argmax(row);
            if am == am_fp {
                agree += 1;
            }
            positions += 1;
            let target = seq[i + 1] as usize;
            let l_fp = ce_of(row_fp, target);
            let l = ce_of(row, target);
            ce_total += l;
            ce_count += 1;
            mad += (l - l_fp).abs();
        }
    }
    LmFidelity {
        pipeline: kind.name().to_string(),
        perplexity: (ce_total / ce_count as f64).exp(),
        top1_agreement: agree as f64 / positions as f64,
        loss_mad: mad / positions as f64,
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

fn ce_of(row: &[f32], target: usize) -> f64 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let logsum: f64 = (row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>()).ln() + m as f64;
    logsum - row[target] as f64
}

/// Build held-out evaluation sequences from the corpus the trainer wrote
/// (`artifacts/corpus_eval.txt`), or synthesize structured text if absent.
pub fn eval_sequences(
    artifacts_dir: &std::path::Path,
    n: usize,
    len: usize,
    vocab: usize,
) -> Vec<Vec<u16>> {
    let text = std::fs::read_to_string(artifacts_dir.join("corpus_eval.txt"))
        .unwrap_or_else(|_| synthetic_corpus(4096, 99));
    let tokens: Vec<u16> = crate::model::tokenizer::encode(&text)
        .into_iter()
        .map(|t| t % vocab as u16)
        .collect();
    let mut out = Vec::with_capacity(n);
    let mut rng = Pcg64::seed_from_u64(1234);
    for _ in 0..n {
        if tokens.len() <= len + 1 {
            out.push(tokens.clone());
        } else {
            let start = rng.below((tokens.len() - len - 1) as u64) as usize;
            out.push(tokens[start..start + len].to_vec());
        }
    }
    out
}

/// The synthetic corpus generator shared with `train.py` in spirit: a
/// Markov-ish arithmetic/word-pattern text with learnable structure.
pub fn synthetic_corpus(chars: usize, seed: u64) -> String {
    let mut rng = Pcg64::seed_from_u64(seed);
    let words = [
        "edge", "device", "tensor", "integer", "attention", "softmax", "kernel",
        "lookup", "table", "quantize", "latency", "energy", "pipeline", "index",
    ];
    let mut out = String::with_capacity(chars + 64);
    while out.len() < chars {
        let a = rng.below(10);
        let b = rng.below(10);
        match rng.below(3) {
            0 => {
                // arithmetic pattern: "3 + 4 = 7 ."
                out.push_str(&format!("{a} + {b} = {} . ", a + b));
            }
            1 => {
                // word bigram pattern: deterministic successor
                let w = words[rng.below(words.len() as u64) as usize];
                let idx = words.iter().position(|&x| x == w).unwrap();
                let next = words[(idx + 1) % words.len()];
                out.push_str(w);
                out.push(' ');
                out.push_str(next);
                out.push_str(" . ");
            }
            _ => {
                // counting pattern
                out.push_str(&format!("{a} {} {} . ", (a + 1) % 10, (a + 2) % 10));
            }
        }
    }
    out.truncate(chars);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn prob_fidelity_identity() {
        let mut rng = Pcg64::seed_from_u64(1);
        let p = MatF32::from_vec(2, 4, (0..8).map(|_| rng.next_f32()).collect());
        let f = ProbFidelity::of(&p, &p);
        assert!((f.cos_sim - 1.0).abs() < 1e-9);
        assert_eq!(f.rel_l1, 0.0);
        assert_eq!(f.rmse, 0.0);
    }

    #[test]
    fn synthetic_corpus_is_deterministic_and_structured() {
        let a = synthetic_corpus(500, 7);
        let b = synthetic_corpus(500, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 500);
        assert!(a.contains('='), "has arithmetic patterns");
    }

    #[test]
    fn eval_sequences_without_artifacts_fall_back() {
        let dir = std::env::temp_dir().join("intattn_no_artifacts");
        let seqs = eval_sequences(&dir, 3, 64, 256);
        assert_eq!(seqs.len(), 3);
        assert!(seqs.iter().all(|s| s.len() == 64));
    }

    #[test]
    fn lm_fidelity_fp32_is_perfect_agreement() {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 32, mlp_mult: 2 };
        let w = Weights::random(cfg, 5);
        let seqs = vec![vec![1u16, 5, 9, 2, 8, 3, 1, 4]];
        let f = eval_lm_fidelity(&w, PipelineKind::Fp32, &seqs);
        assert!((f.top1_agreement - 1.0).abs() < 1e-12);
        assert!(f.loss_mad < 1e-9);
        assert!(f.perplexity > 1.0);
    }

    #[test]
    fn lm_fidelity_int_close_but_not_exact() {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 32, mlp_mult: 2 };
        let w = Weights::random(cfg, 5);
        let seqs: Vec<Vec<u16>> = (0..3)
            .map(|s| (0..16).map(|i| ((i * 7 + s * 3) % 32) as u16).collect())
            .collect();
        let f = eval_lm_fidelity(&w, PipelineKind::IntAttention, &seqs);
        assert!(f.top1_agreement > 0.6, "agreement {}", f.top1_agreement);
        assert!(f.loss_mad < 1.0, "mad {}", f.loss_mad);
    }
}
