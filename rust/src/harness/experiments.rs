//! Experiment drivers — one per table/figure of the paper (DESIGN.md §5).
//! Each driver returns structured rows (so tests can assert the *shape* of
//! the result — who wins, by how much) and renders a paper-style table.
//!
//! Benchmarks default to this host's practical sizes; `INTATTN_FULL=1`
//! extends sweeps to the paper's 16 K maximum.

use crate::attention::{
    batch_row, build_pipeline, kv_page_rows, page_pool_stats, AttentionConfig, KvState,
    PipelineKind,
};
use crate::energy::{EnergyModel, OpCounts};
use crate::harness::fidelity::{eval_lm_fidelity, eval_sequences, exact_probs, LmFidelity, ProbFidelity};
use crate::harness::workload::{clustered_qkv, random_qkv};
use crate::model::lm::TinyLm;
use crate::model::weights::Weights;
use crate::quant::{dequantize_p_i8, dequantize_p_u8, quantize_i8, quantize_p_i8, quantize_p_u8};
use crate::softmax::index_softmax::{IndexSoftmax, IndexSoftmaxConfig, Mask};
use crate::softmax::lut::ExpLut;
use crate::tensor::{MatF32, MatI32};
use crate::util::bench::Table;
use crate::util::prng::Pcg64;

/// Default sequence sweep for this 1-core host; the paper's sweep is
/// 1K..16K — enable with `INTATTN_FULL=1`.
pub fn default_seq_lens() -> Vec<usize> {
    if std::env::var("INTATTN_FULL").map(|v| v == "1").unwrap_or(false) {
        vec![1024, 2048, 4096, 8192, 16384]
    } else {
        vec![256, 512, 1024, 2048]
    }
}

/// Paper head dimension.
pub const HEAD_DIM: usize = 128;

// ---------------------------------------------------------------------------
// Figure 2 — softmax-path share per precision

#[derive(Clone, Debug)]
pub struct BreakdownRow {
    pub pipeline: PipelineKind,
    pub seq_len: usize,
    pub softmax_path_share: f64,
    pub total_ms: f64,
}

pub fn fig2_breakdown(seq_lens: &[usize], d: usize, threads: usize) -> Vec<BreakdownRow> {
    let mut rng = Pcg64::seed_from_u64(2);
    let mut rows = Vec::new();
    for &l in seq_lens {
        let (q, k, v) = random_qkv(&mut rng, l, d, 1.0);
        for kind in [PipelineKind::Fp32, PipelineKind::Fp16, PipelineKind::QuantOnly, PipelineKind::IntAttention] {
            let cfg = AttentionConfig::new(l, d).with_threads(threads);
            let mut pipe = build_pipeline(kind, cfg);
            let _ = pipe.forward(&q, &k, &v);
            let t = pipe.stage_times();
            rows.push(BreakdownRow {
                pipeline: kind,
                seq_len: l,
                softmax_path_share: t.softmax_path_share(),
                total_ms: t.total_ns() as f64 / 1e6,
            });
        }
    }
    rows
}

pub fn render_fig2(rows: &[BreakdownRow]) -> Table {
    let mut t = Table::new(
        "Figure 2 — dequantize→softmax→requantize share of attention latency",
        &["pipeline", "L", "softmax-path %", "total ms"],
    );
    for r in rows {
        t.row(vec![
            r.pipeline.name().into(),
            r.seq_len.to_string(),
            format!("{:.1}", 100.0 * r.softmax_path_share),
            format!("{:.2}", r.total_ms),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Figure 4 — exponential sparsity

#[derive(Clone, Debug)]
pub struct SparsityRow {
    pub top_frac: f64,
    /// Softmax mass captured by the top `top_frac` of logits (mean over rows).
    pub mass: f64,
}

pub fn fig4_sparsity(l: usize, d: usize) -> Vec<SparsityRow> {
    let mut rng = Pcg64::seed_from_u64(4);
    let (q, k, _v) = clustered_qkv(&mut rng, l, d, 8, 3.0);
    let qq = quantize_i8(&q);
    let kq = quantize_i8(&k);
    let mut logits = MatI32::zeros(l, l);
    crate::gemm::gemm_i8(&qq.data, &kq.data, &mut logits);
    let alpha = qq.scale * kq.scale / (d as f32).sqrt();
    let p = exact_probs(&logits, alpha, Mask::None);
    let fracs = [0.01, 0.02, 0.05, 0.10, 0.25, 0.50];
    fracs
        .iter()
        .map(|&f| {
            let mut mass = 0f64;
            for r in 0..p.rows() {
                let mut row: Vec<f32> = p.row(r).to_vec();
                row.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let k = ((f * l as f64).ceil() as usize).max(1);
                mass += row[..k].iter().map(|&x| x as f64).sum::<f64>();
            }
            SparsityRow { top_frac: f, mass: mass / p.rows() as f64 }
        })
        .collect()
}

pub fn render_fig4(rows: &[SparsityRow]) -> Table {
    let mut t = Table::new(
        "Figure 4 — softmax mass concentrated in top logits (clustered workload)",
        &["top fraction of logits", "softmax mass captured"],
    );
    for r in rows {
        t.row(vec![format!("{:.0}%", 100.0 * r.top_frac), format!("{:.3}", r.mass)]);
    }
    t
}

// ---------------------------------------------------------------------------
// Figure 5 — LUT resolution under equal memory budget

#[derive(Clone, Debug)]
pub struct LutRow {
    pub method: String,
    pub entries: usize,
    pub bytes: usize,
    pub max_abs_err: f64,
}

pub fn fig5_lut_resolution() -> Vec<LutRow> {
    let ours = ExpLut::paper_default();
    let mut rows = vec![LutRow {
        method: "IndexSoftmax (b=5, UINT8)".into(),
        entries: ours.len(),
        bytes: ours.u8_bytes(),
        max_abs_err: ours.max_abs_error_u8(),
    }];
    // EXAQ with f32 entries at the same 32 B budget: INT3 → 8 entries; INT2 → 4.
    for (bits, name) in [(3u32, "EXAQ INT3 (8×f32)"), (2, "EXAQ INT2 (4×f32)")] {
        let lut = ExpLut::new(bits, crate::softmax::lut::DEFAULT_C);
        rows.push(LutRow {
            method: name.into(),
            entries: lut.len(),
            bytes: lut.len() * 4,
            max_abs_err: lut.max_abs_error_f32(),
        });
    }
    rows
}

pub fn render_fig5(rows: &[LutRow]) -> Table {
    let mut t = Table::new(
        "Figure 5 — LUT fidelity under a 32-byte budget",
        &["method", "entries", "bytes", "max |err| vs exp(-x)"],
    );
    for r in rows {
        t.row(vec![
            r.method.clone(),
            r.entries.to_string(),
            r.bytes.to_string(),
            format!("{:.5}", r.max_abs_err),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Figures 6/7 + Table 8 — throughput & latency sweeps

#[derive(Clone, Debug)]
pub struct SpeedRow {
    pub pipeline: PipelineKind,
    pub seq_len: usize,
    pub mean_ms: f64,
    pub gflops: f64,
}

/// One platform configuration's speed sweep (Fig 6 = config "rk3588s2-like",
/// Fig 7 = "m2-like"; on this host they differ in thread count). `threads`
/// selects the cached persistent [`crate::util::threadpool::ParallelPool`]
/// of that width (1 = inline, no dispatch overhead).
pub fn speed_sweep(seq_lens: &[usize], d: usize, threads: usize) -> Vec<SpeedRow> {
    let mut rng = Pcg64::seed_from_u64(6);
    let bench_cfg = crate::util::bench::BenchConfig::from_env(crate::util::bench::BenchConfig::heavy());
    let mut rows = Vec::new();
    for &l in seq_lens {
        let (q, k, v) = random_qkv(&mut rng, l, d, 1.0);
        for kind in PipelineKind::headline() {
            let cfg = AttentionConfig::new(l, d).with_threads(threads);
            let mut pipe = build_pipeline(kind, cfg);
            let m = crate::util::bench::bench(kind.name(), bench_cfg, |_| {
                pipe.forward(&q, &k, &v)
            });
            let flops = cfg.gemm_flops(l) as f64;
            rows.push(SpeedRow {
                pipeline: kind,
                seq_len: l,
                mean_ms: m.mean_ms(),
                gflops: flops / (m.mean_ms() / 1e3) / 1e9,
            });
        }
    }
    rows
}

pub fn render_speed(rows: &[SpeedRow], title: &str) -> Table {
    let mut t = Table::new(title, &["pipeline", "L", "latency ms", "GFLOP/s", "speedup vs FP16"]);
    for r in rows {
        let fp16 = rows
            .iter()
            .find(|x| x.seq_len == r.seq_len && x.pipeline == PipelineKind::Fp16)
            .map(|x| x.mean_ms)
            .unwrap_or(r.mean_ms);
        t.row(vec![
            r.pipeline.name().into(),
            r.seq_len.to_string(),
            format!("{:.2}", r.mean_ms),
            format!("{:.2}", r.gflops),
            format!("{:.2}x", fp16 / r.mean_ms),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Decode throughput — the serving-path bench (stateful prefill/decode API)

#[derive(Clone, Debug)]
pub struct DecodeRow {
    pub pipeline: PipelineKind,
    /// Context length already resident in the KV state when decoding starts.
    pub ctx: usize,
    /// Decoded tokens per second at that context length.
    pub tok_s: f64,
    /// Mean Quantize-stage nanoseconds per decoded token. For the stateful
    /// integer pipelines this is O(1) in `ctx` — the step quantizes only the
    /// new K/V row and the 1-row query, never the resident history.
    pub quantize_ns_per_tok: f64,
    /// KV state footprint (allocated page capacity, native widths) at the
    /// end of the run.
    pub kv_bytes: usize,
    /// Pages the state holds at the end of the run.
    pub kv_pages: usize,
    /// Bytes the pre-paging contiguous layout would have memcpy'd growing
    /// this run's K+V `Vec`s (amortized doubling over the same append
    /// schedule: one prefill block + per-token rows). The paged layout's
    /// append-path copy traffic is **zero** — appends fill the tail page in
    /// place and new pages come from the pool.
    pub append_copy_bytes_contiguous: u64,
}

/// Bytes a contiguous growing `Vec` memcpy's across an append schedule of
/// `blocks` row-counts (`d` elements per row, `elem_bytes` wide), under the
/// standard amortized-doubling growth policy the pre-paging KV layout used:
/// every time capacity is exhausted the whole resident prefix is copied to
/// the new allocation. One K or V side; the caller doubles it for a state.
/// Paged residency pays none of this — the decode bench reports both.
pub fn contiguous_realloc_copy_bytes(blocks: &[usize], d: usize, elem_bytes: usize) -> u64 {
    let (mut cap, mut len, mut copied) = (0usize, 0usize, 0u64);
    for &rows in blocks {
        let need = rows * d;
        if cap - len < need {
            copied += len as u64;
            cap = (cap * 2).max(len + need);
        }
        len += need;
    }
    copied * elem_bytes as u64
}

/// Single-head decode throughput: prefill `ctx` positions into a KV state,
/// then time `gen_tokens` incremental decode steps.
pub fn decode_sweep(ctx_lens: &[usize], d: usize, gen_tokens: usize, threads: usize) -> Vec<DecodeRow> {
    let mut rng = Pcg64::seed_from_u64(31);
    let mut rows = Vec::new();
    for &ctx in ctx_lens {
        for kind in PipelineKind::headline() {
            let cfg = AttentionConfig::new(ctx + gen_tokens, d).with_threads(threads);
            let mut pipe = build_pipeline(kind, cfg);
            let mut st = pipe.begin_state();
            let (q, k, v) = random_qkv(&mut rng, ctx, d, 1.0);
            let _ = pipe.prefill(&mut st, &q, &k, &v);
            pipe.reset_stats();
            // Pre-generate the decode inputs so the timed loop is pure
            // pipeline work.
            let steps: Vec<_> = (0..gen_tokens)
                .map(|_| random_qkv(&mut rng, 1, d, 1.0))
                .collect();
            let t0 = std::time::Instant::now();
            for (q1, k1, v1) in &steps {
                crate::util::bench::black_box(pipe.decode_step(&mut st, q1, k1, v1));
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-12);
            let quantize_ns_per_tok = pipe
                .stage_times()
                .get_ns(crate::util::timer::Stage::Quantize) as f64
                / gen_tokens as f64;
            // What the pre-paging layout would have copied growing its K/V
            // Vecs over this exact schedule (one prefill block, then one
            // row per decoded token), both sides.
            let elem = crate::attention::kv_bytes_per_token(kind, 1) / 2;
            let mut schedule = vec![ctx];
            schedule.resize(1 + gen_tokens, 1);
            let copy_contig = 2 * contiguous_realloc_copy_bytes(&schedule, d, elem);
            rows.push(DecodeRow {
                pipeline: kind,
                ctx,
                tok_s: gen_tokens as f64 / dt,
                quantize_ns_per_tok,
                kv_bytes: st.bytes(),
                kv_pages: st.pages(),
                append_copy_bytes_contiguous: copy_contig,
            });
        }
    }
    rows
}

pub fn render_decode(rows: &[DecodeRow]) -> Table {
    let mut t = Table::new(
        "Decode throughput — stateful paged-KV path (single head, incremental decode)",
        &[
            "pipeline",
            "ctx",
            "tok/s",
            "quantize ns/tok",
            "kv bytes",
            "kv pages",
            "append copy B (contig→paged)",
            "speedup vs FP16",
        ],
    );
    for r in rows {
        let fp16 = rows
            .iter()
            .find(|x| x.ctx == r.ctx && x.pipeline == PipelineKind::Fp16)
            .map(|x| x.tok_s)
            .unwrap_or(r.tok_s);
        t.row(vec![
            r.pipeline.name().into(),
            r.ctx.to_string(),
            format!("{:.0}", r.tok_s),
            format!("{:.0}", r.quantize_ns_per_tok),
            r.kv_bytes.to_string(),
            r.kv_pages.to_string(),
            format!("{}→0", r.append_copy_bytes_contiguous),
            format!("{:.2}x", r.tok_s / fp16),
        ]);
    }
    t
}

/// JSON payload for the decode bench, in the `kv_rows_json` label/value
/// shape shared by the fig/tab reports.
pub fn decode_rows_json(rows: &[DecodeRow]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for r in rows {
        out.push((format!("{}@ctx{}:tok_s", r.pipeline.name(), r.ctx), r.tok_s));
        out.push((
            format!("{}@ctx{}:quantize_ns_per_tok", r.pipeline.name(), r.ctx),
            r.quantize_ns_per_tok,
        ));
        out.push((
            format!("{}@ctx{}:kv_bytes", r.pipeline.name(), r.ctx),
            r.kv_bytes as f64,
        ));
        out.push((
            format!("{}@ctx{}:kv_pages", r.pipeline.name(), r.ctx),
            r.kv_pages as f64,
        ));
        out.push((
            format!("{}@ctx{}:append_copy_bytes_contiguous", r.pipeline.name(), r.ctx),
            r.append_copy_bytes_contiguous as f64,
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Batched multi-sequence decode — grouped kernels vs the sequential loop

#[derive(Clone, Debug)]
pub struct BatchedDecodeRow {
    pub pipeline: PipelineKind,
    /// Context length resident in every sequence's KV state.
    pub ctx: usize,
    /// Number of concurrently decoding sequences.
    pub batch: usize,
    /// Aggregate decoded tok/s when the B sequences step one at a time —
    /// B separate 1-row GEMM pairs per round (the pre-batching engine; a
    /// 1-row GEMM cannot use more than one worker thread).
    pub seq_tok_s: f64,
    /// Aggregate decoded tok/s through `decode_step_batch`'s grouped
    /// kernels (one launch per GEMM side per round, workers split across
    /// sequences).
    pub batch_tok_s: f64,
}

impl BatchedDecodeRow {
    pub fn speedup(&self) -> f64 {
        if self.seq_tok_s > 0.0 {
            self.batch_tok_s / self.seq_tok_s
        } else {
            0.0
        }
    }
}

/// Batched-vs-sequential decode throughput: prefill `batch` single-head KV
/// states to `ctx` positions, then time `rounds` decode rounds driven (a)
/// sequentially and (b) through one `decode_step_batch` call per round.
/// Both paths start from clones of the same prefilled states and consume
/// the same inputs, so the comparison is kernel-shape only. The grouped
/// launches dispatch onto the cached `threads`-wide persistent pool
/// (~µs per launch), so they parallelize even at short contexts — the old
/// spawn-per-launch grain guard kept integer launches inline below
/// `8·ctx·d ≈ 2^20` resident elements.
pub fn batched_decode_sweep(
    ctx: usize,
    batches: &[usize],
    d: usize,
    rounds: usize,
    threads: usize,
) -> Vec<BatchedDecodeRow> {
    let mut rng = Pcg64::seed_from_u64(33);
    let mut rows = Vec::new();
    for &batch in batches {
        for kind in PipelineKind::headline() {
            let cfg = AttentionConfig::new(ctx + rounds, d).with_threads(threads);
            let mut pipe = build_pipeline(kind, cfg);
            let mut base: Vec<KvState> = Vec::with_capacity(batch);
            for _ in 0..batch {
                let mut st = pipe.begin_state();
                let (q, k, v) = random_qkv(&mut rng, ctx, d, 1.0);
                let _ = pipe.prefill(&mut st, &q, &k, &v);
                base.push(st);
            }
            // Pre-generate the stacked per-round inputs so the timed loops
            // are pure pipeline work.
            let steps: Vec<(MatF32, MatF32, MatF32)> =
                (0..rounds).map(|_| random_qkv(&mut rng, batch, d, 1.0)).collect();
            // (a) sequential: B decode_step calls per round.
            let mut st_seq = base.clone();
            let t0 = std::time::Instant::now();
            for (q, k, v) in &steps {
                for (i, st) in st_seq.iter_mut().enumerate() {
                    crate::util::bench::black_box(pipe.decode_step(
                        st,
                        &batch_row(q, i),
                        &batch_row(k, i),
                        &batch_row(v, i),
                    ));
                }
            }
            let dt_seq = t0.elapsed().as_secs_f64().max(1e-12);
            // (b) grouped: one decode_step_batch per round.
            let mut st_bat = base.clone();
            let t0 = std::time::Instant::now();
            for (q, k, v) in &steps {
                let mut refs: Vec<&mut KvState> = st_bat.iter_mut().collect();
                crate::util::bench::black_box(pipe.decode_step_batch(&mut refs, q, k, v));
            }
            let dt_bat = t0.elapsed().as_secs_f64().max(1e-12);
            let toks = (rounds * batch) as f64;
            rows.push(BatchedDecodeRow {
                pipeline: kind,
                ctx,
                batch,
                seq_tok_s: toks / dt_seq,
                batch_tok_s: toks / dt_bat,
            });
        }
    }
    rows
}

pub fn render_batched_decode(rows: &[BatchedDecodeRow]) -> Table {
    let mut t = Table::new(
        "Batched multi-sequence decode — grouped kernels vs sequential loop (aggregate tok/s)",
        &["pipeline", "ctx", "batch", "sequential tok/s", "batched tok/s", "speedup"],
    );
    for r in rows {
        t.row(vec![
            r.pipeline.name().into(),
            r.ctx.to_string(),
            r.batch.to_string(),
            format!("{:.0}", r.seq_tok_s),
            format!("{:.0}", r.batch_tok_s),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    t
}

/// JSON payload for the batched-decode bench (label/value rows).
pub fn batched_decode_rows_json(rows: &[BatchedDecodeRow]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for r in rows {
        let key = format!("{}@ctx{}b{}", r.pipeline.name(), r.ctx, r.batch);
        out.push((format!("{key}:seq_tok_s"), r.seq_tok_s));
        out.push((format!("{key}:batch_tok_s"), r.batch_tok_s));
        out.push((format!("{key}:speedup"), r.speedup()));
    }
    out
}

// ---------------------------------------------------------------------------
// Fused flash-decode — one-page-walk fused kernel vs unfused three-pass

#[derive(Clone, Debug)]
pub struct FusedDecodeRow {
    pub pipeline: PipelineKind,
    /// Context length resident in the KV state when decoding starts.
    pub ctx: usize,
    /// Decoded tok/s through the unfused three-pass decode (materialized
    /// L-length logit/probability rows, `fused_decode(false)`).
    pub unfused_tok_s: f64,
    /// Decoded tok/s through the fused walk (one KV page-walk per step,
    /// online renormalization, no L-length row).
    pub fused_tok_s: f64,
    /// Cosine similarity of the two arms' final decode outputs — the
    /// documented ε-bound riding along as a fidelity witness (the hard
    /// assertions live in `tests/decode_equivalence.rs`).
    pub cosine: f64,
}

impl FusedDecodeRow {
    pub fn speedup(&self) -> f64 {
        if self.unfused_tok_s > 0.0 {
            self.fused_tok_s / self.unfused_tok_s
        } else {
            0.0
        }
    }
}

/// Fused-vs-unfused decode throughput for the fused-capable integer
/// pipelines: prefill one KV state per arm (the prefill path ignores the
/// toggle), then time `gen_tokens` decode steps per arm over identical
/// pre-generated inputs. The fused walk reads each resident K̂/V̂ page once
/// per step where the unfused path reads K̂ pages, writes + re-reads an
/// L-length score row, and reads V̂ pages — so its advantage grows with the
/// resident context.
pub fn fused_decode_sweep(
    ctx_lens: &[usize],
    d: usize,
    gen_tokens: usize,
    threads: usize,
) -> Vec<FusedDecodeRow> {
    let mut rng = Pcg64::seed_from_u64(37);
    let mut rows = Vec::new();
    for &ctx in ctx_lens {
        for kind in [PipelineKind::IntAttention, PipelineKind::ExaqInt2, PipelineKind::ExaqInt3] {
            let cfg = AttentionConfig::new(ctx + gen_tokens, d).with_threads(threads);
            let mut plain = build_pipeline(kind, cfg.with_fused_decode(false));
            let mut fused = build_pipeline(kind, cfg.with_fused_decode(true));
            let mut st_u = plain.begin_state();
            let (q, k, v) = random_qkv(&mut rng, ctx, d, 1.0);
            let _ = plain.prefill(&mut st_u, &q, &k, &v);
            let mut st_f = st_u.clone();
            let steps: Vec<_> = (0..gen_tokens).map(|_| random_qkv(&mut rng, 1, d, 1.0)).collect();

            let mut last_u = MatF32::zeros(0, 0);
            let t0 = std::time::Instant::now();
            for (q1, k1, v1) in &steps {
                last_u = plain.decode_step(&mut st_u, q1, k1, v1);
                crate::util::bench::black_box(&last_u);
            }
            let dt_u = t0.elapsed().as_secs_f64().max(1e-12);

            let mut last_f = MatF32::zeros(0, 0);
            let t0 = std::time::Instant::now();
            for (q1, k1, v1) in &steps {
                last_f = fused.decode_step(&mut st_f, q1, k1, v1);
                crate::util::bench::black_box(&last_f);
            }
            let dt_f = t0.elapsed().as_secs_f64().max(1e-12);

            rows.push(FusedDecodeRow {
                pipeline: kind,
                ctx,
                unfused_tok_s: gen_tokens as f64 / dt_u,
                fused_tok_s: gen_tokens as f64 / dt_f,
                cosine: crate::util::stats::cosine_similarity(last_f.as_slice(), last_u.as_slice()),
            });
        }
    }
    rows
}

pub fn render_fused_decode(rows: &[FusedDecodeRow]) -> Table {
    let mut t = Table::new(
        "Fused flash-decode — one KV page-walk per step vs unfused three-pass (tok/s)",
        &["pipeline", "ctx", "unfused tok/s", "fused tok/s", "speedup", "cosine"],
    );
    for r in rows {
        t.row(vec![
            r.pipeline.name().into(),
            r.ctx.to_string(),
            format!("{:.0}", r.unfused_tok_s),
            format!("{:.0}", r.fused_tok_s),
            format!("{:.2}x", r.speedup()),
            format!("{:.6}", r.cosine),
        ]);
    }
    t
}

/// JSON payload for the fused-decode bench (label/value rows).
pub fn fused_decode_rows_json(rows: &[FusedDecodeRow]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for r in rows {
        let key = format!("{}@ctx{}", r.pipeline.name(), r.ctx);
        out.push((format!("{key}:unfused_tok_s"), r.unfused_tok_s));
        out.push((format!("{key}:fused_tok_s"), r.fused_tok_s));
        out.push((format!("{key}:speedup"), r.speedup()));
        out.push((format!("{key}:cosine"), r.cosine));
    }
    out
}

// ---------------------------------------------------------------------------
// Page-parallel fused decode — span-split walk vs sequential one-span walk

#[derive(Clone, Debug)]
pub struct ParallelFusedRow {
    pub pipeline: PipelineKind,
    /// Pool width the arms dispatch on.
    pub threads: usize,
    /// Context length resident in the KV state when decoding starts.
    pub ctx: usize,
    /// Decoded tok/s through the sequential fused walk (`decode_split(1)`:
    /// one span, one worker per sequence).
    pub seq_tok_s: f64,
    /// Decoded tok/s with the page list split across the pool
    /// (`decode_split(0)`: auto span width, exact integer merge).
    pub par_tok_s: f64,
    /// Whether the two arms' final decode outputs were byte-identical —
    /// the split-invariance contract riding along as a witness (the hard
    /// assertions live in `tests/fused_decode.rs`).
    pub identical: bool,
}

impl ParallelFusedRow {
    pub fn speedup(&self) -> f64 {
        if self.seq_tok_s > 0.0 {
            self.par_tok_s / self.seq_tok_s
        } else {
            0.0
        }
    }
}

/// Sequential-fused vs page-parallel decode throughput over a threads ×
/// context grid — the batch-of-1 deep-context scaling the span split
/// exists for. Both arms run the fused walk; only the split policy
/// differs, so any tok/s gap is pure dispatch. Uses the process page
/// geometry (`INTATTN_KV_PAGE`, default 64 rows), so the page count — the
/// parallelism grain — grows with `ctx`.
pub fn parallel_fused_sweep(
    ctx_lens: &[usize],
    d: usize,
    gen_tokens: usize,
    thread_list: &[usize],
) -> Vec<ParallelFusedRow> {
    let mut rng = Pcg64::seed_from_u64(53);
    let mut rows = Vec::new();
    for &threads in thread_list {
        for &ctx in ctx_lens {
            let kind = PipelineKind::IntAttention;
            let cfg = AttentionConfig::new(ctx + gen_tokens, d)
                .with_threads(threads)
                .with_fused_decode(true);
            let mut seq = build_pipeline(kind, cfg.with_decode_split(1));
            let mut par = build_pipeline(kind, cfg.with_decode_split(0));
            let mut st_s = seq.begin_state();
            let (q, k, v) = random_qkv(&mut rng, ctx, d, 1.0);
            let _ = seq.prefill(&mut st_s, &q, &k, &v);
            let mut st_p = st_s.clone();
            let steps: Vec<_> = (0..gen_tokens).map(|_| random_qkv(&mut rng, 1, d, 1.0)).collect();

            let mut last_s = MatF32::zeros(0, 0);
            let t0 = std::time::Instant::now();
            for (q1, k1, v1) in &steps {
                last_s = seq.decode_step(&mut st_s, q1, k1, v1);
                crate::util::bench::black_box(&last_s);
            }
            let dt_s = t0.elapsed().as_secs_f64().max(1e-12);

            let mut last_p = MatF32::zeros(0, 0);
            let t0 = std::time::Instant::now();
            for (q1, k1, v1) in &steps {
                last_p = par.decode_step(&mut st_p, q1, k1, v1);
                crate::util::bench::black_box(&last_p);
            }
            let dt_p = t0.elapsed().as_secs_f64().max(1e-12);

            rows.push(ParallelFusedRow {
                pipeline: kind,
                threads,
                ctx,
                seq_tok_s: gen_tokens as f64 / dt_s,
                par_tok_s: gen_tokens as f64 / dt_p,
                identical: last_s.as_slice() == last_p.as_slice(),
            });
        }
    }
    rows
}

pub fn render_parallel_fused(rows: &[ParallelFusedRow]) -> Table {
    let mut t = Table::new(
        "Page-parallel fused decode — span-split walk vs sequential walk (tok/s)",
        &["pipeline", "threads", "ctx", "seq tok/s", "parallel tok/s", "speedup", "identical"],
    );
    for r in rows {
        t.row(vec![
            r.pipeline.name().into(),
            r.threads.to_string(),
            r.ctx.to_string(),
            format!("{:.0}", r.seq_tok_s),
            format!("{:.0}", r.par_tok_s),
            format!("{:.2}x", r.speedup()),
            if r.identical { "yes".into() } else { "NO".into() },
        ]);
    }
    t
}

/// JSON payload for the page-parallel decode bench (label/value rows).
pub fn parallel_fused_rows_json(rows: &[ParallelFusedRow]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for r in rows {
        let key = format!("{}@t{}ctx{}", r.pipeline.name(), r.threads, r.ctx);
        out.push((format!("{key}:seq_tok_s"), r.seq_tok_s));
        out.push((format!("{key}:par_tok_s"), r.par_tok_s));
        out.push((format!("{key}:speedup"), r.speedup()));
        out.push((format!("{key}:identical"), if r.identical { 1.0 } else { 0.0 }));
    }
    out
}

// ---------------------------------------------------------------------------
// Online-tiled prefill — flash-style loop vs materialized m×L score block

#[derive(Clone, Debug)]
pub struct TiledPrefillRow {
    pub pipeline: PipelineKind,
    /// Rows prefilled in the measured block (the whole context, one call).
    pub ctx: usize,
    /// Wall seconds per arm.
    pub tiled_s: f64,
    pub materialized_s: f64,
    /// Peak heap bytes observed during each arm's prefill, when the caller
    /// can measure them — the `decode_throughput` bench binary installs a
    /// peak-tracking allocator and probes these; callers without one pass a
    /// probe returning 0 and the render shows `-`.
    pub tiled_peak: u64,
    pub materialized_peak: u64,
}

/// Tiled vs materialized prefill, one full-context block per arm over
/// identical inputs. `peak_probe` runs the supplied closure and reports
/// the peak heap bytes during it (0 = unmeasured) — allocator hooks are
/// per-binary, so the probe is injected rather than owned here. Wall time
/// is measured around the same call.
pub fn tiled_prefill_sweep(
    ctx_lens: &[usize],
    d: usize,
    threads: usize,
    peak_probe: &mut dyn FnMut(&mut dyn FnMut()) -> u64,
) -> Vec<TiledPrefillRow> {
    let mut rng = Pcg64::seed_from_u64(61);
    let mut rows = Vec::new();
    for &ctx in ctx_lens {
        for kind in [PipelineKind::IntAttention, PipelineKind::ExaqInt3] {
            let cfg = AttentionConfig::new(ctx, d).with_threads(threads);
            let (q, k, v) = random_qkv(&mut rng, ctx, d, 1.0);
            // Index 0 = tiled, 1 = materialized.
            let mut wall = [0f64; 2];
            let mut peak = [0u64; 2];
            for (i, tiled) in [true, false].into_iter().enumerate() {
                let mut pipe = build_pipeline(kind, cfg.with_tiled_prefill(tiled));
                let mut st = pipe.begin_state();
                let t0 = std::time::Instant::now();
                peak[i] = peak_probe(&mut || {
                    let o = pipe.prefill(&mut st, &q, &k, &v);
                    crate::util::bench::black_box(&o);
                });
                wall[i] = t0.elapsed().as_secs_f64().max(1e-12);
            }
            rows.push(TiledPrefillRow {
                pipeline: kind,
                ctx,
                tiled_s: wall[0],
                materialized_s: wall[1],
                tiled_peak: peak[0],
                materialized_peak: peak[1],
            });
        }
    }
    rows
}

fn fmt_peak(bytes: u64) -> String {
    if bytes == 0 {
        "-".into()
    } else {
        format!("{:.2} MiB", bytes as f64 / (1024.0 * 1024.0))
    }
}

pub fn render_tiled_prefill(rows: &[TiledPrefillRow]) -> Table {
    let mut t = Table::new(
        "Online-tiled prefill — flash-style loop vs materialized m×L block",
        &["pipeline", "ctx", "mat wall", "tiled wall", "speedup", "mat peak", "tiled peak"],
    );
    for r in rows {
        let speedup =
            if r.tiled_s > 0.0 { r.materialized_s / r.tiled_s } else { 0.0 };
        t.row(vec![
            r.pipeline.name().into(),
            r.ctx.to_string(),
            format!("{:.1} ms", r.materialized_s * 1e3),
            format!("{:.1} ms", r.tiled_s * 1e3),
            format!("{speedup:.2}x"),
            fmt_peak(r.materialized_peak),
            fmt_peak(r.tiled_peak),
        ]);
    }
    t
}

/// JSON payload for the tiled-prefill bench (label/value rows).
pub fn tiled_prefill_rows_json(rows: &[TiledPrefillRow]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for r in rows {
        let key = format!("prefill:{}@ctx{}", r.pipeline.name(), r.ctx);
        out.push((format!("{key}:materialized_ms"), r.materialized_s * 1e3));
        out.push((format!("{key}:tiled_ms"), r.tiled_s * 1e3));
        if r.materialized_peak > 0 {
            out.push((format!("{key}:materialized_peak_b"), r.materialized_peak as f64));
        }
        if r.tiled_peak > 0 {
            out.push((format!("{key}:tiled_peak_b"), r.tiled_peak as f64));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Shared-system-prompt admission — prefix sharing vs unshared

#[derive(Clone, Debug)]
pub struct PrefixShareRow {
    pub pipeline: PipelineKind,
    /// Requests admitting the same system prompt.
    pub requests: usize,
    /// Shared prefix length (rows; page-aligned).
    pub prefix_rows: usize,
    /// Per-request unshared suffix length (rows).
    pub suffix_rows: usize,
    /// Prefix quantize-and-store passes: `requests` unshared, 1 shared.
    pub unshared_quant_passes: usize,
    pub shared_quant_passes: usize,
    /// KV pages handed out by the pool (allocated + recycled) while
    /// building all requests' resident states, per arm. The shared arm pays
    /// one prefix page set plus per-request suffix pages.
    pub unshared_pages: u64,
    pub shared_pages: u64,
    /// Wall time to bring all requests' states up (prefix + suffix), per
    /// arm.
    pub unshared_prefill_s: f64,
    pub shared_prefill_s: f64,
}

impl PrefixShareRow {
    pub fn speedup(&self) -> f64 {
        if self.shared_prefill_s > 0.0 {
            self.unshared_prefill_s / self.shared_prefill_s
        } else {
            0.0
        }
    }
}

/// Admission cost of N same-prompt requests, unshared vs prefix-shared, at
/// the single-head pipeline level: the unshared arm quantizes and stores
/// the prefix N times; the shared arm computes it once, snapshots it
/// ([`KvState::share_prefix`]) and every further request adopts the pages
/// by copy-on-write reference, paying only its suffix. Pool handouts are
/// exact here (the bench binary is single-threaded), so `*_pages` is the
/// real page traffic of each arm; all states stay live until the arm is
/// measured, modeling concurrent residency.
pub fn prefix_share_sweep(
    request_counts: &[usize],
    prefix_target: usize,
    suffix_rows: usize,
    d: usize,
) -> Vec<PrefixShareRow> {
    let mut rng = Pcg64::seed_from_u64(37);
    // Whole pages only: adoption shares page runs.
    let prefix_rows = prefix_target.div_ceil(kv_page_rows()).max(1) * kv_page_rows();
    let mut rows = Vec::new();
    for &n in request_counts {
        for kind in PipelineKind::headline() {
            let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d));
            let (pq, pk, pv) = random_qkv(&mut rng, prefix_rows, d, 1.0);
            let suffixes: Vec<(MatF32, MatF32, MatF32)> =
                (0..n).map(|_| random_qkv(&mut rng, suffix_rows, d, 1.0)).collect();

            // Unshared: every request computes prefix + suffix itself.
            let before = page_pool_stats();
            let t0 = std::time::Instant::now();
            let unshared: Vec<KvState> = suffixes
                .iter()
                .map(|(sq, sk, sv)| {
                    let mut st = pipe.begin_state();
                    crate::util::bench::black_box(pipe.prefill(&mut st, &pq, &pk, &pv));
                    crate::util::bench::black_box(pipe.prefill(&mut st, sq, sk, sv));
                    st
                })
                .collect();
            let unshared_prefill_s = t0.elapsed().as_secs_f64();
            let after = page_pool_stats();
            let unshared_pages =
                after.allocated + after.recycled - before.allocated - before.recycled;
            drop(unshared);

            // Shared: one prefix pass, N adoptions + suffixes.
            let before = page_pool_stats();
            let t0 = std::time::Instant::now();
            let mut donor = pipe.begin_state();
            crate::util::bench::black_box(pipe.prefill(&mut donor, &pq, &pk, &pv));
            let snapshot = donor.share_prefix(prefix_rows);
            let shared: Vec<KvState> = suffixes
                .iter()
                .map(|(sq, sk, sv)| {
                    let mut st = snapshot.share_prefix(prefix_rows);
                    crate::util::bench::black_box(pipe.prefill(&mut st, sq, sk, sv));
                    st
                })
                .collect();
            let shared_prefill_s = t0.elapsed().as_secs_f64();
            let after = page_pool_stats();
            let shared_pages =
                after.allocated + after.recycled - before.allocated - before.recycled;
            drop(shared);
            drop(snapshot);
            drop(donor);

            rows.push(PrefixShareRow {
                pipeline: kind,
                requests: n,
                prefix_rows,
                suffix_rows,
                unshared_quant_passes: n,
                shared_quant_passes: 1,
                unshared_pages,
                shared_pages,
                unshared_prefill_s,
                shared_prefill_s,
            });
        }
    }
    rows
}

pub fn render_prefix_share(rows: &[PrefixShareRow]) -> Table {
    let mut t = Table::new(
        "Shared-system-prompt admission — copy-on-write prefix sharing vs unshared (single head)",
        &[
            "pipeline",
            "requests",
            "prefix",
            "suffix",
            "prefix quant passes",
            "kv pages",
            "prefill ms",
            "speedup",
        ],
    );
    for r in rows {
        t.row(vec![
            r.pipeline.name().into(),
            r.requests.to_string(),
            r.prefix_rows.to_string(),
            r.suffix_rows.to_string(),
            format!("{}→{}", r.unshared_quant_passes, r.shared_quant_passes),
            format!("{}→{}", r.unshared_pages, r.shared_pages),
            format!("{:.2}→{:.2}", r.unshared_prefill_s * 1e3, r.shared_prefill_s * 1e3),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    t
}

/// JSON payload for the prefix-share bench (label/value rows).
pub fn prefix_share_rows_json(rows: &[PrefixShareRow]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for r in rows {
        let key = format!("{}@n{}p{}", r.pipeline.name(), r.requests, r.prefix_rows);
        out.push((format!("{key}:unshared_pages"), r.unshared_pages as f64));
        out.push((format!("{key}:shared_pages"), r.shared_pages as f64));
        out.push((format!("{key}:unshared_prefill_s"), r.unshared_prefill_s));
        out.push((format!("{key}:shared_prefill_s"), r.shared_prefill_s));
        out.push((format!("{key}:speedup"), r.speedup()));
        out.push((
            format!("{key}:quant_passes_saved"),
            (r.unshared_quant_passes - r.shared_quant_passes) as f64,
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 8 — energy model

#[derive(Clone, Debug)]
pub struct EnergyRow {
    pub pipeline: PipelineKind,
    pub seq_len: usize,
    pub energy_uj: f64,
    /// Normalized to FP16 at the same L.
    pub vs_fp16: f64,
}

pub fn fig8_energy(seq_lens: &[usize], d: usize) -> Vec<EnergyRow> {
    let mut rng = Pcg64::seed_from_u64(8);
    let model = EnergyModel::default();
    let mut rows: Vec<EnergyRow> = Vec::new();
    for &l in seq_lens {
        let (q, k, v) = random_qkv(&mut rng, l, d, 1.0);
        let mut raw: Vec<(PipelineKind, f64)> = Vec::new();
        for kind in PipelineKind::headline() {
            let cfg = AttentionConfig::new(l, d);
            let mut pipe = build_pipeline(kind, cfg);
            let _ = pipe.forward(&q, &k, &v);
            raw.push((kind, model.energy_uj(pipe.op_counts())));
        }
        let fp16 = raw
            .iter()
            .find(|(k, _)| *k == PipelineKind::Fp16)
            .map(|(_, e)| *e)
            .unwrap();
        for (kind, e) in raw {
            rows.push(EnergyRow { pipeline: kind, seq_len: l, energy_uj: e, vs_fp16: e / fp16 });
        }
    }
    rows
}

pub fn render_fig8(rows: &[EnergyRow]) -> Table {
    let mut t = Table::new(
        "Figure 8 — modeled energy per attention iteration (normalized to FP16)",
        &["pipeline", "L", "energy µJ", "vs FP16"],
    );
    for r in rows {
        t.row(vec![
            r.pipeline.name().into(),
            r.seq_len.to_string(),
            format!("{:.1}", r.energy_uj),
            format!("{:.2}", r.vs_fp16),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Figure 9 — (b, c) sensitivity sweep

#[derive(Clone, Debug)]
pub struct SweepCell {
    pub b: u32,
    pub c: f32,
    /// Mean cosine similarity of IndexSoftmax probabilities vs exact softmax.
    pub cos_sim: f64,
}

pub fn fig9_sweep(bs: &[u32], cs: &[f32], l: usize, d: usize) -> Vec<SweepCell> {
    let mut rng = Pcg64::seed_from_u64(9);
    // A representative batch of logit matrices (clustered = realistic).
    let (q, k, _v) = clustered_qkv(&mut rng, l, d, 8, 3.0);
    let qq = quantize_i8(&q);
    let kq = quantize_i8(&k);
    let mut logits = MatI32::zeros(l, l);
    crate::gemm::gemm_i8(&qq.data, &kq.data, &mut logits);
    let alpha = qq.scale * kq.scale / (d as f32).sqrt();
    let p_ref = exact_probs(&logits, alpha, Mask::None);
    let mut cells = Vec::new();
    for &b in bs {
        for &c in cs {
            let isx = IndexSoftmax::new(IndexSoftmaxConfig { b, c });
            let p = isx.forward_probs_f32(&logits, alpha, Mask::None);
            cells.push(SweepCell {
                b,
                c,
                cos_sim: crate::util::stats::cosine_similarity(p_ref.as_slice(), p.as_slice()),
            });
        }
    }
    cells
}

pub fn render_fig9(cells: &[SweepCell]) -> Table {
    let mut t = Table::new(
        "Figure 9 — IndexSoftmax (b, c) sensitivity: cosine sim vs exact softmax",
        &["b", "c", "cos sim"],
    );
    for cell in cells {
        t.row(vec![
            cell.b.to_string(),
            format!("{:.1}", cell.c),
            format!("{:.5}", cell.cos_sim),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 8 — latency table (both "platforms")

pub fn render_tab8(rows_rk: &[SpeedRow], rows_m2: &[SpeedRow]) -> Table {
    let mut t = Table::new(
        "Table 8 — end-to-end attention latency (ms); cfg-A ≈ RK3588S2, cfg-B ≈ Apple M2",
        &["pipeline", "L", "cfg-A ms", "cfg-B ms"],
    );
    for r in rows_rk {
        let m2 = rows_m2
            .iter()
            .find(|x| x.seq_len == r.seq_len && x.pipeline == r.pipeline)
            .map(|x| x.mean_ms)
            .unwrap_or(f64::NAN);
        t.row(vec![
            r.pipeline.name().into(),
            r.seq_len.to_string(),
            format!("{:.2}", r.mean_ms),
            format!("{:.2}", m2),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 9 — P matrix quantization format

pub fn tab9_p_quant(l: usize, d: usize, trials: usize) -> (ProbFidelity, ProbFidelity) {
    let mut rng = Pcg64::seed_from_u64(19);
    let mut agg_i8 = ProbFidelity::default();
    let mut agg_u8 = ProbFidelity::default();
    for _ in 0..trials {
        let (q, k, _v) = clustered_qkv(&mut rng, l, d, 8, 3.0);
        let qq = quantize_i8(&q);
        let kq = quantize_i8(&k);
        let mut logits = MatI32::zeros(l, l);
        crate::gemm::gemm_i8(&qq.data, &kq.data, &mut logits);
        let alpha = qq.scale * kq.scale / (d as f32).sqrt();
        let p = exact_probs(&logits, alpha, Mask::None);
        let f_i8 = ProbFidelity::of(&p, &dequantize_p_i8(&quantize_p_i8(&p)));
        let f_u8 = ProbFidelity::of(&p, &dequantize_p_u8(&quantize_p_u8(&p)));
        agg_i8.cos_sim += f_i8.cos_sim / trials as f64;
        agg_i8.rel_l1 += f_i8.rel_l1 / trials as f64;
        agg_i8.rmse += f_i8.rmse / trials as f64;
        agg_u8.cos_sim += f_u8.cos_sim / trials as f64;
        agg_u8.rel_l1 += f_u8.rel_l1 / trials as f64;
        agg_u8.rmse += f_u8.rmse / trials as f64;
    }
    (agg_i8, agg_u8)
}

pub fn render_tab9(i8f: &ProbFidelity, u8f: &ProbFidelity) -> Table {
    let mut t = Table::new(
        "Table 9 — P quantization format vs FP32 probabilities",
        &["format", "CosSim", "Relative L1", "RMSE"],
    );
    for (name, f) in [("INT8 (×127)", i8f), ("UINT8 (×255)", u8f)] {
        t.row(vec![
            name.into(),
            format!("{:.6}", f.cos_sim),
            format!("{:.6}", f.rel_l1),
            format!("{:.7}", f.rmse),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 10 — stability stress

#[derive(Clone, Debug)]
pub struct StabilityRow {
    pub method: String,
    pub max_token_loss: f64,
    pub loss_std: f64,
    pub nan_inf_events: usize,
}

pub fn tab10_stability(weights: &Weights, ctx: usize, n_seqs: usize) -> Vec<StabilityRow> {
    let artifacts = crate::runtime::default_artifacts_dir();
    let seqs = eval_sequences(&artifacts, n_seqs, ctx.min(weights.cfg.max_seq), weights.cfg.vocab);
    let mut rows = Vec::new();
    for kind in [PipelineKind::Fp16, PipelineKind::IntAttention] {
        let mut lm = TinyLm::new(weights.clone(), kind);
        let mut losses: Vec<f64> = Vec::new();
        let mut bad = 0usize;
        for s in &seqs {
            for l in lm.token_losses(s) {
                if l.is_finite() {
                    losses.push(l);
                } else {
                    bad += 1;
                }
            }
        }
        rows.push(StabilityRow {
            method: if kind == PipelineKind::IntAttention {
                "IndexSoftmax".into()
            } else {
                "FP16".into()
            },
            max_token_loss: crate::util::stats::max(&losses),
            loss_std: crate::util::stats::std_dev(&losses),
            nan_inf_events: bad,
        });
    }
    rows
}

pub fn render_tab10(rows: &[StabilityRow]) -> Table {
    let mut t = Table::new(
        "Table 10 — token-loss stress test (long context)",
        &["method", "max token loss", "loss std dev", "NaN/Inf events"],
    );
    for r in rows {
        t.row(vec![
            r.method.clone(),
            format!("{:.2}", r.max_token_loss),
            format!("{:.4}", r.loss_std),
            r.nan_inf_events.to_string(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Tables 1/2/3/5 — LM & encoder fidelity / ablations

/// Table 1 substitution: end-to-end LM fidelity per pipeline.
pub fn tab1_lm_fidelity(weights: &Weights, n_seqs: usize, seq_len: usize) -> Vec<LmFidelity> {
    let artifacts = crate::runtime::default_artifacts_dir();
    let seqs = eval_sequences(&artifacts, n_seqs, seq_len.min(weights.cfg.max_seq), weights.cfg.vocab);
    [
        PipelineKind::Fp16,
        PipelineKind::QuantOnly,
        PipelineKind::IntAttention,
    ]
    .iter()
    .map(|&k| eval_lm_fidelity(weights, k, &seqs))
    .collect()
}

/// Table 5 substitution: softmax-only ablation (EXAQ INT2/INT3 vs
/// IndexSoftmax, all inside the same integer pipeline).
pub fn tab5_softmax_ablation(weights: &Weights, n_seqs: usize, seq_len: usize) -> Vec<LmFidelity> {
    let artifacts = crate::runtime::default_artifacts_dir();
    let seqs = eval_sequences(&artifacts, n_seqs, seq_len.min(weights.cfg.max_seq), weights.cfg.vocab);
    [
        PipelineKind::Fp16,
        PipelineKind::ExaqInt2,
        PipelineKind::ExaqInt3,
        PipelineKind::IntAttention,
    ]
    .iter()
    .map(|&k| eval_lm_fidelity(weights, k, &seqs))
    .collect()
}

pub fn render_lm_fidelity(rows: &[LmFidelity], title: &str) -> Table {
    let mut t = Table::new(title, &["pipeline", "perplexity ↓", "top-1 agree w/ FP32 ↑", "loss MAD ↓"]);
    for r in rows {
        t.row(vec![
            r.pipeline.clone(),
            format!("{:.3}", r.perplexity),
            format!("{:.3}", r.top1_agreement),
            format!("{:.4}", r.loss_mad),
        ]);
    }
    t
}

/// Table 2 substitution: encoder-mode (bidirectional) operator fidelity on a
/// vision-like clustered workload — output cosine vs FP32 per pipeline.
#[derive(Clone, Debug)]
pub struct EncoderRow {
    pub pipeline: PipelineKind,
    pub out_cos: f64,
    pub out_rmse: f64,
}

pub fn tab2_encoder_fidelity(l: usize, d: usize, trials: usize) -> Vec<EncoderRow> {
    let mut rng = Pcg64::seed_from_u64(22);
    let kinds = [
        PipelineKind::Fp16,
        PipelineKind::QuantOnly,
        PipelineKind::IntAttention,
        PipelineKind::ExaqInt2,
        PipelineKind::ExaqInt3,
    ];
    let mut acc: Vec<(f64, f64)> = vec![(0.0, 0.0); kinds.len()];
    for _ in 0..trials {
        let (q, k, v) = clustered_qkv(&mut rng, l, d, 6, 2.5);
        let cfg = AttentionConfig::new(l, d);
        let want = crate::attention::fp32::reference_attention(&q, &k, &v, Mask::None);
        for (i, &kind) in kinds.iter().enumerate() {
            let got = build_pipeline(kind, cfg).forward(&q, &k, &v);
            acc[i].0 +=
                crate::util::stats::cosine_similarity(want.as_slice(), got.as_slice());
            acc[i].1 += crate::util::stats::rmse(want.as_slice(), got.as_slice());
        }
    }
    kinds
        .iter()
        .zip(acc)
        .map(|(&k, (c, r))| EncoderRow {
            pipeline: k,
            out_cos: c / trials as f64,
            out_rmse: r / trials as f64,
        })
        .collect()
}

pub fn render_tab2(rows: &[EncoderRow]) -> Table {
    let mut t = Table::new(
        "Table 2 — encoder-mode (vision-like) output fidelity vs FP32",
        &["pipeline", "output CosSim ↑", "output RMSE ↓"],
    );
    for r in rows {
        t.row(vec![
            r.pipeline.name().into(),
            format!("{:.5}", r.out_cos),
            format!("{:.5}", r.out_rmse),
        ]);
    }
    t
}

/// Table 3/7 substitution: long-context robustness — perplexity at contexts
/// beyond the training length.
pub fn tab3_long_context(weights: &Weights, ctxs: &[usize], n_seqs: usize) -> Vec<(usize, Vec<LmFidelity>)> {
    let artifacts = crate::runtime::default_artifacts_dir();
    ctxs.iter()
        .map(|&ctx| {
            let seqs = eval_sequences(&artifacts, n_seqs, ctx.min(weights.cfg.max_seq), weights.cfg.vocab);
            let rows = [
                PipelineKind::Fp16,
                PipelineKind::QuantOnly,
                PipelineKind::IntAttention,
            ]
            .iter()
            .map(|&k| eval_lm_fidelity(weights, k, &seqs))
            .collect();
            (ctx, rows)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Shared: load the trained model or fall back to a random one

/// Load the build-time-trained weights if `make artifacts` has run, else a
/// deterministic random model (tests and quick demos).
pub fn load_or_random_weights() -> Weights {
    let dir = crate::runtime::default_artifacts_dir();
    match Weights::load(&dir) {
        Ok(w) => w,
        Err(_) => {
            crate::log_warn!(
                "no trained weights in {} — using random init (run `make artifacts`)",
                dir.display()
            );
            Weights::random(crate::model::config::ModelConfig::tiny(), 0xDEFA)
        }
    }
}

/// Counts helper for ablations: total detour conversions per pipeline.
pub fn detour_conversions(kind: PipelineKind, l: usize, d: usize) -> u64 {
    let mut rng = Pcg64::seed_from_u64(77);
    let (q, k, v) = random_qkv(&mut rng, l, d, 1.0);
    let cfg = AttentionConfig::new(l, d);
    let mut pipe = build_pipeline(kind, cfg);
    let _ = pipe.forward(&q, &k, &v);
    let c: &OpCounts = pipe.op_counts();
    c.dtype_conv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn fig2_shape_int8_detour_dominates() {
        // The paper's Figure 2 claim: in Quant-Only the softmax path share is
        // far higher than in FP32, and IntAttention collapses it.
        let rows = fig2_breakdown(&[256], 64, 1);
        let share = |k: PipelineKind| {
            rows.iter().find(|r| r.pipeline == k).unwrap().softmax_path_share
        };
        assert!(share(PipelineKind::QuantOnly) > share(PipelineKind::Fp32));
        assert!(share(PipelineKind::QuantOnly) > 0.3, "detour must dominate: {}", share(PipelineKind::QuantOnly));
        assert!(share(PipelineKind::IntAttention) < share(PipelineKind::QuantOnly));
    }

    #[test]
    fn fig4_mass_concentrates() {
        let rows = fig4_sparsity(128, 64);
        // Mass is monotone in fraction and the top 10% holds most of it.
        for w in rows.windows(2) {
            assert!(w[0].mass <= w[1].mass + 1e-9);
        }
        let top10 = rows.iter().find(|r| (r.top_frac - 0.10).abs() < 1e-9).unwrap();
        assert!(top10.mass > 0.5, "top-10% mass {}", top10.mass);
    }

    #[test]
    fn fig5_ours_beats_exaq_under_budget() {
        let rows = fig5_lut_resolution();
        let ours = &rows[0];
        let int3 = &rows[1];
        assert_eq!(ours.bytes, int3.bytes, "same 32 B budget");
        assert_eq!(ours.entries, 4 * int3.entries, "4× resolution");
        assert!(ours.max_abs_err < int3.max_abs_err);
    }

    #[test]
    fn fig8_intattention_cheapest() {
        let rows = fig8_energy(&[256], 64);
        let e = |k: PipelineKind| rows.iter().find(|r| r.pipeline == k).unwrap().vs_fp16;
        assert!(e(PipelineKind::IntAttention) < e(PipelineKind::QuantOnly));
        assert!(e(PipelineKind::QuantOnly) < e(PipelineKind::Fp16));
        assert!(e(PipelineKind::Fp32) > 1.0);
        // Paper: IntAttention ≈ 0.39× FP16; our model must land well below 1.
        assert!(e(PipelineKind::IntAttention) < 0.6, "got {}", e(PipelineKind::IntAttention));
    }

    #[test]
    fn fig9_plateau_above_b4() {
        let cells = fig9_sweep(&[2, 3, 4, 5, 6], &[4.4, 5.5, 6.6, 7.7], 96, 32);
        let get = |b: u32, c: f32| {
            cells
                .iter()
                .find(|x| x.b == b && (x.c - c).abs() < 1e-6)
                .unwrap()
                .cos_sim
        };
        // (5, 6.6) on the plateau; b=2 clearly worse.
        assert!(get(5, 6.6) > 0.995, "plateau point {}", get(5, 6.6));
        assert!(get(2, 6.6) < get(5, 6.6));
        // b≥4 stable: going 4→6 changes little.
        assert!((get(4, 6.6) - get(6, 6.6)).abs() < 0.01);
    }

    #[test]
    fn decode_sweep_shapes_and_kv_footprint() {
        let rows = decode_sweep(&[32, 64], 32, 4, 1);
        assert_eq!(rows.len(), 2 * PipelineKind::headline().len());
        let get = |k: PipelineKind, c: usize| {
            rows.iter().find(|r| r.pipeline == k && r.ctx == c).unwrap()
        };
        assert!(rows.iter().all(|r| r.tok_s > 0.0));
        // INT8-resident states are ~4× smaller than FP32's (same page
        // count, quarter the page bytes).
        let ia = get(PipelineKind::IntAttention, 64);
        let fp = get(PipelineKind::Fp32, 64);
        assert!(ia.kv_bytes * 3 < fp.kv_bytes, "{} vs {}", ia.kv_bytes, fp.kv_bytes);
        // Exact allocated capacity: (K+V) × ⌈(ctx+gen)/page⌉ pages of
        // page × d elements at the native width (+ INT8 bookkeeping).
        let pr = crate::attention::kv_page_rows();
        let pages_per_side = (64usize + 4).div_ceil(pr);
        assert_eq!(ia.kv_pages, 2 * pages_per_side);
        assert_eq!(fp.kv_pages, 2 * pages_per_side);
        assert_eq!(ia.kv_bytes, 2 * pages_per_side * pr * 32 + 56);
        assert_eq!(fp.kv_bytes, 2 * pages_per_side * pr * 32 * 4);
        // The contiguous layout would have paid growth copies; paging pays
        // none (wider elements ⇒ more copied bytes).
        assert!(ia.append_copy_bytes_contiguous > 0);
        assert!(fp.append_copy_bytes_contiguous > ia.append_copy_bytes_contiguous);
        // JSON payload covers every row's five metrics.
        assert_eq!(decode_rows_json(&rows).len(), 5 * rows.len());
    }

    #[test]
    fn contiguous_realloc_copy_model() {
        // Appending 4 rows of 2 elems one at a time with doubling growth:
        // caps 2→4→8; copies of 2 then 4 resident elems = 6 elems.
        assert_eq!(contiguous_realloc_copy_bytes(&[1, 1, 1, 1], 2, 1), 6);
        // Element width scales linearly; a single block append copies
        // nothing (one allocation, no resident prefix).
        assert_eq!(contiguous_realloc_copy_bytes(&[1, 1, 1, 1], 2, 4), 24);
        assert_eq!(contiguous_realloc_copy_bytes(&[64], 8, 1), 0);
        // Long decode tails dominate: copies grow with the resident length.
        let short = contiguous_realloc_copy_bytes(&[16, 1, 1], 8, 1);
        let mut long_schedule = vec![16usize];
        long_schedule.resize(1 + 256, 1);
        let long = contiguous_realloc_copy_bytes(&long_schedule, 8, 1);
        assert!(long > short);
    }

    #[test]
    fn batched_decode_sweep_shapes() {
        let rows = batched_decode_sweep(24, &[1, 3], 16, 3, 2);
        assert_eq!(rows.len(), 2 * PipelineKind::headline().len());
        assert!(rows.iter().all(|r| r.seq_tok_s > 0.0 && r.batch_tok_s > 0.0));
        assert!(rows.iter().all(|r| r.speedup() > 0.0));
        assert_eq!(batched_decode_rows_json(&rows).len(), 3 * rows.len());
    }

    #[test]
    fn tab9_uint8_wins_all_metrics() {
        let (i8f, u8f) = tab9_p_quant(96, 32, 2);
        assert!(u8f.cos_sim > i8f.cos_sim);
        assert!(u8f.rel_l1 < i8f.rel_l1);
        assert!(u8f.rmse < i8f.rmse);
    }

    #[test]
    fn tab2_ordering_holds() {
        let rows = tab2_encoder_fidelity(64, 32, 2);
        let cos = |k: PipelineKind| rows.iter().find(|r| r.pipeline == k).unwrap().out_cos;
        assert!(cos(PipelineKind::IntAttention) > cos(PipelineKind::ExaqInt2));
        assert!(cos(PipelineKind::Fp16) > 0.999);
        assert!(cos(PipelineKind::IntAttention) > 0.99);
    }

    #[test]
    fn tab10_no_nan_inf() {
        let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 64, mlp_mult: 2 };
        let w = Weights::random(cfg, 5);
        let rows = tab10_stability(&w, 48, 2);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.nan_inf_events, 0, "{}: NaN/Inf", r.method);
            assert!(r.max_token_loss.is_finite());
        }
    }

    #[test]
    fn detour_conversion_counts() {
        // IntAttention's conversions are O(L·d) (quantize inputs + output);
        // Quant-Only adds the O(L²) dequant/requant detour.
        let qo = detour_conversions(PipelineKind::QuantOnly, 128, 32);
        let ia = detour_conversions(PipelineKind::IntAttention, 128, 32);
        assert!(qo > ia + 2 * 128 * 128_u64 - 1000, "qo={qo} ia={ia}");
    }
}
