//! Report writer: persists rendered experiment tables under `reports/` and
//! appends machine-readable JSON, so EXPERIMENTS.md entries are regenerable.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Where reports go: `$INTATTN_REPORTS` or `reports/`.
pub fn reports_dir() -> PathBuf {
    if let Ok(p) = std::env::var("INTATTN_REPORTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("reports")
}

/// Write a rendered table (and optional JSON payload) under `reports/`.
pub fn write_report(name: &str, rendered: &str, payload: Option<Json>) -> std::io::Result<PathBuf> {
    let dir = reports_dir();
    std::fs::create_dir_all(&dir)?;
    let txt_path = dir.join(format!("{name}.txt"));
    std::fs::write(&txt_path, rendered)?;
    if let Some(j) = payload {
        std::fs::write(dir.join(format!("{name}.json")), j.to_string())?;
    }
    Ok(txt_path)
}

/// Read back a previously written JSON report (used by meta-analyses/tests).
pub fn read_report_json(name: &str) -> Option<Json> {
    let p = reports_dir().join(format!("{name}.json"));
    let text = std::fs::read_to_string(p).ok()?;
    Json::parse(&text).ok()
}

/// Helper: rows of `(label, value)` pairs to a JSON object array.
pub fn kv_rows_json(rows: &[(String, f64)]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|(k, v)| Json::obj(vec![("label", Json::str(k)), ("value", Json::num(*v))]))
            .collect(),
    )
}

/// Write into a custom directory (tests).
pub fn write_report_to(dir: &Path, name: &str, rendered: &str) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let p = dir.join(format!("{name}.txt"));
    std::fs::write(&p, rendered)?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_and_read_custom_dir() {
        let dir = std::env::temp_dir().join("intattn_reports_test");
        let p = write_report_to(&dir, "demo", "hello table").unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap(), "hello table");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kv_rows_json_shape() {
        let j = kv_rows_json(&[("a".into(), 1.0), ("b".into(), 2.5)]);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].req_f64("value").unwrap(), 2.5);
    }
}
