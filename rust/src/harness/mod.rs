//! Experiment harness: workload generators and the drivers that regenerate
//! every table and figure of the paper's evaluation section (DESIGN.md §5
//! maps IDs to drivers; the `rust/benches/*` binaries are thin wrappers over
//! these functions so results are reproducible from both `cargo bench` and
//! the `intattn` CLI).

pub mod workload;
pub mod experiments;
pub mod fidelity;
pub mod report;
