//! # IntAttention
//!
//! A from-scratch reproduction of *IntAttention: A Fully Integer Attention
//! Pipeline for Efficient Edge Inference* (MLSys 2026) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — substrates this image's offline crate cache does not provide
//!   (PRNG, JSON, CLI parsing, thread pool, stats, software f16, a tiny
//!   property-testing driver, a criterion-style bench harness).
//! * [`tensor`] — row-major matrices over `f32`/`i8`/`u8`/`i32`.
//! * [`quant`] — per-tensor / per-group symmetric quantization (paper eq. 2–3, 16).
//! * [`gemm`] — blocked GEMM kernels: f32, f16-storage, `i8×i8→i32`, `u8×i8→i32`.
//! * [`softmax`] — the paper's core: LUT construction (eq. 10/13),
//!   **IndexSoftmax** (eq. 7–15), the EXAQ baseline, FP32/FP16 softmax.
//! * [`attention`] — the five pipelines the paper evaluates (FP32, FP16,
//!   Quant-Only, **IntAttention**, EXAQ) behind one trait, instrumented with
//!   per-stage timers and energy counters.
//! * [`energy`] — the analytic energy model standing in for the paper's
//!   wall-plug meter (Fig. 8 substitution, see DESIGN.md §2).
//! * [`model`] — a tiny byte-level transformer LM whose attention backend is
//!   pluggable; weights come from the build-time JAX training run.
//! * [`coordinator`] — the edge serving engine: request queue, admission
//!   control, dynamic batcher, prefill/decode scheduler, metrics.
//! * [`runtime`] — PJRT artifact loader/executor (the `xla` crate), proving
//!   L1/L2/L3 compose: JAX-lowered HLO runs under the Rust event loop.
//! * [`harness`] — experiment drivers that regenerate every table and figure
//!   in the paper's evaluation section (see DESIGN.md §5).
//!
//! ## Quickstart
//!
//! ```no_run
//! use intattention::attention::{AttentionConfig, PipelineKind, build_pipeline};
//! use intattention::harness::workload::random_qkv;
//! use intattention::util::prng::Pcg64;
//!
//! let cfg = AttentionConfig::new(512, 64);
//! let mut rng = Pcg64::seed_from_u64(0);
//! let (q, k, v) = random_qkv(&mut rng, cfg.seq_len, cfg.head_dim, 1.0);
//! let mut pipe = build_pipeline(PipelineKind::IntAttention, cfg);
//! let out = pipe.forward(&q, &k, &v);
//! assert_eq!(out.rows(), 512);
//! ```
//!
//! ## Unsafe code policy
//!
//! Every `unsafe` site in this crate carries a `// SAFETY:` comment and a
//! matching entry in `rust/audit/unsafe_inventory.toml`, enforced by the
//! in-repo [`audit`] pass (`cargo run --bin audit`). See
//! `docs/UNSAFE_POLICY.md` for the full policy.

// Unsafe operations inside `unsafe fn` bodies must still be wrapped in
// explicit `unsafe {}` blocks, each with its own SAFETY justification
// (audited by `intattn-audit`; see docs/UNSAFE_POLICY.md).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod util;
pub mod tensor;
pub mod quant;
pub mod gemm;
pub mod softmax;
pub mod attention;
pub mod energy;
pub mod model;
pub mod coordinator;
pub mod runtime;
pub mod harness;
pub mod audit;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI and the serving engine.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
