//! `benchdiff` — compare two bench report JSON files metric by metric.
//!
//! ```text
//! cargo run --bin benchdiff -- old.json new.json
//! cargo run --bin benchdiff -- --threshold 5 --fail-on-regression old.json new.json
//! ```
//!
//! Both inputs are bench reports as written by `harness::report` — either
//! the `[{"label": ..., "value": ...}, ...]` row form or any JSON tree
//! whose numeric leaves become dotted-path metrics. Output is one line per
//! metric with the old/new values and the relative delta; metrics whose
//! |Δ%| meets the threshold (default 10%) are flagged, and labels present
//! on only one side are reported as added/removed. With
//! `--fail-on-regression` the process exits 1 when any metric is flagged
//! (added/removed labels alone do not fail — wall-time metric sets grow
//! with new bench modes). CI's bench-smoke job runs this as an
//! informational step against the previous run's artifacts.

use std::collections::BTreeMap;
use std::process::ExitCode;

use intattention::util::json::Json;

/// Flatten a report into `label -> value`. The `kv_rows_json` row form
/// keeps its labels verbatim; anything else flattens numeric leaves into
/// `a.b[2].c` paths.
fn flatten(j: &Json, prefix: &str, out: &mut BTreeMap<String, f64>) {
    match j {
        Json::Num(n) => {
            out.insert(prefix.to_string(), *n);
        }
        Json::Arr(items) => {
            for (i, item) in items.iter().enumerate() {
                // A {label, value} row keeps its own label as the metric
                // name (prefixed when nested under a named section).
                if let (Some(label), Some(value)) =
                    (item.get("label").and_then(Json::as_str), item.get("value"))
                {
                    let key = if prefix.is_empty() {
                        label.to_string()
                    } else {
                        format!("{prefix}.{label}")
                    };
                    flatten(value, &key, out);
                } else {
                    flatten(item, &format!("{prefix}[{i}]"), out);
                }
            }
        }
        Json::Obj(map) => {
            for (k, v) in map {
                let key = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten(v, &key, out);
            }
        }
        _ => {}
    }
}

fn load(path: &str) -> Result<BTreeMap<String, f64>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let json = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let mut out = BTreeMap::new();
    flatten(&json, "", &mut out);
    Ok(out)
}

struct Args {
    old: String,
    new: String,
    threshold: f64,
    fail_on_regression: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut threshold = 10.0;
    let mut fail_on_regression = false;
    let mut paths = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--threshold" => {
                let v = it.next().ok_or("--threshold needs a value")?;
                threshold = v.parse::<f64>().map_err(|_| format!("bad threshold '{v}'"))?;
                if threshold.is_nan() || threshold < 0.0 {
                    return Err(format!("bad threshold '{v}'"));
                }
            }
            "--fail-on-regression" => fail_on_regression = true,
            _ if a.starts_with("--") => return Err(format!("unknown flag '{a}'")),
            _ => paths.push(a),
        }
    }
    match <[String; 2]>::try_from(paths) {
        Ok([old, new]) => Ok(Args { old, new, threshold, fail_on_regression }),
        Err(_) => Err("expected exactly two report files".into()),
    }
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("benchdiff: {e}");
            eprintln!(
                "usage: benchdiff [--threshold PCT] [--fail-on-regression] old.json new.json"
            );
            return ExitCode::from(2);
        }
    };
    let (old, new) = match (load(&args.old), load(&args.new)) {
        (Ok(o), Ok(n)) => (o, n),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("benchdiff: {e}");
            return ExitCode::from(2);
        }
    };

    let mut flagged = 0usize;
    let mut compared = 0usize;
    for (label, &ov) in &old {
        let Some(&nv) = new.get(label) else { continue };
        compared += 1;
        let pct = if ov != 0.0 {
            (nv - ov) / ov.abs() * 100.0
        } else if nv == 0.0 {
            0.0
        } else {
            f64::INFINITY
        };
        let flag = pct.abs() >= args.threshold;
        if flag {
            flagged += 1;
        }
        println!(
            "{} {label}: {ov} -> {nv} ({pct:+.2}%)",
            if flag { "FLAG" } else { "  ok" }
        );
    }
    for label in old.keys().filter(|l| !new.contains_key(*l)) {
        println!(" del {label}: only in {}", args.old);
    }
    for label in new.keys().filter(|l| !old.contains_key(*l)) {
        println!(" add {label}: only in {}", args.new);
    }
    println!(
        "benchdiff: {compared} metric(s) compared, {flagged} beyond {}%, {} removed, {} added",
        args.threshold,
        old.keys().filter(|l| !new.contains_key(*l)).count(),
        new.keys().filter(|l| !old.contains_key(*l)).count(),
    );
    if args.fail_on_regression && flagged > 0 {
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}
