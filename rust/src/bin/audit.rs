//! `intattn-audit` — run the in-repo static-analysis gate.
//!
//! ```text
//! cargo run --bin audit                      # check; exit 1 on findings
//! cargo run --bin audit -- --write-env-table # regenerate rust/audit/env_vars.md
//! ```
//!
//! Passes (see `intattention::audit` for the full story):
//! integer-domain purity lint over `// AUDIT: int-only` fences, the unsafe
//! inventory (`rust/audit/unsafe_inventory.toml`), and the `INTATTN_*`
//! env-var inventory (`rust/audit/env_vars.md`).

use std::process::ExitCode;

use intattention::audit;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let write_table = match args.iter().map(String::as_str).collect::<Vec<_>>()[..] {
        [] => false,
        ["--write-env-table"] => true,
        _ => {
            eprintln!("usage: audit [--write-env-table]");
            return ExitCode::from(2);
        }
    };

    let root = audit::crate_root();
    let outcome = match audit::run(&root) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("audit: failed to read crate sources: {e}");
            return ExitCode::from(2);
        }
    };

    if write_table {
        let table = audit::envscan::render_table(&outcome.env_vars);
        let path = root.join("audit/env_vars.md");
        if let Err(e) = std::fs::write(&path, table) {
            eprintln!("audit: failed to write {}: {e}", path.display());
            return ExitCode::from(2);
        }
        println!("audit: wrote {}", path.display());
        // Fall through: still report findings (a freshly written table
        // clears only the staleness finding on the *next* run, so filter
        // it here to keep `--write-env-table` usable as a fix-up step).
    }

    let findings: Vec<_> = outcome
        .findings
        .iter()
        .filter(|f| !(write_table && f.message.contains("table is stale")))
        .collect();

    println!(
        "audit: {} files, {} int-only regions, {} env vars",
        audit::collect_sources(&root).map(|f| f.len()).unwrap_or(0),
        outcome.regions.len(),
        outcome.env_vars.len(),
    );
    if findings.is_empty() {
        println!("audit: OK");
        return ExitCode::SUCCESS;
    }
    eprintln!("audit: {} finding(s):", findings.len());
    for f in &findings {
        eprintln!("  {f}");
    }
    ExitCode::FAILURE
}
