//! `serve` — the TCP front-end binary: start the IntAttention serving
//! engine and expose it over the length-prefixed wire protocol of
//! [`intattention::coordinator::tcp`] (see the README's "serving
//! front-end" section for the frame tables).
//!
//! ```sh
//! cargo run --release --bin serve -- --addr 127.0.0.1:7411
//! # in another shell: one streamed smoke request
//! cargo run --release --bin serve -- --client --addr 127.0.0.1:7411
//! ```
//!
//! The listen address comes from `--addr`, falling back to
//! `INTATTN_SERVE_ADDR`, then `127.0.0.1:7411`. The server runs until the
//! process is killed; `--client` instead connects to `--addr`, drives one
//! streamed request, prints every frame, and exits 0 iff the stream
//! terminated with a FINAL frame.

use intattention::attention::PipelineKind;
use intattention::coordinator::batcher::BatchPolicy;
use intattention::coordinator::tcp::{run_client, ServerMsg, TcpServer};
use intattention::coordinator::{Engine, EngineOptions, SubmitOptions};
use intattention::harness::experiments::load_or_random_weights;
use intattention::util::cli::Command;
use std::sync::Arc;

const DEFAULT_ADDR: &str = "127.0.0.1:7411";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("serve", "TCP front-end for the IntAttention serving engine")
        .opt("addr", "listen/connect address (default INTATTN_SERVE_ADDR)", None)
        .opt("pipeline", "attention backend", Some("int"))
        .opt("max-active", "max concurrent decodes", Some("8"))
        .opt("max-queue", "wait-queue bound (backpressure)", Some("64"))
        .opt("gen", "--client: tokens to request", Some("8"))
        .flag("client", "drive one streamed request against --addr and exit");
    let args = match cmd.parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => std::env::var("INTATTN_SERVE_ADDR").unwrap_or_else(|_| DEFAULT_ADDR.into()),
    };
    let run = || -> anyhow::Result<()> {
        if args.flag("client") {
            client(&addr, args.get_usize("gen", 8)?)
        } else {
            server(&addr, &args)
        }
    };
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn server(addr: &str, args: &intattention::util::cli::Args) -> anyhow::Result<()> {
    let kind = args.get_or("pipeline", "int");
    let kind = PipelineKind::parse(kind)
        .ok_or_else(|| anyhow::anyhow!("unknown pipeline '{kind}'"))?;
    let opts = EngineOptions {
        attention: kind,
        policy: BatchPolicy {
            max_active: args.get_usize("max-active", 8)?,
            ..Default::default()
        },
        max_queue: args.get_usize("max-queue", 64)?,
        ..Default::default()
    };
    let engine = Arc::new(Engine::start(load_or_random_weights(), opts));
    let server = TcpServer::spawn(Arc::clone(&engine), addr)?;
    println!("serving on {} (pipeline {})", server.local_addr(), kind.name());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(60));
        println!("{}", engine.metrics().render());
    }
}

fn client(addr: &str, gen: usize) -> anyhow::Result<()> {
    let prompt: Vec<u16> = (1..=8).collect();
    let events = run_client(addr, &prompt, gen, SubmitOptions::default())?;
    let mut ok = false;
    for ev in &events {
        match ev {
            ServerMsg::Queued { id, .. } => println!("queued id={id}"),
            ServerMsg::Prefilling { ts_us, .. } => println!("prefilling at {ts_us}us"),
            ServerMsg::Token { index, token, ts_us, .. } => {
                println!("token[{index}] = {token} at {ts_us}us")
            }
            ServerMsg::Final { finish, total_us, tokens, .. } => {
                println!("final: finish={finish} tokens={tokens:?} total={total_us}us");
                ok = *finish == 0 && !tokens.is_empty();
            }
            ServerMsg::Rejected { code, .. } => println!("rejected: code {code}"),
        }
    }
    anyhow::ensure!(ok, "stream did not end in a successful FINAL frame");
    Ok(())
}
