//! A minimal Rust tokenizer for the audit passes.
//!
//! This is not a full lexer — it classifies exactly what the passes need
//! to reason about source without being fooled by comments and literals:
//!
//! * identifiers/keywords (`f32`, `unsafe`, `fn`, …),
//! * numeric literals, split into **integer** vs **float** (the purity
//!   lint's hard case: `1.0`, `1e-3`, `2f32` are floats; `0..n`, tuple
//!   index `.0`, `0x1e3` and `1.max(2)` are not),
//! * string / raw-string / byte-string / char literals (with contents, so
//!   the env pass can find `"INTATTN_*"` reads),
//! * lifetimes (so `'a` is not mistaken for an unterminated char),
//! * comments (with contents, so the purity pass can see `AUDIT:` fence
//!   markers and the unsafety pass can see `SAFETY:` tags),
//! * every other byte as punctuation.
//!
//! Offline-cache constraint: no `syn`/`proc-macro2`, so this is written
//! from scratch against the token grammar the crate actually uses.

/// One classified token with the 1-indexed line it starts on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    pub line: usize,
    pub kind: TokKind,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident(String),
    /// Integer literal (including `0x`/`0o`/`0b` and int-suffixed forms).
    Int,
    /// Float literal (`1.0`, `1.`, `1e-3`, `1f32`, `1.5e2f64`, …).
    Float(String),
    /// String-ish literal (`"…"`, `r"…"`, `r#"…"#`, `b"…"`, `c"…"`)
    /// with its unquoted contents (escapes left as written).
    Str(String),
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`).
    Lifetime,
    /// `//`/`/*…*/` comment with its contents (markers included).
    Comment(String),
    /// Any other single byte of punctuation.
    Punct(char),
}

/// Tokenize `src`. Unterminated constructs (string, block comment) consume
/// to end of input rather than erroring — the audit runs on code that the
/// compiler will reject anyway if truly malformed.
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { b: src.as_bytes(), i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: usize,
    out: Vec<Tok>,
}

fn is_ident_start(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphabetic()
}

fn is_ident_cont(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

impl Lexer<'_> {
    fn peek(&self, ahead: usize) -> u8 {
        *self.b.get(self.i + ahead).unwrap_or(&0)
    }

    /// Advance one byte, tracking line numbers.
    fn bump(&mut self) -> u8 {
        let c = self.b[self.i];
        self.i += 1;
        if c == b'\n' {
            self.line += 1;
        }
        c
    }

    fn push(&mut self, line: usize, kind: TokKind) {
        self.out.push(Tok { line, kind });
    }

    fn run(mut self) -> Vec<Tok> {
        while self.i < self.b.len() {
            let line = self.line;
            let c = self.peek(0);
            match c {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek(1) == b'/' => self.line_comment(line),
                b'/' if self.peek(1) == b'*' => self.block_comment(line),
                b'r' if self.peek(1) == b'"' || (self.peek(1) == b'#' && self.raw_str_ahead(1)) => {
                    self.bump();
                    self.raw_string(line);
                }
                // b"…" / br#"…"# / c"…" byte- and C-string forms.
                b'b' | b'c'
                    if self.peek(1) == b'"'
                        || (self.peek(1) == b'r' && (self.peek(2) == b'"' || self.peek(2) == b'#')) =>
                {
                    self.bump();
                    if self.peek(0) == b'r' {
                        self.bump();
                        self.raw_string(line);
                    } else {
                        self.bump();
                        self.quoted_string(line);
                    }
                }
                b'b' if self.peek(1) == b'\'' => {
                    self.bump();
                    self.bump();
                    self.char_body(line);
                }
                _ if is_ident_start(c) => self.ident(line),
                _ if c.is_ascii_digit() => self.number(line),
                b'"' => {
                    self.bump();
                    self.quoted_string(line);
                }
                b'\'' => self.quote(line),
                _ => {
                    self.bump();
                    self.push(line, TokKind::Punct(c as char));
                }
            }
        }
        self.out
    }

    /// Is `r` followed (after `hashes_at` offset) by `#…#"`? Distinguishes
    /// `r#"raw"#` from the raw identifier `r#match`.
    fn raw_str_ahead(&self, mut at: usize) -> bool {
        while self.peek(at) == b'#' {
            at += 1;
        }
        self.peek(at) == b'"'
    }

    fn line_comment(&mut self, line: usize) {
        let start = self.i;
        while self.i < self.b.len() && self.peek(0) != b'\n' {
            self.bump();
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.push(line, TokKind::Comment(text));
    }

    fn block_comment(&mut self, line: usize) {
        let start = self.i;
        self.bump();
        self.bump();
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            if self.peek(0) == b'/' && self.peek(1) == b'*' {
                depth += 1;
                self.bump();
                self.bump();
            } else if self.peek(0) == b'*' && self.peek(1) == b'/' {
                depth -= 1;
                self.bump();
                self.bump();
            } else {
                self.bump();
            }
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.push(line, TokKind::Comment(text));
    }

    fn ident(&mut self, line: usize) {
        let start = self.i;
        while self.i < self.b.len() && is_ident_cont(self.peek(0)) {
            self.bump();
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.push(line, TokKind::Ident(text));
    }

    /// `"…"` body after the opening quote was consumed.
    fn quoted_string(&mut self, line: usize) {
        let start = self.i;
        while self.i < self.b.len() {
            match self.peek(0) {
                b'\\' => {
                    self.bump();
                    if self.i < self.b.len() {
                        self.bump();
                    }
                }
                b'"' => break,
                _ => {
                    self.bump();
                }
            }
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        if self.i < self.b.len() {
            self.bump(); // closing quote
        }
        self.push(line, TokKind::Str(text));
    }

    /// `#…#"…"#…#` body after `r` was consumed.
    fn raw_string(&mut self, line: usize) {
        let mut hashes = 0usize;
        while self.peek(0) == b'#' {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening quote
        let start = self.i;
        let mut end = self.i;
        while self.i < self.b.len() {
            if self.peek(0) == b'"' {
                let mut h = 0;
                while h < hashes && self.peek(1 + h) == b'#' {
                    h += 1;
                }
                if h == hashes {
                    end = self.i;
                    self.bump();
                    for _ in 0..hashes {
                        self.bump();
                    }
                    break;
                }
            }
            self.bump();
            end = self.i;
        }
        let text = String::from_utf8_lossy(&self.b[start..end]).into_owned();
        self.push(line, TokKind::Str(text));
    }

    /// `'` dispatch: lifetime (`'a`) vs char literal (`'a'`, `'\n'`).
    fn quote(&mut self, line: usize) {
        self.bump(); // the quote
        if self.peek(0) == b'\\' {
            // Escaped char literal.
            self.char_body(line);
        } else if is_ident_start(self.peek(0)) && self.peek(1) != b'\'' {
            // `'ident` not followed by a closing quote: lifetime.
            while self.i < self.b.len() && is_ident_cont(self.peek(0)) {
                self.bump();
            }
            self.push(line, TokKind::Lifetime);
        } else {
            self.char_body(line);
        }
    }

    /// Char-literal body after the opening `'`.
    fn char_body(&mut self, line: usize) {
        while self.i < self.b.len() {
            match self.peek(0) {
                b'\\' => {
                    self.bump();
                    if self.i < self.b.len() {
                        self.bump();
                    }
                }
                b'\'' => {
                    self.bump();
                    break;
                }
                _ => {
                    self.bump();
                }
            }
        }
        self.push(line, TokKind::Char);
    }

    fn number(&mut self, line: usize) {
        // A number directly after a single `.` token is a tuple index
        // (`x.0`, nested `x.0.1`) — digits only, never a float. Two dots
        // are a range (`0.0..1.0`), where a normal literal follows.
        let after_dot = matches!(self.out.last().map(|t| &t.kind), Some(TokKind::Punct('.')))
            && !matches!(
                self.out.len().checked_sub(2).and_then(|j| self.out.get(j)).map(|t| &t.kind),
                Some(TokKind::Punct('.'))
            );
        if after_dot {
            while self.peek(0).is_ascii_digit() {
                self.bump();
            }
            self.push(line, TokKind::Int);
            return;
        }
        let start = self.i;
        let mut float = false;
        if self.peek(0) == b'0' && matches!(self.peek(1), b'x' | b'o' | b'b') {
            // Radix literal: always an integer (covers `0x1e3`).
            self.bump();
            self.bump();
            while is_ident_cont(self.peek(0)) {
                self.bump();
            }
        } else {
            while self.peek(0).is_ascii_digit() || self.peek(0) == b'_' {
                self.bump();
            }
            // Fractional part — but `0..n` is a range, `1.max(2)` a method
            // call, and a field access never starts at a digit so `.`
            // followed by ident-start is never a fraction.
            if self.peek(0) == b'.' && self.peek(1) != b'.' && !is_ident_start(self.peek(1)) {
                float = true;
                self.bump();
                while self.peek(0).is_ascii_digit() || self.peek(0) == b'_' {
                    self.bump();
                }
            }
            // Exponent.
            if matches!(self.peek(0), b'e' | b'E') {
                let sign = matches!(self.peek(1), b'+' | b'-');
                let digit_at = if sign { 2 } else { 1 };
                if self.peek(digit_at).is_ascii_digit() {
                    float = true;
                    self.bump(); // e
                    if sign {
                        self.bump();
                    }
                    while self.peek(0).is_ascii_digit() || self.peek(0) == b'_' {
                        self.bump();
                    }
                }
            }
            // Suffix: `1f32` / `2.5f64` are floats; `7u32` stays an int.
            if is_ident_start(self.peek(0)) {
                let sfx_start = self.i;
                while is_ident_cont(self.peek(0)) {
                    self.bump();
                }
                let sfx = &self.b[sfx_start..self.i];
                if sfx == b"f32" || sfx == b"f64" {
                    float = true;
                }
            }
        }
        if float {
            let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
            self.push(line, TokKind::Float(text));
        } else {
            self.push(line, TokKind::Int);
        }
    }
}

/// The non-comment tokens of `src` (what most passes iterate).
pub fn code_tokens(src: &str) -> Vec<Tok> {
    lex(src).into_iter().filter(|t| !matches!(t.kind, TokKind::Comment(_))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokKind> {
        lex(src).into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn float_vs_int_disambiguation() {
        // Ranges, tuple indexes, method calls on literals and hex digits
        // that look like exponents must all stay integers.
        assert!(kinds("0..n").iter().all(|k| !matches!(k, TokKind::Float(_))));
        assert!(kinds("x.0").iter().all(|k| !matches!(k, TokKind::Float(_))));
        assert!(kinds("x.0.1").iter().all(|k| !matches!(k, TokKind::Float(_))));
        assert_eq!(
            kinds("0.0..=1.0").iter().filter(|k| matches!(k, TokKind::Float(_))).count(),
            2,
            "floats on both sides of a range"
        );
        assert!(kinds("1.max(2)").iter().all(|k| !matches!(k, TokKind::Float(_))));
        assert!(kinds("0x1e3 + 7u32").iter().all(|k| !matches!(k, TokKind::Float(_))));
        for src in ["1.0", "1.", "1e-3", "2f32", "3.5e2f64", "1_000.5"] {
            assert!(
                kinds(src).iter().any(|k| matches!(k, TokKind::Float(_))),
                "{src} must lex as a float"
            );
        }
    }

    #[test]
    fn strings_and_chars_hide_their_contents() {
        let toks = kinds(r#"let s = "f32 1.0 // not a comment"; let c = 'f';"#);
        assert!(toks.iter().all(|k| !matches!(k, TokKind::Float(_))));
        assert!(toks.iter().all(|k| !matches!(k, TokKind::Comment(_))));
        assert!(!toks.iter().any(|k| matches!(k, TokKind::Ident(i) if i == "f32")));
        assert!(toks
            .iter()
            .any(|k| matches!(k, TokKind::Str(s) if s.contains("f32"))));
    }

    #[test]
    fn raw_and_byte_strings() {
        let toks = kinds(r##"let a = r#"raw "quoted" f64"#; let b = b"bytes"; let l: &'static str = "";"##);
        assert_eq!(
            toks.iter().filter(|k| matches!(k, TokKind::Str(_))).count(),
            3
        );
        assert!(toks.iter().any(|k| matches!(k, TokKind::Lifetime)));
        assert!(!toks.iter().any(|k| matches!(k, TokKind::Ident(i) if i == "f64")));
    }

    #[test]
    fn escaped_quote_char_literal() {
        let toks = kinds(r"let q = '\''; let f = 1.5;");
        assert!(toks.iter().any(|k| matches!(k, TokKind::Char)));
        assert!(toks.iter().any(|k| matches!(k, TokKind::Float(f) if f == "1.5")));
    }

    #[test]
    fn comments_carry_text_and_nest() {
        let toks = lex("// AUDIT: int-only begin x\nlet y = 1; /* outer /* inner */ f32 */ let z = 2;");
        let comments: Vec<_> = toks
            .iter()
            .filter_map(|t| match &t.kind {
                TokKind::Comment(c) => Some(c.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(comments.len(), 2);
        assert!(comments[0].contains("AUDIT: int-only begin x"));
        assert!(comments[1].contains("inner"));
        // The f32 inside the block comment is not an identifier token.
        assert!(!toks.iter().any(|t| matches!(&t.kind, TokKind::Ident(i) if i == "f32")));
    }

    #[test]
    fn line_numbers_track_newlines() {
        let toks = lex("a\nb\n  c");
        let lines: Vec<usize> = toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 3]);
    }
}
