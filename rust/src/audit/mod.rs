//! `intattn-audit` — the in-repo static-analysis gate (`cargo run --bin audit`).
//!
//! Three passes over the crate's own sources (`src/`, `tests/`,
//! `benches/`), built on a small hand-rolled tokenizer ([`lexer`]) so the
//! gate needs nothing from a registry:
//!
//! * [`purity`] — **integer-domain purity lint**: inside
//!   `// AUDIT: int-only` fenced regions of the integer hot paths, any
//!   `f32`/`f64` identifier or float literal is an error unless excused by
//!   `rust/audit/int_only_allow.txt`. The audit's tests cross-check every
//!   fenced region against a conversion-count claim in
//!   [`crate::attention::counts`], so a fence is never decorative.
//! * [`unsafety`] — **unsafe inventory**: every `unsafe` site carries a
//!   `// SAFETY:` comment and an entry in
//!   `rust/audit/unsafe_inventory.toml` (justification + exercising test);
//!   stale entries fail too. See `docs/UNSAFE_POLICY.md`.
//! * [`envscan`] — **env-var inventory**: every `INTATTN_*` read appears
//!   in the [`crate::util::env`] module-doc table and in the generated
//!   `rust/audit/env_vars.md`.
//!
//! Passes take `(file, source)` pairs, so unit tests drive them with
//! in-memory seeded violations; the binary feeds them the real tree.

pub mod envscan;
pub mod lexer;
pub mod purity;
pub mod unsafety;

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// One audit violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Crate-relative path (or the data file the finding is about).
    pub file: String,
    /// 1-indexed line; 0 when the finding is about a whole file.
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(file: impl Into<String>, line: usize, message: impl Into<String>) -> Self {
        Finding { file: file.into(), line, message: message.into() }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line > 0 {
            write!(f, "{}:{}: {}", self.file, self.line, self.message)
        } else {
            write!(f, "{}: {}", self.file, self.message)
        }
    }
}

/// Everything one audit run produces.
pub struct AuditOutcome {
    pub findings: Vec<Finding>,
    /// Every `int-only` fenced region found (file, name) — exposed for the
    /// region↔claim cross-check.
    pub regions: Vec<purity::Region>,
    /// `INTATTN_*` variable -> referencing files.
    pub env_vars: BTreeMap<String, Vec<String>>,
}

/// The crate root (where `Cargo.toml`, `src/` and `audit/` live), resolved
/// at compile time so `cargo run --bin audit` works from any directory.
pub fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// All `.rs` sources under `src/`, `tests/` and `benches/` as
/// `(crate-relative path, contents)`, sorted by path for determinism.
/// (`vendor/` is intentionally out of scope: the audit governs this
/// crate's code, not vendored dependencies. The audit's own sources are
/// excluded too — its unit tests deliberately embed seeded violations
/// (floats in fences, uncommented `unsafe`, fabricated `INTATTN_*` names)
/// that must not trip the real run.)
pub fn collect_sources(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for top in ["src", "tests", "benches"] {
        let dir = root.join(top);
        if dir.is_dir() {
            walk(&dir, root, &mut out)?;
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<(String, String)>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            if rel.starts_with("src/audit/") || rel == "src/bin/audit.rs" {
                continue;
            }
            out.push((rel, std::fs::read_to_string(&path)?));
        }
    }
    Ok(())
}

fn read_data_file(root: &Path, rel: &str, findings: &mut Vec<Finding>) -> String {
    let path = root.join(rel);
    match std::fs::read_to_string(&path) {
        Ok(s) => s,
        Err(_) => {
            findings.push(Finding::new(format!("rust/{rel}"), 0, "required audit data file is missing"));
            String::new()
        }
    }
}

/// Run all three passes over the crate rooted at `root`.
pub fn run(root: &Path) -> std::io::Result<AuditOutcome> {
    let files = collect_sources(root)?;
    let mut findings = Vec::new();

    let allow = read_data_file(root, "audit/int_only_allow.txt", &mut findings);
    let inventory = read_data_file(root, "audit/unsafe_inventory.toml", &mut findings);
    let committed_table = read_data_file(root, "audit/env_vars.md", &mut findings);

    let (purity_findings, regions) = purity::run(&files, &allow);
    findings.extend(purity_findings);
    findings.extend(unsafety::run(&files, &inventory));

    let env_rs = files
        .iter()
        .find(|(f, _)| f == "src/util/env.rs")
        .map(|(_, s)| s.clone())
        .unwrap_or_default();
    let (env_findings, env_vars) = envscan::run(&files, &committed_table, &env_rs);
    findings.extend(env_findings);

    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(AuditOutcome { findings, regions, env_vars })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::counts;

    // Reading the real tree needs the filesystem — pointless under Miri
    // (the passes' logic is covered by the in-memory unit tests).
    #[cfg(not(miri))]
    #[test]
    fn audit_passes_on_the_real_tree() {
        let outcome = run(&crate_root()).expect("read crate sources");
        assert!(
            outcome.findings.is_empty(),
            "audit findings on the committed tree:\n{}",
            outcome
                .findings
                .iter()
                .map(|f| format!("  {f}"))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    /// Every `int-only` fence corresponds to a conversion-count claim in
    /// `attention::counts` — deleting a fence, renaming a region, or
    /// fencing code with no accounted claim all fail here. Zero-conversion
    /// regions assert `dtype_conv == 0`; the two boundary regions (the
    /// requantize detour helper and the final output rescale) are fenced
    /// *with allowlisted floats* precisely because their conversions are
    /// the ones the counts model bills.
    #[cfg(not(miri))]
    #[test]
    fn every_fenced_region_is_backed_by_a_conversion_count_claim() {
        let outcome = run(&crate_root()).expect("read crate sources");
        let mut seen = std::collections::BTreeSet::new();
        for r in &outcome.regions {
            seen.insert(r.name.clone());
            let (v, rows, m, d) = (1000u64, 10u64, 4usize, 64usize);
            match r.name.as_str() {
                // IndexSoftmax proper: zero conversions, zero float exps.
                "index-softmax-forward" | "index-softmax-row" | "index-softmax-observe-max"
                | "index-softmax-gather" | "index-softmax-merge"
                | "index-softmax-rescale-lane" | "int-decode-softmax" => {
                    let c = counts::index_softmax(v, rows);
                    assert_eq!(c.dtype_conv, 0, "{}", r.name);
                    assert_eq!(c.fp32_exp, 0, "{}", r.name);
                }
                // i8 Q·Kᵀ kernels: integer MACs, no conversions.
                "gemm-i8-paged" => {
                    let c = counts::qk_gemm(m, v as usize, d, 1, 4);
                    assert_eq!(c.dtype_conv, 0);
                    assert!(c.int8_mac > 0 && c.fp32_mac == 0);
                }
                // P̂·V̂ aggregation kernels (u8/i8, the fused i8 walk, and
                // the tiled-prefill i8 walk).
                "gemm-u8i8-paged" | "gemm-i8-notrans-paged" | "gemm-fused-decode-i8"
                | "gemm-tiled-prefill-i8" => {
                    let c = counts::pv_gemm(v, v as usize, d, 1, 4);
                    assert_eq!(c.dtype_conv, 0, "{}", r.name);
                    assert!(c.int8_mac > 0 && c.fp32_mac == 0, "{}", r.name);
                }
                // EXAQ fused walk: now pure integer in the kernel (bucketed
                // i64 lane sums); the per-element ×255 requantize is gone.
                "gemm-fused-decode-exaq" => {
                    assert_eq!(counts::exaq_softmax_fused(v, rows).dtype_conv, 0);
                }
                // EXAQ tiled prefill: the stats walk is pure integer; the
                // gather+P̂V̂ walk replays the materialized operator, whose
                // ×255 requantize conversions the counts model bills.
                "gemm-tiled-prefill-exaq" => {
                    assert_eq!(counts::exaq_softmax(v, rows).dtype_conv, v);
                }
                // Boundary regions: conversions exist and are counted.
                "requantize-probs-i8" => {
                    assert_eq!(counts::requantize_probs(v).dtype_conv, v);
                }
                "int-decode-output-rescale" => {
                    assert_eq!(counts::output_rescale(m, d).dtype_conv, (m * d) as u64);
                }
                other => panic!(
                    "fenced region `{other}` ({}:{}) has no conversion-count claim — \
                     add one here and in attention::counts",
                    r.file, r.begin_line
                ),
            }
        }
        // The fences the integer hot paths must carry; losing one (e.g. a
        // refactor dropping the markers) breaks the audit's coverage.
        for required in [
            "index-softmax-forward",
            "index-softmax-row",
            "index-softmax-observe-max",
            "index-softmax-gather",
            "index-softmax-merge",
            "index-softmax-rescale-lane",
            "int-decode-softmax",
            "int-decode-output-rescale",
            "gemm-i8-paged",
            "gemm-u8i8-paged",
            "gemm-i8-notrans-paged",
            "gemm-fused-decode-i8",
            "gemm-fused-decode-exaq",
            "gemm-tiled-prefill-i8",
            "gemm-tiled-prefill-exaq",
            "requantize-probs-i8",
        ] {
            assert!(seen.contains(required), "required int-only fence `{required}` is missing");
        }
    }

    #[cfg(not(miri))]
    #[test]
    fn env_scan_sees_the_snapshot_knobs() {
        let outcome = run(&crate_root()).expect("read crate sources");
        for var in ["INTATTN_THREADS", "INTATTN_KV_PAGE", "INTATTN_FUSED_DECODE"] {
            assert!(
                outcome.env_vars.contains_key(var),
                "{var} read not found by the env scan"
            );
        }
        // The snapshot knobs are read in exactly one place.
        assert_eq!(outcome.env_vars["INTATTN_THREADS"], vec!["src/util/env.rs".to_string()]);
    }
}
