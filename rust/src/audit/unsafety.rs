//! Unsafe inventory pass.
//!
//! Two obligations per `unsafe` site (block, fn, or impl):
//!
//! 1. a `// SAFETY:` comment at the site — on the same line or within the
//!    6 lines above it; `unsafe fn` declarations may alternatively carry a
//!    `# Safety` section in their doc comment (the std convention);
//! 2. a matching entry in `rust/audit/unsafe_inventory.toml`, keyed by
//!    `file` plus a `pattern` substring of the site's source line (stable
//!    across line drift), with a written `justification` and a `tested_by`
//!    pointer at the test that exercises the site.
//!
//! Matching is bidirectional: an unsafe site with no inventory entry fails
//! the audit, and an inventory entry matching no site fails it too (stale
//! inventory rots loudly). One entry may cover several sites — repeated
//! idioms (the disjoint-row `from_raw_parts_mut` reconstructions in the
//! GEMM drivers) document the shared argument once.
//!
//! `unsafe fn` **pointer types** (`func_call: unsafe fn(*const (), …)`)
//! declare no unsafe operation and are skipped: after `unsafe fn` the next
//! token being `(` means a type, not a declaration.

use super::lexer::{code_tokens, TokKind};
use super::Finding;

/// One `[[site]]` entry of the inventory file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    pub file: String,
    pub pattern: String,
    pub justification: String,
    pub tested_by: String,
}

/// Hand-rolled parse of the inventory's TOML subset: `[[site]]` headers,
/// `key = "value"` pairs, `#` comments. (The offline cache has no `toml`
/// crate; the audit is registry-independent by design.)
pub fn parse_inventory(text: &str) -> Result<Vec<Entry>, String> {
    let mut out: Vec<Entry> = Vec::new();
    let mut cur: Option<Entry> = None;
    for (i, raw) in text.lines().enumerate() {
        let ln = i + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[site]]" {
            if let Some(e) = cur.take() {
                finish(e, &mut out, ln)?;
            }
            cur = Some(Entry {
                file: String::new(),
                pattern: String::new(),
                justification: String::new(),
                tested_by: String::new(),
            });
            continue;
        }
        let Some((key, val)) = line.split_once('=') else {
            return Err(format!("unsafe_inventory.toml:{ln}: expected `key = \"value\"`, got `{raw}`"));
        };
        let key = key.trim();
        let val = val.trim();
        if val.len() < 2 || !val.starts_with('"') || !val.ends_with('"') {
            return Err(format!("unsafe_inventory.toml:{ln}: value for `{key}` must be a quoted string"));
        }
        let val = val[1..val.len() - 1].to_string();
        let Some(e) = cur.as_mut() else {
            return Err(format!("unsafe_inventory.toml:{ln}: `{key}` before any [[site]] header"));
        };
        match key {
            "file" => e.file = val,
            "pattern" => e.pattern = val,
            "justification" => e.justification = val,
            "tested_by" => e.tested_by = val,
            _ => return Err(format!("unsafe_inventory.toml:{ln}: unknown key `{key}`")),
        }
    }
    if let Some(e) = cur.take() {
        finish(e, &mut out, 0)?;
    }
    Ok(out)
}

fn finish(e: Entry, out: &mut Vec<Entry>, ln: usize) -> Result<(), String> {
    for (field, v) in [
        ("file", &e.file),
        ("pattern", &e.pattern),
        ("justification", &e.justification),
        ("tested_by", &e.tested_by),
    ] {
        if v.is_empty() {
            return Err(format!(
                "unsafe_inventory.toml (near line {ln}): [[site]] missing required field `{field}`"
            ));
        }
    }
    out.push(e);
    Ok(())
}

/// One detected `unsafe` site.
#[derive(Debug, Clone)]
pub struct Site {
    pub file: String,
    pub line: usize,
    /// The trimmed source line holding the `unsafe` token (what inventory
    /// patterns match against).
    pub text: String,
    /// `unsafe fn` declaration (eligible for the doc `# Safety` form).
    pub is_fn_decl: bool,
}

/// Scan one file for unsafe sites, skipping `unsafe fn(...)` pointer types.
pub fn sites(file: &str, src: &str) -> Vec<Site> {
    let lines: Vec<&str> = src.lines().collect();
    let toks = code_tokens(src);
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if !matches!(&t.kind, TokKind::Ident(w) if w == "unsafe") {
            continue;
        }
        let next = toks.get(i + 1).map(|t| &t.kind);
        let is_fn = matches!(next, Some(TokKind::Ident(w)) if w == "fn");
        if is_fn {
            // `unsafe fn (` is a function-pointer *type*: no site.
            let after = toks.get(i + 2).map(|t| &t.kind);
            if matches!(after, Some(TokKind::Punct('('))) {
                continue;
            }
        }
        out.push(Site {
            file: file.to_string(),
            line: t.line,
            text: lines.get(t.line - 1).map_or_else(String::new, |l| l.trim().to_string()),
            is_fn_decl: is_fn,
        });
    }
    out
}

/// How far above a site a `// SAFETY:` comment may sit.
const SAFETY_WINDOW: usize = 6;

/// Does `site` carry its safety comment? Same line or the `SAFETY_WINDOW`
/// lines above must contain `SAFETY:`; an `unsafe fn` declaration may
/// instead document a `# Safety` section in the contiguous doc/attribute
/// block above it.
fn has_safety_comment(site: &Site, lines: &[&str]) -> bool {
    let idx = site.line - 1; // 0-indexed
    let lo = idx.saturating_sub(SAFETY_WINDOW);
    if lines[lo..=idx.min(lines.len() - 1)].iter().any(|l| l.contains("SAFETY:")) {
        return true;
    }
    if site.is_fn_decl {
        // Walk the contiguous `///` / `//` / `#[...]` block upward.
        let mut j = idx;
        while j > 0 {
            j -= 1;
            let l = lines[j].trim();
            if l.starts_with("///") || l.starts_with("//") || l.starts_with("#[") || l.is_empty() {
                if l.contains("# Safety") {
                    return true;
                }
            } else {
                break;
            }
        }
    }
    false
}

/// Run the unsafety pass over `(file, src)` pairs against `inventory_text`.
pub fn run(files: &[(String, String)], inventory_text: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let entries = match parse_inventory(inventory_text) {
        Ok(e) => e,
        Err(e) => {
            findings.push(Finding::new("rust/audit/unsafe_inventory.toml", 0, e));
            return findings;
        }
    };
    let mut entry_used = vec![false; entries.len()];
    for (file, src) in files {
        let lines: Vec<&str> = src.lines().collect();
        for site in sites(file, src) {
            if !has_safety_comment(&site, &lines) {
                findings.push(Finding::new(
                    file,
                    site.line,
                    format!("unsafe site without a `// SAFETY:` comment: `{}`", site.text),
                ));
            }
            let mut matched = false;
            for (i, e) in entries.iter().enumerate() {
                if e.file == *file && site.text.contains(&e.pattern) {
                    entry_used[i] = true;
                    matched = true;
                }
            }
            if !matched {
                findings.push(Finding::new(
                    file,
                    site.line,
                    format!(
                        "unsafe site not in rust/audit/unsafe_inventory.toml: `{}`",
                        site.text
                    ),
                ));
            }
        }
    }
    for (e, used) in entries.iter().zip(&entry_used) {
        if !used {
            findings.push(Finding::new(
                "rust/audit/unsafe_inventory.toml",
                0,
                format!(
                    "stale inventory entry: no unsafe site in `{}` matches pattern `{}`",
                    e.file, e.pattern
                ),
            ));
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(file: &str, src: &str) -> Vec<(String, String)> {
        vec![(file.to_string(), src.to_string())]
    }

    const INV: &str = r#"
# comment
[[site]]
file = "src/x.rs"
pattern = "from_raw_parts_mut"
justification = "disjoint rows"
tested_by = "tests::covers"
"#;

    #[test]
    fn commented_and_inventoried_site_passes() {
        let src = "
fn f(p: *mut u8) {
    // SAFETY: p is valid for 4 bytes per caller contract.
    let _s = unsafe { std::slice::from_raw_parts_mut(p, 4) };
}
";
        let findings = run(&one("src/x.rs", src), INV);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn seeded_uncommented_unsafe_block_is_caught() {
        // The ISSUE's acceptance seed: an unsafe block with no SAFETY tag.
        let src = "
fn f(p: *mut u8) {
    let _s = unsafe { std::slice::from_raw_parts_mut(p, 4) };
}
";
        let findings = run(&one("src/x.rs", src), INV);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("SAFETY"));
    }

    #[test]
    fn site_missing_from_inventory_is_caught() {
        let src = "
// SAFETY: fine.
unsafe impl Send for Thing {}
";
        let findings = run(&one("src/x.rs", src), INV);
        // Unmatched site + the now-stale from_raw_parts_mut entry.
        assert_eq!(findings.len(), 2, "{findings:?}");
        assert!(findings.iter().any(|f| f.message.contains("not in rust/audit/unsafe_inventory.toml")));
        assert!(findings.iter().any(|f| f.message.contains("stale inventory entry")));
    }

    #[test]
    fn unsafe_fn_pointer_type_is_not_a_site() {
        let src = "struct L { call: unsafe fn(*const (), usize) }";
        assert!(sites("src/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_fn_decl_accepts_doc_safety_section() {
        let src = "
/// Does a thing.
///
/// # Safety
///
/// Caller must ensure `p` is valid.
#[inline]
unsafe fn f(p: *const u8) -> u8 {
    // SAFETY: valid per this fn's contract.
    unsafe { *p }
}
";
        let inv = r#"
[[site]]
file = "src/x.rs"
pattern = "unsafe fn f"
justification = "raw read"
tested_by = "tests::t"
[[site]]
file = "src/x.rs"
pattern = "unsafe { *p }"
justification = "contract"
tested_by = "tests::t"
"#;
        let findings = run(&one("src/x.rs", src), inv);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn inventory_parse_rejects_incomplete_entries() {
        let bad = "[[site]]\nfile = \"src/x.rs\"\npattern = \"p\"\n";
        assert!(parse_inventory(bad).unwrap_err().contains("justification"));
        let bad2 = "file = \"src/x.rs\"\n";
        assert!(parse_inventory(bad2).unwrap_err().contains("before any [[site]]"));
    }

    #[test]
    fn one_entry_may_cover_repeated_idiom_sites() {
        let src = "
fn f(p: *mut u8, q: *mut u8) {
    // SAFETY: disjoint.
    let _a = unsafe { std::slice::from_raw_parts_mut(p, 4) };
    // SAFETY: disjoint.
    let _b = unsafe { std::slice::from_raw_parts_mut(q, 4) };
}
";
        let findings = run(&one("src/x.rs", src), INV);
        assert!(findings.is_empty(), "{findings:?}");
    }
}
