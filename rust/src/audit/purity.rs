//! Integer-domain purity lint.
//!
//! The paper's central claim is a softmax-to-output attention path with
//! **zero** float↔int conversions (PAPER.md; `attention::counts` carries
//! the per-stage arithmetic of that claim). This pass makes the claim
//! mechanically checkable: hot-path code wrapped in
//!
//! ```text
//! // AUDIT: int-only begin <region-name>
//!     …
//! // AUDIT: int-only end
//! ```
//!
//! must contain no `f32`/`f64` identifier (which covers `as f32` casts and
//! type ascriptions) and no float literal. Documented exceptions — the
//! quantization *boundary* kernels whose conversions `attention::counts`
//! explicitly counts, and EXAQ's float normalization — live in an allowlist
//! file (`rust/audit/int_only_allow.txt`); every allowlist entry must fire,
//! so stale exceptions rot loudly.
//!
//! The audit's own tests assert the reverse direction too: every fenced
//! region name maps to a conversion-count claim in
//! [`crate::attention::counts`] (see `super::tests`).

use super::lexer::{lex, Tok, TokKind};
use super::Finding;

/// Fence marker prefixes (the full begin form is `AUDIT: int-only begin
/// <name>`).
const BEGIN: &str = "AUDIT: int-only begin";
const END: &str = "AUDIT: int-only end";

/// One fenced region of one file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    pub file: String,
    pub name: String,
    pub begin_line: usize,
}

/// One allowlist entry: `token` is permitted inside region `region`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    pub region: String,
    pub token: String,
}

/// Parse the allowlist format: one `<region> <token>` pair per line,
/// `#`-comments and blank lines ignored. The token field is the exact
/// lexeme being excused (`f32`, `255.0`, …).
pub fn parse_allowlist(text: &str) -> Result<Vec<Allow>, String> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        match (parts.next(), parts.next(), parts.next()) {
            (Some(region), Some(token), None) => {
                out.push(Allow { region: region.to_string(), token: token.to_string() })
            }
            _ => {
                return Err(format!(
                    "int_only_allow.txt:{}: expected `<region> <token>`, got `{raw}`",
                    i + 1
                ))
            }
        }
    }
    Ok(out)
}

/// The fenced regions of one file (no lint, just the fence structure).
/// Fence errors (begin-inside-begin, end-without-begin, unterminated) are
/// reported as findings.
pub fn regions(file: &str, src: &str, findings: &mut Vec<Finding>) -> Vec<Region> {
    let mut out = Vec::new();
    let mut open: Option<Region> = None;
    for t in lex(src) {
        let TokKind::Comment(text) = &t.kind else { continue };
        if let Some(pos) = text.find(BEGIN) {
            let name = text[pos + BEGIN.len()..].trim().to_string();
            if name.is_empty() {
                findings.push(Finding::new(file, t.line, "int-only fence begin without a region name"));
                continue;
            }
            if let Some(prev) = &open {
                findings.push(Finding::new(
                    file,
                    t.line,
                    format!("int-only fence `{name}` opened inside open fence `{}`", prev.name),
                ));
                continue;
            }
            open = Some(Region { file: file.to_string(), name, begin_line: t.line });
        } else if text.contains(END) {
            match open.take() {
                Some(r) => out.push(r),
                None => findings.push(Finding::new(file, t.line, "int-only fence end without begin")),
            }
        }
    }
    if let Some(r) = open {
        findings.push(Finding::new(
            file,
            r.begin_line,
            format!("int-only fence `{}` never closed", r.name),
        ));
    }
    out
}

/// Lint one file's fenced regions. Returns findings for violations and
/// marks used allowlist entries in `used` (same indexing as `allow`).
pub fn check_file(
    file: &str,
    src: &str,
    allow: &[Allow],
    used: &mut [bool],
    findings: &mut Vec<Finding>,
) {
    let mut open: Option<String> = None;
    for t in lex(src) {
        match &t.kind {
            TokKind::Comment(text) => {
                if let Some(pos) = text.find(BEGIN) {
                    // Structure errors are reported by `regions`; here just
                    // track state (ignore a nested begin).
                    if open.is_none() {
                        open = Some(text[pos + BEGIN.len()..].trim().to_string());
                    }
                } else if text.contains(END) {
                    open = None;
                }
            }
            _ => {
                let Some(region) = &open else { continue };
                if let Some(lexeme) = violating_lexeme(&t) {
                    match allow.iter().position(|a| a.region == *region && a.token == lexeme) {
                        Some(i) => used[i] = true,
                        None => findings.push(Finding::new(
                            file,
                            t.line,
                            format!(
                                "float `{lexeme}` inside int-only region `{region}` \
                                 (allowlist: rust/audit/int_only_allow.txt)"
                            ),
                        )),
                    }
                }
            }
        }
    }
}

/// The lexeme of a float-domain token, if `t` is one.
fn violating_lexeme(t: &Tok) -> Option<String> {
    match &t.kind {
        TokKind::Ident(i) if i == "f32" || i == "f64" => Some(i.clone()),
        TokKind::Float(f) => Some(f.clone()),
        _ => None,
    }
}

/// Run the purity lint over `(file, src)` pairs against `allow_text`.
/// Returns all findings plus every fenced region found (for the
/// region↔claim cross-check).
pub fn run(files: &[(String, String)], allow_text: &str) -> (Vec<Finding>, Vec<Region>) {
    let mut findings = Vec::new();
    let allow = match parse_allowlist(allow_text) {
        Ok(a) => a,
        Err(e) => {
            findings.push(Finding::new("rust/audit/int_only_allow.txt", 0, e));
            return (findings, Vec::new());
        }
    };
    let mut used = vec![false; allow.len()];
    let mut all_regions = Vec::new();
    for (file, src) in files {
        all_regions.extend(regions(file, src, &mut findings));
        check_file(file, src, &allow, &mut used, &mut findings);
    }
    for (a, u) in allow.iter().zip(&used) {
        if !u {
            findings.push(Finding::new(
                "rust/audit/int_only_allow.txt",
                0,
                format!(
                    "unused allowlist entry `{} {}` — the exception no longer exists; remove it",
                    a.region, a.token
                ),
            ));
        }
    }
    (findings, all_regions)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn files(src: &str) -> Vec<(String, String)> {
        vec![("src/x.rs".to_string(), src.to_string())]
    }

    #[test]
    fn clean_region_passes() {
        let src = "
// AUDIT: int-only begin demo
fn f(a: i32) -> i32 { let b = a + 1; b / 2 }
// AUDIT: int-only end
fn g() -> f32 { 1.0 }  // floats outside the fence are fine
";
        let (findings, regions) = run(&files(src), "");
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].name, "demo");
    }

    #[test]
    fn seeded_float_violation_is_caught() {
        // The ISSUE's acceptance seed: inject a float into a fenced region.
        let src = "
// AUDIT: int-only begin demo
fn f(a: i32) -> f32 { let x = 0.5; a as f32 * x }
// AUDIT: int-only end
";
        let (findings, _) = run(&files(src), "");
        // f32 (return type), 0.5, f32 (cast) — three violations.
        assert_eq!(findings.len(), 3, "{findings:?}");
        assert!(findings.iter().all(|f| f.message.contains("int-only region `demo`")));
    }

    #[test]
    fn allowlist_excuses_exactly_the_listed_lexeme() {
        let src = "
// AUDIT: int-only begin exaq
fn f(a: i32) -> f32 { a as f32 * 0.5 }
// AUDIT: int-only end
";
        // f32 excused, 0.5 not.
        let (findings, _) = run(&files(src), "exaq f32\n");
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("0.5"));
        // Excusing both clears the lint.
        let (findings, _) = run(&files(src), "exaq f32\nexaq 0.5\n");
        assert!(findings.is_empty(), "{findings:?}");
        // The same token in a *different* region is not excused.
        let (findings, _) = run(&files(src), "other f32\nexaq 0.5\n");
        assert_eq!(findings.len(), 2, "violation + unused entry: {findings:?}");
    }

    #[test]
    fn unused_allowlist_entry_is_an_error() {
        let (findings, _) = run(&files("fn f() {}"), "ghost f32\n");
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("unused allowlist entry"));
    }

    #[test]
    fn fence_structure_errors() {
        let src = "
// AUDIT: int-only begin a
// AUDIT: int-only begin b
// AUDIT: int-only end
// AUDIT: int-only end
// AUDIT: int-only begin c
";
        let (findings, regions) = run(&files(src), "");
        assert_eq!(regions.len(), 1, "only `a` closes cleanly");
        let msgs: Vec<_> = findings.iter().map(|f| f.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("opened inside open fence")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("end without begin")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("never closed")), "{msgs:?}");
    }

    #[test]
    fn floats_in_comments_and_strings_inside_fence_are_fine() {
        let src = r#"
// AUDIT: int-only begin demo
// eq. 10 uses alpha = 0.125 (f32) — prose, not code
fn f(a: i32) -> i32 { let _m = "f32 1.0"; a }
// AUDIT: int-only end
"#;
        let (findings, _) = run(&files(src), "");
        assert!(findings.is_empty(), "{findings:?}");
    }
}
