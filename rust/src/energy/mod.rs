//! Analytic energy model — the substitution for the paper's wall-plug meter
//! (Figure 8; see DESIGN.md §2).
//!
//! Each pipeline counts the arithmetic and memory operations it executes
//! through an [`OpCounts`] record; [`EnergyModel`] prices them with per-op
//! energies from Horowitz, "Computing's energy problem" (ISSCC 2014, 45 nm),
//! the standard reference for this style of accounting. Absolute joules are
//! process-dependent; the *ratios* between pipelines — what Fig. 8 plots —
//! are governed by the op mix, which we count exactly.

/// Operation/byte counters accumulated by a pipeline forward pass.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCounts {
    /// INT8×INT8→INT32 multiply-accumulates (GEMM work).
    pub int8_mac: u64,
    /// INT32 add/sub/min/max/compare ops (max-subtract, clipping, sums).
    pub int32_alu: u64,
    /// INT32 multiplies (fixed-point scaling, multiply–shift division).
    pub int32_mul: u64,
    /// Table-gather operations (LUT lookups).
    pub lut_gather: u64,
    /// FP16 multiply-accumulates.
    pub fp16_mac: u64,
    /// FP32 multiply-accumulates (float GEMM work).
    pub fp32_mac: u64,
    /// FP32 simple ALU ops (add/sub/mul/cmp as single ops).
    pub fp32_alu: u64,
    /// FP32 transcendental evaluations (`exp`), priced as a multi-op macro.
    pub fp32_exp: u64,
    /// FP32 divisions.
    pub fp32_div: u64,
    /// Datatype conversions (dequantize/requantize/f16↔f32), per element.
    pub dtype_conv: u64,
    /// Bytes moved to/from working memory (operand reads + result writes).
    pub mem_bytes: u64,
}

impl OpCounts {
    pub fn add(&mut self, other: &OpCounts) {
        self.int8_mac += other.int8_mac;
        self.int32_alu += other.int32_alu;
        self.int32_mul += other.int32_mul;
        self.lut_gather += other.lut_gather;
        self.fp16_mac += other.fp16_mac;
        self.fp32_mac += other.fp32_mac;
        self.fp32_alu += other.fp32_alu;
        self.fp32_exp += other.fp32_exp;
        self.fp32_div += other.fp32_div;
        self.dtype_conv += other.dtype_conv;
        self.mem_bytes += other.mem_bytes;
    }

    pub fn total_ops(&self) -> u64 {
        self.int8_mac
            + self.int32_alu
            + self.int32_mul
            + self.lut_gather
            + self.fp16_mac
            + self.fp32_mac
            + self.fp32_alu
            + self.fp32_exp
            + self.fp32_div
            + self.dtype_conv
    }
}

/// Per-op energies in picojoules (45 nm, Horowitz ISSCC'14; exp/div/gather
/// priced as composites of the published primitives).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    pub pj_int8_mac: f64,
    pub pj_int32_alu: f64,
    pub pj_int32_mul: f64,
    pub pj_lut_gather: f64,
    pub pj_fp16_mac: f64,
    pub pj_fp32_mac: f64,
    pub pj_fp32_alu: f64,
    pub pj_fp32_exp: f64,
    pub pj_fp32_div: f64,
    pub pj_dtype_conv: f64,
    /// Per-byte cost of cache/SRAM traffic (8 KB-class SRAM access ≈10 pJ
    /// per 64-bit word → ~1.25 pJ/B; we use a conservative blended figure
    /// that includes some LPDDR traffic).
    pub pj_mem_byte: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            // mul + add pairs from Horowitz Table 1:
            pj_int8_mac: 0.2 + 0.03,        // int8 mul 0.2 + int32 add 0.1 (≈0.03 for 8-bit)
            pj_int32_alu: 0.1,              // int32 add
            pj_int32_mul: 3.1,              // int32 mul
            pj_lut_gather: 1.25 + 0.1,      // small-SRAM read + index add
            pj_fp16_mac: 1.1 + 0.4,         // fp16 mul + add
            pj_fp32_mac: 3.7 + 0.9,         // fp32 mul + add
            pj_fp32_alu: 0.9,
            pj_fp32_exp: 20.0 * 3.7,        // exp ≈ tens of fp32 mul-equivalents (§2.2)
            pj_fp32_div: 4.0 * 3.7,         // iterative divide
            pj_dtype_conv: 1.0,             // int↔fp convert ≈ fp add class
            pj_mem_byte: 1.5,
        }
    }
}

impl EnergyModel {
    /// Total energy in microjoules for a counted workload.
    pub fn energy_uj(&self, c: &OpCounts) -> f64 {
        let pj = c.int8_mac as f64 * self.pj_int8_mac
            + c.int32_alu as f64 * self.pj_int32_alu
            + c.int32_mul as f64 * self.pj_int32_mul
            + c.lut_gather as f64 * self.pj_lut_gather
            + c.fp16_mac as f64 * self.pj_fp16_mac
            + c.fp32_mac as f64 * self.pj_fp32_mac
            + c.fp32_alu as f64 * self.pj_fp32_alu
            + c.fp32_exp as f64 * self.pj_fp32_exp
            + c.fp32_div as f64 * self.pj_fp32_div
            + c.dtype_conv as f64 * self.pj_dtype_conv
            + c.mem_bytes as f64 * self.pj_mem_byte;
        pj * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_counts_zero_energy() {
        let m = EnergyModel::default();
        assert_eq!(m.energy_uj(&OpCounts::default()), 0.0);
    }

    #[test]
    fn int8_mac_is_an_order_cheaper_than_fp32_mac() {
        let m = EnergyModel::default();
        assert!(m.pj_fp32_mac / m.pj_int8_mac > 10.0);
    }

    #[test]
    fn exp_dominates_elementwise_ops() {
        // The premise of the paper: one exp costs tens of int ops.
        let m = EnergyModel::default();
        assert!(m.pj_fp32_exp / m.pj_lut_gather > 30.0);
    }

    #[test]
    fn add_merges_counters() {
        let mut a = OpCounts { int8_mac: 5, mem_bytes: 100, ..Default::default() };
        let b = OpCounts { int8_mac: 3, fp32_exp: 7, ..Default::default() };
        a.add(&b);
        assert_eq!(a.int8_mac, 8);
        assert_eq!(a.fp32_exp, 7);
        assert_eq!(a.mem_bytes, 100);
        assert_eq!(a.total_ops(), 8 + 7);
    }

    #[test]
    fn energy_is_linear_in_counts() {
        let m = EnergyModel::default();
        let c1 = OpCounts { int8_mac: 1000, fp32_exp: 10, mem_bytes: 4096, ..Default::default() };
        let mut c2 = c1;
        c2.add(&c1);
        let e1 = m.energy_uj(&c1);
        let e2 = m.energy_uj(&c2);
        assert!((e2 - 2.0 * e1).abs() < 1e-12);
    }
}
