//! Blocked GEMM kernels for every dtype combination the attention pipelines
//! need:
//!
//! * `f32 × f32 → f32` — the FP32 baseline (`Q·Kᵀ`, `P·V`).
//! * `f16-storage` — FP16 baseline: operands stored as binary16, compute in
//!   f32 (see DESIGN.md §2 on the FP16 substitution).
//! * `i8 × i8 → i32` — quantized `Q̂·K̂ᵀ` (paper eq. 4).
//! * `u8 × i8 → i32` — the `P̂·V̂` aggregation with UINT8 probabilities
//!   (paper §3.2).
//!
//! All kernels take **B pre-transposed** (`bt` is `N×K` row-major, i.e. Bᵀ),
//! so every inner loop is a contiguous dot product that the compiler
//! autovectorizes — the x86 stand-in for the paper's NEON SDOT/I8MM path.
//! Register-blocked 4×2 microkernels with K-tiling keep the accumulators in
//! registers; `par_*` drivers split output rows across the persistent
//! [`ParallelPool`] workers.
//!
//! ## Parallel launch model
//!
//! Every `par_*` driver takes a `&ParallelPool` (the serving path passes
//! [`ParallelPool::global`], sized once from `INTATTN_THREADS`) and
//! dispatches row ranges / groups onto its **persistent workers** — ~µs per
//! launch versus the ~10–30 µs of the old spawn-per-launch
//! (`std::thread::scope`) design. Whether a launch parallelizes at all is
//! the pool's single grain policy (`INTATTN_PAR_GRAIN`, default 2^14 work
//! units per worker): drivers pass their MAC-proportional work estimate
//! (`m·n·k`, or the summed resident-operand elements of a grouped launch)
//! and the pool grants one worker per grain unit, capped at its size. This
//! replaced the per-dtype `PAR_GRAIN_I8/F32/F16` constants (2^16–2^20),
//! which had to keep small-and-medium decode launches inline because each
//! extra worker used to cost an OS-thread spawn; with persistent dispatch
//! the threshold drops by ~1.5 orders of magnitude, so grouped int8 decode
//! launches parallelize far below the old 2^20 bar.
//!
//! ## Paged resident operands
//!
//! The stateful attention path's K̂/V̂ history lives in fixed-size pages
//! ([`crate::attention::state::PagedRows`]), not one contiguous buffer. The
//! `*_paged` kernels take the resident operand as a **page list**
//! (`&[&[T]]`, each page a contiguous run of whole `k`- or `d`-element
//! rows) and walk it in order — contiguity is never required and nothing is
//! ever copied into a flat staging buffer. Paging is pure layout: each
//! output element is still the same per-row dot product (or the same
//! ascending-`j` SAXPY accumulation) the contiguous `*_slices` kernels
//! compute, evaluated by the same row kernel per page segment, so paged
//! output is **byte-equal** to the contiguous kernels at every page size
//! (integer kernels are exact; the float kernels run identical operations
//! in identical order). The AVX-512 i8 row kernel applies per page — a page
//! is a contiguous `rows×k` block, so the 4-wide N-blocking survives paging
//! intact.
//!
//! ## Grouped (batched multi-sequence decode) kernels
//!
//! The serving engine's decode phase issues one `1×L_b` similarity product
//! and one `1×L_b · d` aggregation per sequence per round. A single decode
//! row cannot be split across threads (the `par_*` drivers partition output
//! *rows*, and there is only one), so at batch B the pre-batching engine ran
//! B memory-bound kernel launches back to back. The `*_grouped` drivers take
//! B independent [`GemmGroup`]s — each with its own **page-segmented**
//! resident KV operand and per-group context length `L_b` — and run them in
//! **one** pool launch. Workers claim whole groups (page-aligned spans — a
//! sequence's entire page list) one at a time through the launch's atomic
//! cursor ([`ParallelPool::parallel_groups`]), so ragged batches
//! load-balance dynamically instead of relying on a static strided
//! assignment. Worker count and claim order never affect results: every
//! group owns a disjoint output slice and is computed by the same paged row
//! kernel the sequential path uses.
//!
//! ## Online walk structure (fused decode + tiled prefill)
//!
//! The integer pipelines' flash-style paths never materialize a score row:
//! they walk the resident K̂/V̂ page lists with **two-phase online softmax
//! state** ([`OnlineIndexRow`] / [`ExaqOnlineRow`]). Phase 1 streams the
//! `Q̂K̂ᵀ` tiles through the max fold ([`OnlineIndexRow::observe_max`]);
//! phase 2 re-walks the same tiles with the row max pinned, gathering each
//! logit's LUT weight straight onto the accumulator
//! ([`OnlineIndexRow::gather`]). Recomputing the QK tiles once is the
//! classic flash trade: it buys a state in which **every** partial quantity
//! is an associative integer sum, so the walk can be split at arbitrary
//! page boundaries and merged in any order, byte-identically:
//!
//! * **Fused decode** ([`fused_decode_i8`] / [`fused_decode_exaq`], span
//!   drivers [`par_fused_decode_i8_spans`] / [`par_fused_decode_exaq_spans`]):
//!   one decode row's page list is split into per-worker **spans** (one
//!   [`FusedJobI8`]/[`FusedJobExaq`] each, width policy
//!   `INTATTN_DECODE_SPLIT`). Launch A runs phase 1 per span; the span
//!   maxes merge on the launching thread ([`OnlineIndexRow::merge_max`] —
//!   `max` is associative/commutative) and the joint max is rebroadcast;
//!   launch B runs phase 2 per span; the partial `(max, ΣÊ, acc)` triples
//!   then merge by pure integer adds ([`OnlineIndexRow::merge`] at equal
//!   maxes; EXAQ merges per-bucket counts and lane sums). Single-sequence
//!   deep-context decode therefore scales with pool width while staying
//!   byte-identical to the width-1 sequential walk.
//! * **Tiled prefill** ([`tiled_prefill_i8`], [`tiled_prefill_exaq_stats`] +
//!   [`tiled_prefill_exaq_pv`]): per query row, the same page walk runs
//!   max → gather(ΣÊ) → normalize+`P̂·V̂` as three tile-sized passes (tiles
//!   capped at [`PREFILL_TILE_ROWS`] rows so the scratch is O(1) even for
//!   huge pages), reproducing the materialized path's integer ops in the
//!   materialized order — bit-for-bit equal output for IndexSoftmax — with
//!   no `m×L` score block ever allocated. Rows are independent, so the
//!   drivers parallelize across row blocks ([`ROW_BLOCK`]).

use crate::softmax::exaq::ExaqOnlineRow;
use crate::softmax::index_softmax::{Mask, MulShiftDiv, OnlineIndexRow};
use crate::tensor::{MatF32, MatI32, MatI8, MatU8};
use crate::util::f16::F16;
use crate::util::threadpool::{ParallelPool, SendPtr};

/// K-dimension tile: fits comfortably in L1 alongside 4 A-rows + 2 B-rows.
const KC: usize = 1024;

// ---------------------------------------------------------------------------
// f32

/// `C[m,n] = Σ_k A[m,k]·Bᵀ[n,k]` — B given transposed.
pub fn gemm_f32(a: &MatF32, bt: &MatF32, c: &mut MatF32) {
    let (m, k) = (a.rows(), a.cols());
    let n = bt.rows();
    assert_eq!(bt.cols(), k, "inner dims");
    assert_eq!((c.rows(), c.cols()), (m, n), "output shape");
    gemm_f32_rows(a, bt, c, 0, m);
}

/// Row-range worker (rows `[r0, r1)` of the output), used by the parallel driver.
fn gemm_f32_rows(a: &MatF32, bt: &MatF32, c: &mut MatF32, r0: usize, r1: usize) {
    let k = a.cols();
    let n = bt.rows();
    let a_s = a.as_slice();
    let b_s = bt.as_slice();
    let c_s = c.as_mut_slice();
    // 2×2 register blocking over (m, n); K tiled at KC.
    let mut i = r0;
    while i < r1 {
        let i2 = (i + 2).min(r1);
        let mut j = 0;
        while j < n {
            let j2 = (j + 2).min(n);
            let mut acc = [[0f32; 2]; 2];
            let mut kk = 0;
            while kk < k {
                let ke = (kk + KC).min(k);
                for ii in i..i2 {
                    let arow = &a_s[ii * k + kk..ii * k + ke];
                    for jj in j..j2 {
                        let brow = &b_s[jj * k + kk..jj * k + ke];
                        acc[ii - i][jj - j] += dot_f32(arow, brow);
                    }
                }
                kk = ke;
            }
            for ii in i..i2 {
                for jj in j..j2 {
                    c_s[ii * n + jj] = acc[ii - i][jj - j];
                }
            }
            j = j2;
        }
        i = i2;
    }
}

/// Pool-parallel f32 GEMM.
pub fn par_gemm_f32(a: &MatF32, bt: &MatF32, c: &mut MatF32, pool: &ParallelPool) {
    let (m, k) = (a.rows(), a.cols());
    let n = bt.rows();
    assert_eq!((c.rows(), c.cols()), (m, n));
    let work = m * n * k;
    if pool.workers_for(work) <= 1 {
        return gemm_f32(a, bt, c);
    }
    // Split output rows into disjoint &mut chunks across the workers.
    let c_ptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    pool.parallel_for(m, work, |r0, r1| {
        // SAFETY: each chunk reconstructs only rows [r0, r1) of C, and the
        // pool claims every chunk exactly once — the &mut views are
        // disjoint, in-bounds, and live while the caller blocks.
        let c_chunk =
            unsafe { std::slice::from_raw_parts_mut(c_ptr.get().add(r0 * n), (r1 - r0) * n) };
        gemm_f32_rows_raw(a, bt, c_chunk, r0, r1);
    });
}

fn gemm_f32_rows_raw(a: &MatF32, bt: &MatF32, c_chunk: &mut [f32], r0: usize, r1: usize) {
    let k = a.cols();
    let n = bt.rows();
    let a_s = a.as_slice();
    let b_s = bt.as_slice();
    for ii in r0..r1 {
        let arow = &a_s[ii * k..(ii + 1) * k];
        let crow = &mut c_chunk[(ii - r0) * n..(ii - r0 + 1) * n];
        for jj in 0..n {
            crow[jj] = dot_f32(arow, &b_s[jj * k..(jj + 1) * k]);
        }
    }
}

/// f32 dot product with 8 explicit accumulators: float addition is not
/// associative, so LLVM will not reassociate `s += x*y` into SIMD lanes on
/// its own — unrolling by hand is what unlocks vectorized FMA here.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    const LANES: usize = 16;
    let n = a.len().min(b.len());
    let mut acc = [0f32; LANES];
    let a_chunks = a[..n].chunks_exact(LANES);
    let b_chunks = b[..n].chunks_exact(LANES);
    let (a_rem, b_rem) = (a_chunks.remainder(), b_chunks.remainder());
    for (ca, cb) in a_chunks.zip(b_chunks) {
        for l in 0..LANES {
            acc[l] = ca[l].mul_add(cb[l], acc[l]);
        }
    }
    let mut s = 0f32;
    for l in 0..LANES {
        s += acc[l];
    }
    for (x, y) in a_rem.iter().zip(b_rem) {
        s += x * y;
    }
    s
}

/// `C[i,c] = Σ_j P[i,j]·V[j,c]` with V **not** transposed (SAXPY layout):
/// the `P·V` aggregation for float pipelines. Skips exact zeros in P so the
/// float pipelines get the same masked-column shortcut the integer ones do.
pub fn gemm_f32_notrans(p: &MatF32, v: &MatF32, c: &mut MatF32) {
    let (m, l) = (p.rows(), p.cols());
    let d = v.cols();
    assert_eq!(v.rows(), l, "inner dims");
    assert_eq!((c.rows(), c.cols()), (m, d), "output shape");
    let p_s = p.as_slice();
    let v_s = v.as_slice();
    let c_s = c.as_mut_slice();
    for i in 0..m {
        let prow = &p_s[i * l..(i + 1) * l];
        let crow = &mut c_s[i * d..(i + 1) * d];
        crow.fill(0.0);
        for (j, &pij) in prow.iter().enumerate() {
            if pij == 0.0 {
                continue;
            }
            let vrow = &v_s[j * d..(j + 1) * d];
            for (acc, &vx) in crow.iter_mut().zip(vrow) {
                *acc += pij * vx;
            }
        }
    }
}

/// Slice-based f32 GEMM (`bt` row-major `N×K`): the stateful attention path
/// multiplies against resident KV-state buffers without materializing `Mat`
/// wrappers or copying history.
pub fn gemm_f32_slices(a: &[f32], bt: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(bt.len(), n * k, "Bᵀ shape");
    assert_eq!(c.len(), m * n, "C shape");
    gemm_f32_slices_rows(a, bt, c, n, k, 0, m);
}

fn gemm_f32_slices_rows(a: &[f32], bt: &[f32], c: &mut [f32], n: usize, k: usize, r0: usize, r1: usize) {
    for i in r0..r1 {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, out) in crow.iter_mut().enumerate() {
            *out = dot_f32(arow, &bt[j * k..(j + 1) * k]);
        }
    }
}

/// Pool-parallel [`gemm_f32_slices`].
pub fn par_gemm_f32_slices(
    a: &[f32],
    bt: &[f32],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    pool: &ParallelPool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(c.len(), m * n);
    let work = m * n * k;
    if pool.workers_for(work) <= 1 {
        return gemm_f32_slices(a, bt, c, m, n, k);
    }
    let c_ptr = SendPtr(c.as_mut_ptr());
    pool.parallel_for(m, work, |r0, r1| {
        // SAFETY: the full-C view is written only on rows [r0, r1), which
        // the atomic cursor hands to exactly one worker; C outlives the
        // launch (the caller blocks on the completion latch).
        let c_full = unsafe { std::slice::from_raw_parts_mut(c_ptr.get(), m * n) };
        gemm_f32_slices_rows(a, bt, c_full, n, k, r0, r1);
    });
}

/// Slice-based `P·V` with V in natural `L×d` row layout (no transpose of
/// the resident state); skips exact zeros like [`gemm_f32_notrans`].
pub fn gemm_f32_notrans_slices(p: &[f32], v: &[f32], c: &mut [f32], m: usize, l: usize, d: usize) {
    assert_eq!(p.len(), m * l, "P shape");
    assert_eq!(v.len(), l * d, "V shape");
    assert_eq!(c.len(), m * d, "C shape");
    for i in 0..m {
        let prow = &p[i * l..(i + 1) * l];
        let crow = &mut c[i * d..(i + 1) * d];
        crow.fill(0.0);
        for (j, &pij) in prow.iter().enumerate() {
            if pij == 0.0 {
                continue;
            }
            let vrow = &v[j * d..(j + 1) * d];
            for (acc, &vx) in crow.iter_mut().zip(vrow) {
                *acc += pij * vx;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// f16 storage

/// FP16-storage GEMM: operands are binary16 in memory (half the bandwidth of
/// f32), decoded to f32 in K-tiles and multiplied in f32 — mirroring an edge
/// FP16 pipeline where the register file computes wider than storage.
pub fn gemm_f16(a: &[F16], bt: &[F16], m: usize, n: usize, k: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(c.len(), m * n);
    // Decode B once per call into an f32 scratch (amortized across all M
    // rows), decode A row-by-row.
    let mut bdec = vec![0f32; n * k];
    for (d, &h) in bdec.iter_mut().zip(bt) {
        *d = h.to_f32();
    }
    let mut arow_dec = vec![0f32; k];
    for i in 0..m {
        for (d, &h) in arow_dec.iter_mut().zip(&a[i * k..(i + 1) * k]) {
            *d = h.to_f32();
        }
        for j in 0..n {
            c[i * n + j] = dot_f32(&arow_dec, &bdec[j * k..(j + 1) * k]);
        }
    }
}

// ---------------------------------------------------------------------------
// i8 × i8 → i32  (Q̂·K̂ᵀ, eq. 4)

/// Integer similarity GEMM with INT32 accumulation. `bt` is K̂ (already the
/// transposed operand: row j of `bt` is key j).
pub fn gemm_i8(a: &MatI8, bt: &MatI8, c: &mut MatI32) {
    let (m, k) = (a.rows(), a.cols());
    let n = bt.rows();
    assert_eq!(bt.cols(), k, "inner dims");
    assert_eq!((c.rows(), c.cols()), (m, n), "output shape");
    gemm_i8_rows(a.as_slice(), bt.as_slice(), c.as_mut_slice(), m, n, k, 0, m);
}

/// i8 dot product, i32 accumulate — dispatches to the AVX-512 `vpmaddwd`
/// kernel (the x86 analogue of the NEON SDOT path the paper's ACL kernels
/// use) when available, else a portable multi-accumulator loop.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    #[cfg(target_arch = "x86_64")]
    {
        if has_avx512() {
            // SAFETY: feature presence checked via cpuid (once).
            return unsafe { dot_i8_avx512(a, b) };
        }
    }
    dot_i8_scalar(a, b)
}

/// One-time cpuid probe (std `OnceLock`; the offline cache has no
/// `once_cell`).
#[cfg(target_arch = "x86_64")]
#[inline]
fn has_avx512() -> bool {
    static FLAG: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FLAG.get_or_init(|| is_x86_feature_detected!("avx512bw"))
}

/// AVX-512 i8 dot product: sign-extend 32 i8 lanes to i16, then `vpmaddwd`
/// (32 i16 products pairwise-summed into 16 i32 lanes) with a vector
/// accumulator. ~32 MACs per 3 instructions.
///
/// # Safety
///
/// The CPU must support `avx512bw` — every call site gates on
/// [`has_avx512`]'s cpuid probe.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512bw")]
unsafe fn dot_i8_avx512(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = a.len().min(b.len());
    let chunks = n / 32;
    // SAFETY: avx512bw is available per this fn's contract, and every
    // access stays inside `a[..n]`/`b[..n]` — the vector loads cover
    // `chunks*32 <= n` bytes and the unchecked tail indexes are `< n`.
    unsafe {
        let mut acc = _mm512_setzero_si512();
        for c in 0..chunks {
            let pa = a.as_ptr().add(c * 32) as *const __m256i;
            let pb = b.as_ptr().add(c * 32) as *const __m256i;
            let va = _mm512_cvtepi8_epi16(_mm256_loadu_si256(pa));
            let vb = _mm512_cvtepi8_epi16(_mm256_loadu_si256(pb));
            acc = _mm512_add_epi32(acc, _mm512_madd_epi16(va, vb));
        }
        let mut s = _mm512_reduce_add_epi32(acc);
        for i in chunks * 32..n {
            s += (*a.get_unchecked(i) as i32) * (*b.get_unchecked(i) as i32);
        }
        s
    }
}

/// Portable fallback with explicit accumulator lanes.
#[inline]
pub fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    const LANES: usize = 32;
    let n = a.len().min(b.len());
    let mut acc = [0i32; LANES];
    let a_chunks = a[..n].chunks_exact(LANES);
    let b_chunks = b[..n].chunks_exact(LANES);
    let (a_rem, b_rem) = (a_chunks.remainder(), b_chunks.remainder());
    for (ca, cb) in a_chunks.zip(b_chunks) {
        for l in 0..LANES {
            acc[l] += (ca[l] as i32) * (cb[l] as i32);
        }
    }
    let mut s: i32 = acc.iter().sum();
    for (&x, &y) in a_rem.iter().zip(b_rem) {
        s += (x as i32) * (y as i32);
    }
    s
}

fn gemm_i8_rows(a: &[i8], bt: &[i8], c: &mut [i32], _m: usize, n: usize, k: usize, r0: usize, r1: usize) {
    #[cfg(target_arch = "x86_64")]
    {
        if has_avx512() {
            // SAFETY: feature checked; row ranges in-bounds by construction.
            unsafe { gemm_i8_rows_avx512(a, bt, c, n, k, r0, r1) };
            return;
        }
    }
    for i in r0..r1 {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, out) in crow.iter_mut().enumerate() {
            *out = dot_i8(arow, &bt[j * k..(j + 1) * k]);
        }
    }
}

/// AVX-512 i8 GEMM row kernel with 4-wide N blocking: the A-row tile is
/// sign-extended once and reused across four B rows, amortizing the
/// load+convert overhead that dominates the single-row dot kernel.
///
/// # Safety
///
/// The CPU must support `avx512bw` (call sites gate on [`has_avx512`]),
/// and the operands must satisfy the row-kernel shape contract:
/// `a` holds at least `r1` rows of `k`, `bt` holds `n` rows of `k`, and
/// `c` holds at least `r1` rows of `n`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512bw")]
unsafe fn gemm_i8_rows_avx512(
    a: &[i8],
    bt: &[i8],
    c: &mut [i32],
    n: usize,
    k: usize,
    r0: usize,
    r1: usize,
) {
    use std::arch::x86_64::*;
    let chunks = k / 32;
    // SAFETY: avx512bw is available per this fn's contract; the shape
    // contract keeps every A pointer inside row i (i < r1), every B
    // pointer inside rows j..j+4 (j+4 <= n), the vector loads within
    // `chunks*32 <= k` of each row start, the scalar tail within `k`, and
    // the `from_raw_parts` views are full in-bounds rows of live slices.
    unsafe {
        for i in r0..r1 {
            let arow = a.as_ptr().add(i * k);
            let crow = &mut c[i * n..(i + 1) * n];
            let mut j = 0;
            while j + 4 <= n {
                let b0 = bt.as_ptr().add(j * k);
                let b1 = bt.as_ptr().add((j + 1) * k);
                let b2 = bt.as_ptr().add((j + 2) * k);
                let b3 = bt.as_ptr().add((j + 3) * k);
                let mut acc0 = _mm512_setzero_si512();
                let mut acc1 = _mm512_setzero_si512();
                let mut acc2 = _mm512_setzero_si512();
                let mut acc3 = _mm512_setzero_si512();
                for ch in 0..chunks {
                    let off = ch * 32;
                    let va =
                        _mm512_cvtepi8_epi16(_mm256_loadu_si256(arow.add(off) as *const __m256i));
                    let v0 =
                        _mm512_cvtepi8_epi16(_mm256_loadu_si256(b0.add(off) as *const __m256i));
                    let v1 =
                        _mm512_cvtepi8_epi16(_mm256_loadu_si256(b1.add(off) as *const __m256i));
                    let v2 =
                        _mm512_cvtepi8_epi16(_mm256_loadu_si256(b2.add(off) as *const __m256i));
                    let v3 =
                        _mm512_cvtepi8_epi16(_mm256_loadu_si256(b3.add(off) as *const __m256i));
                    acc0 = _mm512_add_epi32(acc0, _mm512_madd_epi16(va, v0));
                    acc1 = _mm512_add_epi32(acc1, _mm512_madd_epi16(va, v1));
                    acc2 = _mm512_add_epi32(acc2, _mm512_madd_epi16(va, v2));
                    acc3 = _mm512_add_epi32(acc3, _mm512_madd_epi16(va, v3));
                }
                let mut s0 = _mm512_reduce_add_epi32(acc0);
                let mut s1 = _mm512_reduce_add_epi32(acc1);
                let mut s2 = _mm512_reduce_add_epi32(acc2);
                let mut s3 = _mm512_reduce_add_epi32(acc3);
                for idx in chunks * 32..k {
                    let av = *arow.add(idx) as i32;
                    s0 += av * (*b0.add(idx) as i32);
                    s1 += av * (*b1.add(idx) as i32);
                    s2 += av * (*b2.add(idx) as i32);
                    s3 += av * (*b3.add(idx) as i32);
                }
                crow[j] = s0;
                crow[j + 1] = s1;
                crow[j + 2] = s2;
                crow[j + 3] = s3;
                j += 4;
            }
            while j < n {
                crow[j] = dot_i8(
                    std::slice::from_raw_parts(arow, k),
                    std::slice::from_raw_parts(bt.as_ptr().add(j * k), k),
                );
                j += 1;
            }
        }
    }
}

/// Pool-parallel i8 GEMM.
pub fn par_gemm_i8(a: &MatI8, bt: &MatI8, c: &mut MatI32, pool: &ParallelPool) {
    let (m, k) = (a.rows(), a.cols());
    let n = bt.rows();
    assert_eq!(bt.cols(), k);
    assert_eq!((c.rows(), c.cols()), (m, n));
    let work = m * n * k;
    if pool.workers_for(work) <= 1 {
        return gemm_i8(a, bt, c);
    }
    let c_ptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    let (a_s, b_s) = (a.as_slice(), bt.as_slice());
    pool.parallel_for(m, work, |r0, r1| {
        // SAFETY: the full-C view is written only on rows [r0, r1), claimed
        // by exactly one worker; C outlives the blocking launch call.
        let c_full = unsafe { std::slice::from_raw_parts_mut(c_ptr.get(), m * n) };
        gemm_i8_rows(a_s, b_s, c_full, m, n, k, r0, r1);
    });
}

/// Slice-based i8 GEMM (`bt` row-major `N×K`, i.e. keys-as-rows): the
/// stateful attention path's `Q̂·K̂ᵀ` against the resident INT8 K state.
pub fn gemm_i8_slices(a: &[i8], bt: &[i8], c: &mut [i32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(bt.len(), n * k, "Bᵀ shape");
    assert_eq!(c.len(), m * n, "C shape");
    gemm_i8_rows(a, bt, c, m, n, k, 0, m);
}

/// Pool-parallel [`gemm_i8_slices`].
pub fn par_gemm_i8_slices(
    a: &[i8],
    bt: &[i8],
    c: &mut [i32],
    m: usize,
    n: usize,
    k: usize,
    pool: &ParallelPool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(c.len(), m * n);
    let work = m * n * k;
    if pool.workers_for(work) <= 1 {
        return gemm_i8_slices(a, bt, c, m, n, k);
    }
    let c_ptr = SendPtr(c.as_mut_ptr());
    pool.parallel_for(m, work, |r0, r1| {
        // SAFETY: the full-C view is written only on rows [r0, r1), claimed
        // by exactly one worker; C outlives the blocking launch call.
        let c_full = unsafe { std::slice::from_raw_parts_mut(c_ptr.get(), m * n) };
        gemm_i8_rows(a, bt, c_full, m, n, k, r0, r1);
    });
}

// ---------------------------------------------------------------------------
// u8 × i8 → i32  (P̂·V̂, §3.2)

/// Aggregation GEMM: UINT8 probabilities times INT8 values with INT32
/// accumulation. Here `v` is `L×d` row-major and is **not** transposed:
/// `C[i,c] = Σ_j P̂[i,j] · V̂[j,c]`. The inner loop runs over the V row —
/// contiguous — accumulating into a d-wide register panel (classic
//  row-times-matrix SAXPY layout, ideal when d ≤ a few hundred).
pub fn gemm_u8i8(p: &MatU8, v: &MatI8, c: &mut MatI32) {
    let (m, l) = (p.rows(), p.cols());
    let d = v.cols();
    assert_eq!(v.rows(), l, "inner dims");
    assert_eq!((c.rows(), c.cols()), (m, d), "output shape");
    gemm_u8i8_rows(p.as_slice(), v.as_slice(), c.as_mut_slice(), l, d, 0, m);
}

fn gemm_u8i8_rows(p: &[u8], v: &[i8], c: &mut [i32], l: usize, d: usize, r0: usize, r1: usize) {
    for i in r0..r1 {
        let prow = &p[i * l..(i + 1) * l];
        let crow = &mut c[i * d..(i + 1) * d];
        crow.fill(0);
        for (j, &pij) in prow.iter().enumerate() {
            if pij == 0 {
                // IndexSoftmax clips most of the row to the LUT's zero entry;
                // skipping zero rows is the sparsity the paper exploits (§3.1).
                continue;
            }
            let pv = pij as i32;
            let vrow = &v[j * d..(j + 1) * d];
            for (acc, &vx) in crow.iter_mut().zip(vrow) {
                *acc += pv * (vx as i32);
            }
        }
    }
}

/// Pool-parallel u8×i8 GEMM.
pub fn par_gemm_u8i8(p: &MatU8, v: &MatI8, c: &mut MatI32, pool: &ParallelPool) {
    let (m, l) = (p.rows(), p.cols());
    let d = v.cols();
    assert_eq!(v.rows(), l);
    assert_eq!((c.rows(), c.cols()), (m, d));
    let work = m * l * d;
    if pool.workers_for(work) <= 1 {
        return gemm_u8i8(p, v, c);
    }
    let c_ptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    let (p_s, v_s) = (p.as_slice(), v.as_slice());
    pool.parallel_for(m, work, |r0, r1| {
        // SAFETY: the full-C view is written only on rows [r0, r1), claimed
        // by exactly one worker; C outlives the blocking launch call.
        let c_full = unsafe { std::slice::from_raw_parts_mut(c_ptr.get(), m * d) };
        gemm_u8i8_rows(p_s, v_s, c_full, l, d, r0, r1);
    });
}

/// i8 × i8 → i32 with V not transposed (same SAXPY layout as [`gemm_u8i8`]);
/// used by the Quant-Only pipeline whose requantized P is signed INT8.
pub fn gemm_i8_notrans(p: &MatI8, v: &MatI8, c: &mut MatI32) {
    let (m, l) = (p.rows(), p.cols());
    let d = v.cols();
    assert_eq!(v.rows(), l, "inner dims");
    assert_eq!((c.rows(), c.cols()), (m, d), "output shape");
    let p_s = p.as_slice();
    let v_s = v.as_slice();
    let c_s = c.as_mut_slice();
    for i in 0..m {
        let prow = &p_s[i * l..(i + 1) * l];
        let crow = &mut c_s[i * d..(i + 1) * d];
        crow.fill(0);
        for (j, &pij) in prow.iter().enumerate() {
            if pij == 0 {
                continue;
            }
            let pv = pij as i32;
            let vrow = &v_s[j * d..(j + 1) * d];
            for (acc, &vx) in crow.iter_mut().zip(vrow) {
                *acc += pv * (vx as i32);
            }
        }
    }
}

/// Slice-based [`gemm_u8i8`] for the stateful path (`V̂` is the resident
/// INT8 state, `L×d` row-major, never copied or transposed).
pub fn gemm_u8i8_slices(p: &[u8], v: &[i8], c: &mut [i32], m: usize, l: usize, d: usize) {
    assert_eq!(p.len(), m * l, "P shape");
    assert_eq!(v.len(), l * d, "V shape");
    assert_eq!(c.len(), m * d, "C shape");
    gemm_u8i8_rows(p, v, c, l, d, 0, m);
}

/// Slice-based [`gemm_i8_notrans`] (Quant-Only's signed-P aggregation over
/// the resident INT8 state).
pub fn gemm_i8_notrans_slices(p: &[i8], v: &[i8], c: &mut [i32], m: usize, l: usize, d: usize) {
    assert_eq!(p.len(), m * l, "P shape");
    assert_eq!(v.len(), l * d, "V shape");
    assert_eq!(c.len(), m * d, "C shape");
    for i in 0..m {
        let prow = &p[i * l..(i + 1) * l];
        let crow = &mut c[i * d..(i + 1) * d];
        crow.fill(0);
        for (j, &pij) in prow.iter().enumerate() {
            if pij == 0 {
                continue;
            }
            let pv = pij as i32;
            let vrow = &v[j * d..(j + 1) * d];
            for (acc, &vx) in crow.iter_mut().zip(vrow) {
                *acc += pv * (vx as i32);
            }
        }
    }
}

/// `C = P·V` with both operands in f16 storage and V in natural `L×d` row
/// layout — the incremental-decode companion of [`gemm_f16`] (which wants
/// Bᵀ). Decodes V rows on the fly and accumulates in f32; skips exact-zero
/// probabilities (masked-out or underflowed entries).
pub fn gemm_f16_notrans(p: &[F16], v: &[F16], c: &mut [f32], m: usize, l: usize, d: usize) {
    assert_eq!(p.len(), m * l, "P shape");
    assert_eq!(v.len(), l * d, "V shape");
    assert_eq!(c.len(), m * d, "C shape");
    for i in 0..m {
        let prow = &p[i * l..(i + 1) * l];
        let crow = &mut c[i * d..(i + 1) * d];
        crow.fill(0.0);
        for (j, &pij) in prow.iter().enumerate() {
            let pf = pij.to_f32();
            if pf == 0.0 {
                continue;
            }
            let vrow = &v[j * d..(j + 1) * d];
            for (acc, &vx) in crow.iter_mut().zip(vrow) {
                *acc += pf * vx.to_f32();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Paged kernels — resident operand as a page list (block table)

/// Total rows across a page list whose rows are `width` elements wide.
/// Every page must hold whole rows (the [`crate::attention::state::PagedRows`]
/// contract: rows never span pages).
fn paged_rows<T>(pages: &[&[T]], width: usize) -> usize {
    debug_assert!(pages.iter().all(|p| p.len() % width == 0), "partial row in page");
    pages.iter().map(|p| p.len() / width).sum()
}

fn gemm_i8_paged_rows(
    a: &[i8],
    kp: &[&[i8]],
    c: &mut [i32],
    n: usize,
    k: usize,
    r0: usize,
    r1: usize,
) {
    // AUDIT: int-only begin gemm-i8-paged
    for i in r0..r1 {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut off = 0;
        for page in kp {
            let np = page.len() / k;
            // A page is a contiguous np×k block: the blocked (AVX-512 where
            // available) row kernel applies to it unchanged.
            gemm_i8_rows(arow, page, &mut crow[off..off + np], 1, np, k, 0, 1);
            off += np;
        }
    }
    // AUDIT: int-only end
}

/// `Q̂·K̂ᵀ` against paged resident keys: `kp` is the page list (each page
/// `rows×k` keys-as-rows). Byte-equal to [`gemm_i8_slices`] over the
/// concatenated pages (integer dot products are exact and per-row).
pub fn gemm_i8_paged(a: &[i8], kp: &[&[i8]], c: &mut [i32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(paged_rows(kp, k), n, "K̂ page rows");
    assert_eq!(c.len(), m * n, "C shape");
    gemm_i8_paged_rows(a, kp, c, n, k, 0, m);
}

/// Pool-parallel [`gemm_i8_paged`]: output (query) rows split across
/// workers; every worker walks the shared read-only page list.
pub fn par_gemm_i8_paged(
    a: &[i8],
    kp: &[&[i8]],
    c: &mut [i32],
    m: usize,
    n: usize,
    k: usize,
    pool: &ParallelPool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(paged_rows(kp, k), n, "K̂ page rows");
    assert_eq!(c.len(), m * n);
    let work = m * n * k;
    if pool.workers_for(work) <= 1 {
        return gemm_i8_paged_rows(a, kp, c, n, k, 0, m);
    }
    let c_ptr = SendPtr(c.as_mut_ptr());
    pool.parallel_for(m, work, |r0, r1| {
        // SAFETY: the full-C view is written only on rows [r0, r1), claimed
        // by exactly one worker; C outlives the blocking launch call.
        let c_full = unsafe { std::slice::from_raw_parts_mut(c_ptr.get(), m * n) };
        gemm_i8_paged_rows(a, kp, c_full, n, k, r0, r1);
    });
}

fn gemm_f32_paged_rows(
    a: &[f32],
    kp: &[&[f32]],
    c: &mut [f32],
    n: usize,
    k: usize,
    r0: usize,
    r1: usize,
) {
    for i in r0..r1 {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut off = 0;
        for page in kp {
            let np = page.len() / k;
            for (j, out) in crow[off..off + np].iter_mut().enumerate() {
                *out = dot_f32(arow, &page[j * k..(j + 1) * k]);
            }
            off += np;
        }
    }
}

/// `Q·Kᵀ` against paged resident keys; byte-equal to [`gemm_f32_slices`]
/// over the concatenated pages (same [`dot_f32`] per output element).
pub fn gemm_f32_paged(a: &[f32], kp: &[&[f32]], c: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(paged_rows(kp, k), n, "K page rows");
    assert_eq!(c.len(), m * n, "C shape");
    gemm_f32_paged_rows(a, kp, c, n, k, 0, m);
}

/// Pool-parallel [`gemm_f32_paged`].
pub fn par_gemm_f32_paged(
    a: &[f32],
    kp: &[&[f32]],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    pool: &ParallelPool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(paged_rows(kp, k), n, "K page rows");
    assert_eq!(c.len(), m * n);
    let work = m * n * k;
    if pool.workers_for(work) <= 1 {
        return gemm_f32_paged_rows(a, kp, c, n, k, 0, m);
    }
    let c_ptr = SendPtr(c.as_mut_ptr());
    pool.parallel_for(m, work, |r0, r1| {
        // SAFETY: the full-C view is written only on rows [r0, r1), claimed
        // by exactly one worker; C outlives the blocking launch call.
        let c_full = unsafe { std::slice::from_raw_parts_mut(c_ptr.get(), m * n) };
        gemm_f32_paged_rows(a, kp, c_full, n, k, r0, r1);
    });
}

/// FP16-storage `Q·Kᵀ` against paged resident keys. Decodes A once and each
/// K page once per call (amortized across all M query rows, like
/// [`gemm_f16`]'s whole-operand decode); the per-element decode and the
/// per-row [`dot_f32`] are identical to the contiguous path, so the output
/// is byte-equal to [`gemm_f16`] over the concatenated pages.
pub fn gemm_f16_paged(a: &[F16], kp: &[&[F16]], m: usize, n: usize, k: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(paged_rows(kp, k), n, "K page rows");
    assert_eq!(c.len(), m * n, "C shape");
    let mut adec = vec![0f32; m * k];
    for (d, &h) in adec.iter_mut().zip(a) {
        *d = h.to_f32();
    }
    let max_page = kp.iter().map(|p| p.len()).max().unwrap_or(0);
    let mut bdec = vec![0f32; max_page];
    let mut off = 0;
    for page in kp {
        let np = page.len() / k;
        for (d, &h) in bdec[..page.len()].iter_mut().zip(*page) {
            *d = h.to_f32();
        }
        for i in 0..m {
            let arow = &adec[i * k..(i + 1) * k];
            let crow = &mut c[i * n + off..i * n + off + np];
            for (j, out) in crow.iter_mut().enumerate() {
                *out = dot_f32(arow, &bdec[j * k..(j + 1) * k]);
            }
        }
        off += np;
    }
}

/// `P̂·V̂` aggregation over paged resident values (`vp` pages of `rows×d`
/// value rows, natural layout). Zero-skipping like [`gemm_u8i8_slices`] and
/// byte-equal to it over the concatenated pages: the ascending-`j`
/// accumulation order is preserved across page boundaries.
pub fn gemm_u8i8_paged(p: &[u8], vp: &[&[i8]], c: &mut [i32], m: usize, l: usize, d: usize) {
    // AUDIT: int-only begin gemm-u8i8-paged
    assert_eq!(p.len(), m * l, "P shape");
    assert_eq!(paged_rows(vp, d), l, "V̂ page rows");
    assert_eq!(c.len(), m * d, "C shape");
    for i in 0..m {
        let prow = &p[i * l..(i + 1) * l];
        let crow = &mut c[i * d..(i + 1) * d];
        crow.fill(0);
        let mut j = 0;
        for page in vp {
            for vrow in page.chunks_exact(d) {
                let pij = prow[j];
                j += 1;
                if pij == 0 {
                    continue;
                }
                let pv = pij as i32;
                for (acc, &vx) in crow.iter_mut().zip(vrow) {
                    *acc += pv * (vx as i32);
                }
            }
        }
    }
    // AUDIT: int-only end
}

/// Signed-P̂ aggregation over paged resident values (Quant-Only's PV side);
/// byte-equal to [`gemm_i8_notrans_slices`] over the concatenated pages.
pub fn gemm_i8_notrans_paged(p: &[i8], vp: &[&[i8]], c: &mut [i32], m: usize, l: usize, d: usize) {
    // AUDIT: int-only begin gemm-i8-notrans-paged
    assert_eq!(p.len(), m * l, "P shape");
    assert_eq!(paged_rows(vp, d), l, "V̂ page rows");
    assert_eq!(c.len(), m * d, "C shape");
    for i in 0..m {
        let prow = &p[i * l..(i + 1) * l];
        let crow = &mut c[i * d..(i + 1) * d];
        crow.fill(0);
        let mut j = 0;
        for page in vp {
            for vrow in page.chunks_exact(d) {
                let pij = prow[j];
                j += 1;
                if pij == 0 {
                    continue;
                }
                let pv = pij as i32;
                for (acc, &vx) in crow.iter_mut().zip(vrow) {
                    *acc += pv * (vx as i32);
                }
            }
        }
    }
    // AUDIT: int-only end
}

/// `P·V` over paged resident f32 values (natural layout, zero-skipping);
/// byte-equal to [`gemm_f32_notrans_slices`] over the concatenated pages
/// (same accumulation order).
pub fn gemm_f32_notrans_paged(
    p: &[f32],
    vp: &[&[f32]],
    c: &mut [f32],
    m: usize,
    l: usize,
    d: usize,
) {
    assert_eq!(p.len(), m * l, "P shape");
    assert_eq!(paged_rows(vp, d), l, "V page rows");
    assert_eq!(c.len(), m * d, "C shape");
    for i in 0..m {
        let prow = &p[i * l..(i + 1) * l];
        let crow = &mut c[i * d..(i + 1) * d];
        crow.fill(0.0);
        let mut j = 0;
        for page in vp {
            for vrow in page.chunks_exact(d) {
                let pij = prow[j];
                j += 1;
                if pij == 0.0 {
                    continue;
                }
                for (acc, &vx) in crow.iter_mut().zip(vrow) {
                    *acc += pij * vx;
                }
            }
        }
    }
}

/// `P·V` over paged resident f16 values; byte-equal to
/// [`gemm_f16_notrans`] over the concatenated pages.
pub fn gemm_f16_notrans_paged(
    p: &[F16],
    vp: &[&[F16]],
    c: &mut [f32],
    m: usize,
    l: usize,
    d: usize,
) {
    assert_eq!(p.len(), m * l, "P shape");
    assert_eq!(paged_rows(vp, d), l, "V page rows");
    assert_eq!(c.len(), m * d, "C shape");
    for i in 0..m {
        let prow = &p[i * l..(i + 1) * l];
        let crow = &mut c[i * d..(i + 1) * d];
        crow.fill(0.0);
        let mut j = 0;
        for page in vp {
            for vrow in page.chunks_exact(d) {
                let pf = prow[j].to_f32();
                j += 1;
                if pf == 0.0 {
                    continue;
                }
                for (acc, &vx) in crow.iter_mut().zip(vrow) {
                    *acc += pf * vx.to_f32();
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Grouped (batched multi-sequence decode) kernels

/// One sequence's slice of a grouped decode GEMM round: its 1-row left
/// operand (query row on the QK side, probability row on the PV side), its
/// **page-segmented** resident KV operand, and its output row. The per-group
/// context length is implied by the slice lengths (`out.len()` keys on the
/// QK side, `a.len()` positions on the PV side), so a ragged batch needs no
/// padding.
pub struct GemmGroup<'a, A, B, C> {
    /// 1-row left operand.
    pub a: &'a [A],
    /// Resident right operand as a page list (each page a contiguous run of
    /// whole rows: `rows×k` keys-as-rows for QK, `rows×d` value rows for PV
    /// — never copied, never transposed, never flattened).
    pub b: &'a [&'a [B]],
    /// Output row (`n` logits for QK, `d` accumulators for PV).
    pub out: &'a mut [C],
}

/// INT8 group (`Q̂·K̂ᵀ` similarity, or Quant-Only's signed-P̂ aggregation).
pub type GroupI8<'a> = GemmGroup<'a, i8, i8, i32>;
/// UINT8-probability aggregation group (`P̂·V̂`, IntAttention/EXAQ).
pub type GroupU8I8<'a> = GemmGroup<'a, u8, i8, i32>;
/// f32 group (FP32 baseline pipeline).
pub type GroupF32<'a> = GemmGroup<'a, f32, f32, f32>;
/// f16-storage group (FP16 baseline pipeline).
pub type GroupF16<'a> = GemmGroup<'a, F16, F16, f32>;

/// Total resident-operand elements across a grouped launch (summed over
/// every group's pages) — proportional to its MAC count on both the QK
/// (`n·k` keys) and PV (`l·d` values) sides. This is the work estimate the
/// pool's grain policy sees; whether (and how wide) the launch parallelizes
/// is decided by [`ParallelPool::workers_for`] — one env-tunable threshold
/// instead of the old per-dtype `PAR_GRAIN_*` constants.
fn grouped_work<A, B, C>(groups: &[GemmGroup<A, B, C>]) -> usize {
    groups
        .iter()
        .map(|g| g.b.iter().map(|p| p.len()).sum::<usize>())
        .sum()
}

#[inline]
fn gemm_i8_group(g: &mut GroupI8, k: usize) {
    let n = g.out.len();
    assert_eq!(g.a.len(), k, "query row length");
    gemm_i8_paged(g.a, g.b, g.out, 1, n, k);
}

/// Grouped `Q̂·K̂ᵀ` for batched decode: each group is one sequence's
/// `1×L_b` row-times-keys product over its own resident `L_b×k` K̂ buffer.
pub fn gemm_i8_grouped(groups: &mut [GroupI8], k: usize) {
    for g in groups.iter_mut() {
        gemm_i8_group(g, k);
    }
}

/// Pool-parallel [`gemm_i8_grouped`]: workers claim groups dynamically (a
/// single decode row cannot be split; a batch of sequences can).
pub fn par_gemm_i8_grouped(groups: &mut [GroupI8], k: usize, pool: &ParallelPool) {
    let work = grouped_work(groups);
    pool.parallel_groups(groups, work, |g| gemm_i8_group(g, k));
}

#[inline]
fn gemm_u8i8_group(g: &mut GroupU8I8, d: usize) {
    let l = g.a.len();
    assert_eq!(g.out.len(), d, "output row length");
    gemm_u8i8_paged(g.a, g.b, g.out, 1, l, d);
}

/// Grouped `P̂·V̂` for batched decode: each group aggregates one sequence's
/// UINT8 probability row over its own resident `L_b×d` V̂ buffer
/// (zero-skipping, like [`gemm_u8i8`]).
pub fn gemm_u8i8_grouped(groups: &mut [GroupU8I8], d: usize) {
    for g in groups.iter_mut() {
        gemm_u8i8_group(g, d);
    }
}

/// Pool-parallel [`gemm_u8i8_grouped`].
pub fn par_gemm_u8i8_grouped(groups: &mut [GroupU8I8], d: usize, pool: &ParallelPool) {
    let work = grouped_work(groups);
    pool.parallel_groups(groups, work, |g| gemm_u8i8_group(g, d));
}

#[inline]
fn gemm_i8_notrans_group(g: &mut GroupI8, d: usize) {
    let l = g.a.len();
    assert_eq!(g.out.len(), d, "output row length");
    gemm_i8_notrans_paged(g.a, g.b, g.out, 1, l, d);
}

/// Grouped signed-P̂ aggregation (Quant-Only's batched PV side).
pub fn gemm_i8_notrans_grouped(groups: &mut [GroupI8], d: usize) {
    for g in groups.iter_mut() {
        gemm_i8_notrans_group(g, d);
    }
}

/// Pool-parallel [`gemm_i8_notrans_grouped`].
pub fn par_gemm_i8_notrans_grouped(groups: &mut [GroupI8], d: usize, pool: &ParallelPool) {
    let work = grouped_work(groups);
    pool.parallel_groups(groups, work, |g| gemm_i8_notrans_group(g, d));
}

/// Grouped f32 `Q·Kᵀ` (per-group `1×L_b` against paged resident keys);
/// bit-exact with per-group [`gemm_f32_paged`] calls — the grouping only
/// moves work between workers, never within a dot product.
pub fn par_gemm_f32_grouped(groups: &mut [GroupF32], k: usize, pool: &ParallelPool) {
    let work = grouped_work(groups);
    pool.parallel_groups(groups, work, |g| {
        let n = g.out.len();
        assert_eq!(g.a.len(), k, "query row length");
        gemm_f32_paged(g.a, g.b, g.out, 1, n, k);
    });
}

/// Grouped f32 `P·V` with V in natural row layout (zero-skipping, like
/// [`gemm_f32_notrans_paged`]).
pub fn par_gemm_f32_notrans_grouped(groups: &mut [GroupF32], d: usize, pool: &ParallelPool) {
    let work = grouped_work(groups);
    pool.parallel_groups(groups, work, |g| {
        let l = g.a.len();
        assert_eq!(g.out.len(), d, "output row length");
        gemm_f32_notrans_paged(g.a, g.b, g.out, 1, l, d);
    });
}

/// Grouped f16-storage `Q·Kᵀ`: per group, exactly one [`gemm_f16_paged`]
/// call (same decode-then-dot dataflow as the sequential path).
pub fn par_gemm_f16_grouped(groups: &mut [GroupF16], k: usize, pool: &ParallelPool) {
    let work = grouped_work(groups);
    pool.parallel_groups(groups, work, |g| {
        let n = g.out.len();
        assert_eq!(g.a.len(), k, "query row length");
        gemm_f16_paged(g.a, g.b, 1, n, k, g.out);
    });
}

/// Grouped f16-storage `P·V` with V in natural row layout.
pub fn par_gemm_f16_notrans_grouped(groups: &mut [GroupF16], d: usize, pool: &ParallelPool) {
    let work = grouped_work(groups);
    pool.parallel_groups(groups, work, |g| {
        let l = g.a.len();
        assert_eq!(g.out.len(), d, "output row length");
        gemm_f16_notrans_paged(g.a, g.b, g.out, 1, l, d);
    });
}

// ---------------------------------------------------------------------------
// Fused flash-decode kernels (one KV page-walk per head)

/// Phase 1 of the fused integer flash-decode walk: stream every K̂ page's
/// `1×rows` `Q̂K̂ᵀ` tile (the same blocked — AVX-512 where available — row
/// kernel the paged QK path uses) through the [`OnlineIndexRow`] max fold.
/// Touches no V̂ data and no accumulator; after this pass `row` holds the
/// span's logit max (and nothing else — `ΣÊ`/nnz stay zero).
pub fn fused_decode_i8_max(q: &[i8], kp: &[&[i8]], row: &mut OnlineIndexRow, tile: &mut [i32]) {
    // AUDIT: int-only begin gemm-fused-decode-i8
    let k = q.len();
    for kpage in kp {
        let np = kpage.len() / k;
        let t = &mut tile[..np];
        gemm_i8_rows(q, kpage, t, 1, np, k, 0, 1);
        for &a in t.iter() {
            row.observe_max(a);
        }
    }
    // AUDIT: int-only end
}

/// Phase 2 of the fused integer flash-decode walk: with the row max pinned
/// (by [`fused_decode_i8_max`] plus any [`OnlineIndexRow::merge_max`]
/// folds), re-walk the K̂ pages, gather each logit's `Ê` weight and land
/// `Ê·V̂_row` directly on the `d`-lane i64 accumulator. K̂ and V̂ pages pair
/// up row-for-row (same [`crate::attention::state`] paging on both sides),
/// so one zipped walk covers the span — the working set is the accumulator
/// (O(d)) plus one page-sized logit tile (O(page_rows)); no L-length score
/// row exists at any point.
///
/// Because the max never moves inside this phase, `ΣÊ` and every
/// accumulator lane are plain integer sums — associative, so partial
/// states over disjoint page spans merge byte-identically
/// ([`OnlineIndexRow::merge`]) in any order. Final normalization
/// (`round(255·acc/ΣÊ)` via [`OnlineIndexRow::norm_div`]) is the caller's
/// job; `row` carries `ΣÊ` and the nnz op accounting out of the walk.
pub fn fused_decode_i8_gather(
    q: &[i8],
    kp: &[&[i8]],
    vp: &[&[i8]],
    row: &mut OnlineIndexRow,
    table: &[u8],
    acc: &mut [i64],
    tile: &mut [i32],
) {
    // AUDIT: int-only begin gemm-fused-decode-i8
    let k = q.len();
    let d = acc.len();
    debug_assert_eq!(paged_rows(kp, k), paged_rows(vp, d), "K̂/V̂ row counts");
    acc.fill(0);
    for (kpage, vpage) in kp.iter().zip(vp) {
        let np = kpage.len() / k;
        debug_assert_eq!(vpage.len() / d, np, "K̂/V̂ pages pair row-for-row");
        let t = &mut tile[..np];
        gemm_i8_rows(q, kpage, t, 1, np, k, 0, 1);
        for (j, &a) in t.iter().enumerate() {
            let e = row.gather(a, table);
            if e != 0 {
                let w = e as i64;
                for (x, &vx) in acc.iter_mut().zip(&vpage[j * d..(j + 1) * d]) {
                    *x += w * (vx as i64);
                }
            }
        }
    }
    // AUDIT: int-only end
}

/// One sequence's (or span's) complete fused integer flash-decode walk:
/// [`fused_decode_i8_max`] then [`fused_decode_i8_gather`]. The K̂ tiles are
/// computed twice — the classic flash recompute trade, paid to make every
/// partial quantity an associative integer sum (so the page-parallel span
/// drivers are byte-identical to this sequential walk at any split width,
/// including width 1: this *is* the width-1 case).
pub fn fused_decode_i8(
    q: &[i8],
    kp: &[&[i8]],
    vp: &[&[i8]],
    row: &mut OnlineIndexRow,
    table: &[u8],
    acc: &mut [i64],
    tile: &mut [i32],
) {
    fused_decode_i8_max(q, kp, row, tile);
    fused_decode_i8_gather(q, kp, vp, row, table, acc, tile);
}

/// Phase 1 of EXAQ's fused flash-decode walk: the [`fused_decode_i8_max`]
/// max fold over EXAQ's [`ExaqOnlineRow`].
pub fn fused_decode_exaq_max(q: &[i8], kp: &[&[i8]], row: &mut ExaqOnlineRow, tile: &mut [i32]) {
    // AUDIT: int-only begin gemm-fused-decode-exaq
    let k = q.len();
    for kpage in kp {
        let np = kpage.len() / k;
        let t = &mut tile[..np];
        gemm_i8_rows(q, kpage, t, 1, np, k, 0, 1);
        for &a in t.iter() {
            row.observe_max(a);
        }
    }
    // AUDIT: int-only end
}

/// Phase 2 of EXAQ's fused flash-decode walk: with the row max pinned,
/// re-walk the zipped K̂/V̂ pages, bucket each logit by its LUT index
/// ([`ExaqOnlineRow::gather`] — which also rides the exact integer
/// Δ-moments for the dynamic-clip statistics) and add the V̂ row onto that
/// bucket's `d` lanes of the `entries×d` i64 accumulator. The float LUT
/// weights are applied **once per bucket** by the caller's final combine
/// (`Σ_t LUT[t]·acc[t]`), not per element — so the walk itself is pure
/// integer arithmetic and partial states over disjoint page spans merge
/// byte-identically (bucket counts, moments and lane sums all add).
pub fn fused_decode_exaq_gather(
    q: &[i8],
    kp: &[&[i8]],
    vp: &[&[i8]],
    row: &mut ExaqOnlineRow,
    acc: &mut [i64],
    tile: &mut [i32],
) {
    // AUDIT: int-only begin gemm-fused-decode-exaq
    let k = q.len();
    let zb = row.zero_bucket();
    let d = acc.len() / (zb + 1);
    debug_assert_eq!(paged_rows(kp, k), paged_rows(vp, d), "K̂/V̂ row counts");
    acc.fill(0);
    for (kpage, vpage) in kp.iter().zip(vp) {
        let np = kpage.len() / k;
        debug_assert_eq!(vpage.len() / d, np, "K̂/V̂ pages pair row-for-row");
        let t = &mut tile[..np];
        gemm_i8_rows(q, kpage, t, 1, np, k, 0, 1);
        for (j, &a) in t.iter().enumerate() {
            let b = row.gather(a);
            // The zero bucket's LUT weight is exactly 0 — skip the lanes
            // (the gather already counted it for the Δ-moments).
            if b != zb {
                let lanes = &mut acc[b * d..(b + 1) * d];
                for (x, &vx) in lanes.iter_mut().zip(&vpage[j * d..(j + 1) * d]) {
                    *x += vx as i64;
                }
            }
        }
    }
    // AUDIT: int-only end
}

/// One span's complete fused EXAQ decode walk: max phase then bucketed
/// gather phase (see [`fused_decode_i8`] for the recompute trade).
pub fn fused_decode_exaq(
    q: &[i8],
    kp: &[&[i8]],
    vp: &[&[i8]],
    row: &mut ExaqOnlineRow,
    acc: &mut [i64],
    tile: &mut [i32],
) {
    fused_decode_exaq_max(q, kp, row, tile);
    fused_decode_exaq_gather(q, kp, vp, row, acc, tile);
}

/// One page **span** of one sequence's fused flash-decode walk
/// (IndexSoftmax pipelines): the sequence's query row, the span's zipped
/// K̂/V̂ page sub-lists, its streaming softmax state (carried by value —
/// read the `ΣÊ`/nnz accounting back out after the launch), and its
/// disjoint accumulator + page-tile scratch. `OnlineIndexRow` bakes in the
/// per-sequence `α` (and thus `c_int`), so grouped-Q batches need no extra
/// per-job fields. An unsplit sequence is the one-span case.
pub struct FusedJobI8<'a> {
    pub q: &'a [i8],
    pub kp: &'a [&'a [i8]],
    pub vp: &'a [&'a [i8]],
    pub row: OnlineIndexRow,
    pub acc: &'a mut [i64],
    pub tile: &'a mut [i32],
}

/// One page span of one sequence's fused EXAQ decode walk. The f32 LUT
/// rides in the job because each sequence's dynamic clip (and therefore its
/// table) differs; `acc` is the bucketed `entries×d` i64 lane accumulator
/// of [`fused_decode_exaq_gather`].
pub struct FusedJobExaq<'a> {
    pub q: &'a [i8],
    pub kp: &'a [&'a [i8]],
    pub vp: &'a [&'a [i8]],
    pub row: ExaqOnlineRow,
    pub lut: &'a [f32],
    pub acc: &'a mut [i64],
    pub tile: &'a mut [i32],
}

/// MAC-proportional work estimate of a fused grouped launch: the K̂ pages
/// are read for the QK tiles and the V̂ pages at most once for the
/// accumulation, so the summed resident elements of both sides bound the
/// walk — the same currency [`grouped_work`] reports for unfused launches.
fn fused_work(kvs: impl Iterator<Item = (usize, usize)>) -> usize {
    kvs.map(|(kb, vb)| kb + vb).sum()
}

/// Span-width policy for the page-parallel fused decode walk: how many page
/// spans one sequence's resident page list splits into. `split == 0` is the
/// auto policy (`INTATTN_DECODE_SPLIT` unset/0): one span per pool worker
/// left over after the batch itself is spread across workers. An explicit
/// width is clamped to the page count (a span must own at least one page).
pub fn decode_split_spans(split: usize, pages: usize, pool_size: usize, batch: usize) -> usize {
    let w = if split == 0 { (pool_size / batch.max(1)).max(1) } else { split };
    w.min(pages).max(1)
}

/// Sequential grouped [`fused_decode_i8`]: one one-span job per sequence.
/// The u8 LUT is shared across the batch (fixed `(b, c)` — that is
/// IndexSoftmax's point). The oracle the span drivers are tested against.
pub fn fused_decode_i8_grouped(jobs: &mut [FusedJobI8], table: &[u8]) {
    for j in jobs.iter_mut() {
        fused_decode_i8(j.q, j.kp, j.vp, &mut j.row, table, j.acc, j.tile);
    }
}

/// Pool-parallel span-scheduled fused integer decode. `jobs` is the flat
/// list of page-span jobs; `spans[s]` says how many consecutive jobs belong
/// to sequence `s` (`Σ spans == jobs.len()`). Sequence results land in the
/// **first** job of each sequence's run: its `row` and `acc` after the call
/// are the fully merged `(max, ΣÊ, accumulator)` of the whole page list.
///
/// All-ones spans (no sequence split) run as a single launch of complete
/// walks — the grouped fast path. Otherwise the walk runs as two launches
/// around two merge points on the launching thread:
///
/// 1. launch A — phase 1 ([`fused_decode_i8_max`]) per span;
/// 2. per sequence: fold the span maxes ([`OnlineIndexRow::merge_max`] —
///    associative max) and rebroadcast the joint state to every span;
/// 3. launch B — phase 2 ([`fused_decode_i8_gather`]) per span;
/// 4. per sequence: merge the partial triples into the first span
///    ([`OnlineIndexRow::merge`]) — pure integer adds at the equal maxes
///    the rebroadcast guarantees.
///
/// Workers claim whole span jobs through the launch's atomic cursor
/// ([`ParallelPool::parallel_groups`]), so worker count and claim order
/// never affect results; neither do the split points (every partial
/// quantity is an associative integer sum), so the output is byte-identical
/// to the sequential walk at every split width.
pub fn par_fused_decode_i8_spans(
    jobs: &mut [FusedJobI8],
    spans: &[usize],
    table: &[u8],
    pool: &ParallelPool,
) {
    debug_assert_eq!(spans.iter().sum::<usize>(), jobs.len(), "span/job mismatch");
    let work = fused_work(jobs.iter().map(|j| {
        (
            j.kp.iter().map(|p| p.len()).sum::<usize>(),
            j.vp.iter().map(|p| p.len()).sum::<usize>(),
        )
    }));
    if spans.iter().all(|&s| s <= 1) {
        pool.parallel_groups(jobs, work, |j| {
            fused_decode_i8(j.q, j.kp, j.vp, &mut j.row, table, j.acc, j.tile)
        });
        return;
    }
    pool.parallel_groups(jobs, work, |j| fused_decode_i8_max(j.q, j.kp, &mut j.row, j.tile));
    let mut at = 0;
    for &s in spans {
        let span = &mut jobs[at..at + s];
        let mut root = span[0].row;
        for j in &span[1..] {
            root.merge_max(&j.row);
        }
        for j in span.iter_mut() {
            j.row = root;
        }
        at += s;
    }
    pool.parallel_groups(jobs, work, |j| {
        fused_decode_i8_gather(j.q, j.kp, j.vp, &mut j.row, table, j.acc, j.tile)
    });
    let mut at = 0;
    for &s in spans {
        let (first, rest) = jobs[at..at + s].split_at_mut(1);
        let f = &mut first[0];
        for j in rest.iter() {
            f.row.merge(&j.row, &mut *f.acc, &*j.acc, table);
        }
        at += s;
    }
}

/// Sequential grouped [`fused_decode_exaq`] — the span drivers' oracle.
pub fn fused_decode_exaq_grouped(jobs: &mut [FusedJobExaq]) {
    for j in jobs.iter_mut() {
        fused_decode_exaq(j.q, j.kp, j.vp, &mut j.row, j.acc, j.tile);
    }
}

/// Pool-parallel span-scheduled fused EXAQ decode — the
/// [`par_fused_decode_i8_spans`] schedule over [`ExaqOnlineRow`] states.
/// The post-gather merge adds bucket counts, Δ-moments and the bucketed
/// accumulator lanes — all integers, so the merged result is byte-identical
/// to the sequential walk at every split width (the equal maxes the
/// rebroadcast guarantees are a hard requirement here: EXAQ buckets cannot
/// be re-binned, and [`ExaqOnlineRow::merge`] asserts it).
pub fn par_fused_decode_exaq_spans(
    jobs: &mut [FusedJobExaq],
    spans: &[usize],
    pool: &ParallelPool,
) {
    debug_assert_eq!(spans.iter().sum::<usize>(), jobs.len(), "span/job mismatch");
    let work = fused_work(jobs.iter().map(|j| {
        (
            j.kp.iter().map(|p| p.len()).sum::<usize>(),
            j.vp.iter().map(|p| p.len()).sum::<usize>(),
        )
    }));
    if spans.iter().all(|&s| s <= 1) {
        pool.parallel_groups(jobs, work, |j| {
            fused_decode_exaq(j.q, j.kp, j.vp, &mut j.row, j.acc, j.tile)
        });
        return;
    }
    pool.parallel_groups(jobs, work, |j| fused_decode_exaq_max(j.q, j.kp, &mut j.row, j.tile));
    let mut at = 0;
    for &s in spans {
        let span = &mut jobs[at..at + s];
        let mut root = span[0].row;
        for j in &span[1..] {
            root.merge_max(&j.row);
        }
        for j in span.iter_mut() {
            j.row = root;
        }
        at += s;
    }
    pool.parallel_groups(jobs, work, |j| {
        fused_decode_exaq_gather(j.q, j.kp, j.vp, &mut j.row, j.acc, j.tile)
    });
    let mut at = 0;
    for &s in spans {
        let (first, rest) = jobs[at..at + s].split_at_mut(1);
        let f = &mut first[0];
        for j in rest.iter() {
            f.row.merge(&j.row);
            for (x, &y) in f.acc.iter_mut().zip(j.acc.iter()) {
                *x += y;
            }
        }
        at += s;
    }
}

// ---------------------------------------------------------------------------
// Online-tiled prefill kernels (flash-style, no m×L score block)

/// Upper bound on the QK tile width of the tiled-prefill walk, in KV rows.
/// Pages larger than this are walked as sub-tiles, so the per-job scratch
/// is O(1) — independent of both the context length *and* the configured
/// page size (`tests/decode_alloc.rs` pins prefill with huge pages).
pub const PREFILL_TILE_ROWS: usize = 256;

/// Query rows per tiled-prefill job: rows are independent (each owns its
/// max/ΣÊ/output), so the drivers parallelize across fixed-size row blocks
/// — partition-invariant by construction.
pub const ROW_BLOCK: usize = 8;

/// Walk the `valid`-row prefix of a K̂ page list as `1×tw` Q̂K̂ᵀ logit tiles
/// (`tw ≤ PREFILL_TILE_ROWS`, also capped by page and prefix bounds),
/// calling `f(page_index, first_row_in_page, tile)` for each. The V̂ rows
/// matching tile column `jj` are `vp[page_index]`'s rows
/// `first_row_in_page + jj` — pages pair row-for-row across the two sides.
fn prefill_qk_tiles(
    qrow: &[i8],
    kp: &[&[i8]],
    k: usize,
    valid: usize,
    tile: &mut [i32],
    mut f: impl FnMut(usize, usize, &[i32]),
) {
    let mut remaining = valid;
    for (pi, page) in kp.iter().enumerate() {
        if remaining == 0 {
            break;
        }
        let np = page.len() / k;
        let take = np.min(remaining);
        let mut j0 = 0;
        while j0 < take {
            let tw = (take - j0).min(PREFILL_TILE_ROWS);
            let t = &mut tile[..tw];
            gemm_i8_rows(qrow, &page[j0 * k..(j0 + tw) * k], t, 1, tw, k, 0, 1);
            f(pi, j0, t);
            j0 += tw;
        }
        remaining -= take;
    }
}

/// One row block of an IndexSoftmax tiled prefill: the query rows, their
/// absolute position (`row0`, for the causal mask), the resident K̂/V̂ page
/// lists, the per-row `(c_int, idx_div)` IndexSoftmax parameters (grouped-Q
/// schemes vary them per row), the shared LUT geometry `n1`, the block's
/// `rows×d` i32 output accumulator, and a [`PREFILL_TILE_ROWS`]-sized logit
/// tile. `nnz` comes back with the block's nonzero-`P̂` count.
pub struct TiledPrefillJobI8<'a> {
    pub q: &'a [i8],
    pub row0: usize,
    pub mask: Mask,
    pub l: usize,
    pub kp: &'a [&'a [i8]],
    pub vp: &'a [&'a [i8]],
    pub params: &'a [(u64, MulShiftDiv)],
    pub n1: u64,
    pub out: &'a mut [i32],
    pub tile: &'a mut [i32],
    pub nnz: u64,
}

/// Online-tiled IndexSoftmax prefill of one row block: per query row, three
/// tile-sized passes over the valid prefix of the page walk — (A) row max,
/// (B) `ΣÊ` with the max pinned, (C) `P̂ = round(255·Ê/ΣÊ)` and the
/// zero-skipping `P̂·V̂` accumulation. Pass C recomputes each `Ê` from the
/// same logit recompute, so every integer op (and its order) is exactly
/// what the materialized `forward_into` + paged `P̂·V̂` path performs —
/// the output is **bit-for-bit** equal to the unfused oracle — while the
/// working set stays O([`PREFILL_TILE_ROWS`] + d): no `m×L` score block,
/// no L-length row, at any page size.
pub fn tiled_prefill_i8(job: &mut TiledPrefillJobI8, table: &[u8]) {
    let rows = job.params.len();
    let k = job.q.len() / rows;
    let d = job.out.len() / rows;
    let (kp, vp, q, params) = (job.kp, job.vp, job.q, job.params);
    let (n1, l, row0, mask) = (job.n1, job.l, job.row0, job.mask);
    let mut nnz = 0u64;
    // AUDIT: int-only begin gemm-tiled-prefill-i8
    debug_assert_eq!(paged_rows(kp, k), l, "K̂ row count");
    debug_assert_eq!(paged_rows(vp, d), l, "V̂ row count");
    job.out.fill(0);
    for r in 0..rows {
        let qrow = &q[r * k..(r + 1) * k];
        let valid = mask.valid_cols(row0 + r, l);
        let (c_int, idx_div) = params[r];
        // Pass A: the materialized path's row max over the valid prefix.
        let mut m = i32::MIN;
        prefill_qk_tiles(qrow, kp, k, valid, job.tile, |_, _, t| {
            for &a in t {
                if a > m {
                    m = a;
                }
            }
        });
        // Pass B: ΣÊ with the max pinned (eq. 15's u32 accumulator).
        let mut sum = 0u32;
        prefill_qk_tiles(qrow, kp, k, valid, job.tile, |_, _, t| {
            for &a in t {
                let delta = (m as i64 - a as i64) as u64;
                if delta < c_int {
                    sum += table[idx_div.div_round(delta * n1) as usize] as u32;
                }
            }
        });
        // Pass C: normalize each re-gathered Ê and accumulate P̂·V̂ in
        // ascending column order (the paged u8×i8 kernel's order).
        debug_assert!(sum >= 255);
        let norm_div = MulShiftDiv::new(sum as u64);
        let orow = &mut job.out[r * d..(r + 1) * d];
        prefill_qk_tiles(qrow, kp, k, valid, job.tile, |pi, j0, t| {
            let vpage = vp[pi];
            for (jj, &a) in t.iter().enumerate() {
                let delta = (m as i64 - a as i64) as u64;
                let e = if delta >= c_int {
                    0
                } else {
                    table[idx_div.div_round(delta * n1) as usize]
                };
                let p = norm_div.div_round(255 * e as u64);
                if p != 0 {
                    nnz += 1;
                    let vrow = &vpage[(j0 + jj) * d..(j0 + jj + 1) * d];
                    for (x, &vx) in orow.iter_mut().zip(vrow) {
                        *x += p as i32 * vx as i32;
                    }
                }
            }
        });
    }
    // AUDIT: int-only end
    job.nnz = nnz;
}

/// Pool-parallel [`tiled_prefill_i8`] over independent row-block jobs.
pub fn par_tiled_prefill_i8(jobs: &mut [TiledPrefillJobI8], table: &[u8], pool: &ParallelPool) {
    // Three logit recomputes per row: 3·rows·L·k MAC-equivalents.
    let work: usize = jobs.iter().map(|j| 3 * j.q.len() * j.l).sum();
    pool.parallel_groups(jobs, work, |j| tiled_prefill_i8(j, table));
}

/// One row block of the EXAQ tiled prefill's **statistics** launch: a
/// single pass per row producing the row max and the exact integer
/// Δ-moments `(Σδ, Σδ², n)` about it (running-max shifted as the walk
/// discovers larger logits — exact in i128). The launching thread folds
/// the moments into the running clip statistics before the PV launch.
pub struct TiledPrefillStatsJob<'a> {
    pub q: &'a [i8],
    pub row0: usize,
    pub mask: Mask,
    pub l: usize,
    pub kp: &'a [&'a [i8]],
    pub maxes: &'a mut [i32],
    pub moments: &'a mut [(i128, i128, u64)],
    pub tile: &'a mut [i32],
}

/// Max + exact Δ-moment pass of the EXAQ tiled prefill (one QK walk per
/// row). When the running max moves by `s`, every prior `δ` grows by `s`:
/// `Σδ² += 2sΣδ + n·s²` then `Σδ += n·s` — exact integer shifts, so the
/// final moments equal a direct reduction against the final max.
pub fn tiled_prefill_exaq_stats(job: &mut TiledPrefillStatsJob) {
    let rows = job.maxes.len();
    let k = job.q.len() / rows;
    let (kp, q) = (job.kp, job.q);
    let (l, row0, mask) = (job.l, job.row0, job.mask);
    // AUDIT: int-only begin gemm-tiled-prefill-exaq
    for r in 0..rows {
        let qrow = &q[r * k..(r + 1) * k];
        let valid = mask.valid_cols(row0 + r, l);
        let mut m = i32::MIN;
        let mut started = false;
        let (mut dsum, mut dsumsq, mut n) = (0i128, 0i128, 0u64);
        prefill_qk_tiles(qrow, kp, k, valid, job.tile, |_, _, t| {
            for &a in t {
                if !started || a > m {
                    if started {
                        let s = (a as i64 - m as i64) as i128;
                        dsumsq += 2 * s * dsum + (n as i128) * s * s;
                        dsum += (n as i128) * s;
                    }
                    m = a;
                    started = true;
                }
                let delta = (m as i64 - a as i64) as i128;
                dsum += delta;
                dsumsq += delta * delta;
                n += 1;
            }
        });
        job.maxes[r] = m;
        job.moments[r] = (dsum, dsumsq, n);
    }
    // AUDIT: int-only end
}

/// Pool-parallel [`tiled_prefill_exaq_stats`].
pub fn par_tiled_prefill_exaq_stats(jobs: &mut [TiledPrefillStatsJob], pool: &ParallelPool) {
    let work: usize = jobs.iter().map(|j| j.q.len() * j.l).sum();
    pool.parallel_groups(jobs, work, tiled_prefill_exaq_stats);
}

/// One row block of the EXAQ tiled prefill's **PV** launch: with the per-row
/// maxes pinned (from the stats launch) and the block-wide dynamic clip /
/// f32 LUT resolved, two more passes per row — (B) the f32 row sum of LUT
/// gathers in ascending column order (bit-equal to the materialized
/// forward's), (C) `P̂ = round(255·LUT/Σ)` requantize + zero-skipping
/// `P̂·V̂` accumulation.
pub struct TiledPrefillExaqJob<'a> {
    pub q: &'a [i8],
    pub row0: usize,
    pub mask: Mask,
    pub l: usize,
    pub kp: &'a [&'a [i8]],
    pub vp: &'a [&'a [i8]],
    pub maxes: &'a [i32],
    pub lut: &'a [f32],
    pub clip_int: f32,
    pub out: &'a mut [i32],
    pub tile: &'a mut [i32],
    pub nnz: u64,
}

/// LUT-gather + requantize + `P̂·V̂` pass of the EXAQ tiled prefill. The
/// float work here is exactly the materialized `forward_with_clip_counted`
/// row arithmetic (EXAQ's mixed-precision dataflow — the fence's allowlist
/// entries); everything else is integer.
pub fn tiled_prefill_exaq_pv(job: &mut TiledPrefillExaqJob) {
    let rows = job.maxes.len();
    let k = job.q.len() / rows;
    let d = job.out.len() / rows;
    let (kp, vp, q, maxes, lut) = (job.kp, job.vp, job.q, job.maxes, job.lut);
    let (l, row0, mask, clip_int) = (job.l, job.row0, job.mask, job.clip_int);
    let n = lut.len();
    let mut nnz = 0u64;
    // AUDIT: int-only begin gemm-tiled-prefill-exaq
    job.out.fill(0);
    for r in 0..rows {
        let qrow = &q[r * k..(r + 1) * k];
        let valid = mask.valid_cols(row0 + r, l);
        let m = maxes[r] as i64;
        // Pass B: the materialized row's f32 LUT sum, same gathers in the
        // same ascending order.
        let mut fsum: f32 = 0.0;
        prefill_qk_tiles(qrow, kp, k, valid, job.tile, |_, _, t| {
            for &a in t {
                let delta = (m - a as i64) as f32;
                let idx = ((delta / clip_int * (n - 1) as f32).round() as usize).min(n - 1);
                fsum += lut[idx];
            }
        });
        let inv = 1.0 / fsum;
        // Pass C: requantize each re-gathered weight and accumulate P̂·V̂.
        let orow = &mut job.out[r * d..(r + 1) * d];
        prefill_qk_tiles(qrow, kp, k, valid, job.tile, |pi, j0, t| {
            let vpage = vp[pi];
            for (jj, &a) in t.iter().enumerate() {
                let delta = (m - a as i64) as f32;
                let idx = ((delta / clip_int * (n - 1) as f32).round() as usize).min(n - 1);
                let p = (lut[idx] * inv * 255.0).round().clamp(0.0, 255.0) as u8;
                if p != 0 {
                    nnz += 1;
                    let vrow = &vpage[(j0 + jj) * d..(j0 + jj + 1) * d];
                    for (x, &vx) in orow.iter_mut().zip(vrow) {
                        *x += p as i32 * vx as i32;
                    }
                }
            }
        });
    }
    // AUDIT: int-only end
    job.nnz = nnz;
}

/// Pool-parallel [`tiled_prefill_exaq_pv`].
pub fn par_tiled_prefill_exaq_pv(jobs: &mut [TiledPrefillExaqJob], pool: &ParallelPool) {
    let work: usize = jobs.iter().map(|j| 2 * j.q.len() * j.l).sum();
    pool.parallel_groups(jobs, work, tiled_prefill_exaq_pv);
}

// ---------------------------------------------------------------------------
// Reference (naive) implementations for testing

/// Naive triple loop, f32 — the oracle the blocked kernels are tested against.
pub fn gemm_f32_naive(a: &MatF32, bt: &MatF32, c: &mut MatF32) {
    let (m, k) = (a.rows(), a.cols());
    let n = bt.rows();
    for i in 0..m {
        for j in 0..n {
            let mut s = 0f32;
            for x in 0..k {
                s += a.get(i, x) * bt.get(j, x);
            }
            c.set(i, j, s);
        }
    }
}

/// Naive i8 oracle.
pub fn gemm_i8_naive(a: &MatI8, bt: &MatI8, c: &mut MatI32) {
    let (m, k) = (a.rows(), a.cols());
    let n = bt.rows();
    for i in 0..m {
        for j in 0..n {
            let mut s = 0i32;
            for x in 0..k {
                s += a.get(i, x) as i32 * bt.get(j, x) as i32;
            }
            c.set(i, j, s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    /// Test pool with grain 1: every launch actually dispatches onto the
    /// persistent workers regardless of how small the test shapes are.
    fn tpool(n: usize) -> ParallelPool {
        ParallelPool::with_grain(n, 1)
    }

    /// Split a contiguous `rows×width` buffer into pages of at most
    /// `rows_per_page` whole rows — the layout `PagedRows` hands the
    /// kernels.
    fn split_pages<T>(buf: &[T], width: usize, rows_per_page: usize) -> Vec<&[T]> {
        assert_eq!(buf.len() % width, 0);
        if buf.is_empty() {
            return Vec::new();
        }
        let rows = buf.len() / width;
        buf.chunks(rows_per_page.clamp(1, rows) * width).collect()
    }

    fn rand_f32(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
        MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    fn rand_i8(rng: &mut Pcg64, r: usize, c: usize) -> MatI8 {
        MatI8::from_vec(r, c, (0..r * c).map(|_| rng.range_i64(-127, 128) as i8).collect())
    }

    fn rand_u8(rng: &mut Pcg64, r: usize, c: usize) -> MatU8 {
        MatU8::from_vec(r, c, (0..r * c).map(|_| rng.below(256) as u8).collect())
    }

    #[test]
    fn f32_matches_naive_various_shapes() {
        let mut rng = Pcg64::seed_from_u64(1);
        for &(m, n, k) in &[(1, 1, 1), (3, 5, 7), (8, 8, 8), (17, 13, 31), (2, 64, 128)] {
            let a = rand_f32(&mut rng, m, k);
            let bt = rand_f32(&mut rng, n, k);
            let mut c = MatF32::zeros(m, n);
            let mut c_ref = MatF32::zeros(m, n);
            gemm_f32(&a, &bt, &mut c);
            gemm_f32_naive(&a, &bt, &mut c_ref);
            assert!(c.allclose(&c_ref, 1e-4, 1e-4), "shape {m}x{n}x{k}");
        }
    }

    #[test]
    fn f32_parallel_matches_serial() {
        let mut rng = Pcg64::seed_from_u64(2);
        let a = rand_f32(&mut rng, 33, 64);
        let bt = rand_f32(&mut rng, 29, 64);
        let mut c1 = MatF32::zeros(33, 29);
        let mut c4 = MatF32::zeros(33, 29);
        gemm_f32(&a, &bt, &mut c1);
        par_gemm_f32(&a, &bt, &mut c4, &tpool(4));
        assert!(c1.allclose(&c4, 1e-5, 1e-5));
    }

    #[test]
    fn i8_matches_naive_exactly() {
        let mut rng = Pcg64::seed_from_u64(3);
        for &(m, n, k) in &[(1, 1, 1), (4, 6, 9), (16, 16, 64), (7, 31, 128), (5, 2, 3)] {
            let a = rand_i8(&mut rng, m, k);
            let bt = rand_i8(&mut rng, n, k);
            let mut c = MatI32::zeros(m, n);
            let mut c_ref = MatI32::zeros(m, n);
            gemm_i8(&a, &bt, &mut c);
            gemm_i8_naive(&a, &bt, &mut c_ref);
            assert_eq!(c, c_ref, "shape {m}x{n}x{k}");
        }
    }

    #[test]
    fn i8_parallel_matches_serial_exactly() {
        let mut rng = Pcg64::seed_from_u64(4);
        let a = rand_i8(&mut rng, 37, 96);
        let bt = rand_i8(&mut rng, 23, 96);
        let mut c1 = MatI32::zeros(37, 23);
        let mut c4 = MatI32::zeros(37, 23);
        gemm_i8(&a, &bt, &mut c1);
        par_gemm_i8(&a, &bt, &mut c4, &tpool(3));
        assert_eq!(c1, c4);
    }

    #[test]
    fn i8_accumulator_never_overflows_for_supported_dims() {
        // Worst case |a|=|b|=127: per-element 16129; i32 holds k ≤ 133k at
        // worst case — far above d=128 head dims. Verify at the extreme.
        let k = 4096;
        let a = MatI8::from_vec(1, k, vec![127; k]);
        let bt = MatI8::from_vec(1, k, vec![127; k]);
        let mut c = MatI32::zeros(1, 1);
        gemm_i8(&a, &bt, &mut c);
        assert_eq!(c.get(0, 0), 127 * 127 * k as i32);
    }

    #[test]
    fn u8i8_matches_scalar_reference() {
        let mut rng = Pcg64::seed_from_u64(5);
        let (m, l, d) = (9, 33, 16);
        let p = rand_u8(&mut rng, m, l);
        let v = rand_i8(&mut rng, l, d);
        let mut c = MatI32::zeros(m, d);
        gemm_u8i8(&p, &v, &mut c);
        for i in 0..m {
            for cc in 0..d {
                let mut s = 0i32;
                for j in 0..l {
                    s += p.get(i, j) as i32 * v.get(j, cc) as i32;
                }
                assert_eq!(c.get(i, cc), s, "({i},{cc})");
            }
        }
    }

    #[test]
    fn u8i8_parallel_matches_serial() {
        let mut rng = Pcg64::seed_from_u64(6);
        let p = rand_u8(&mut rng, 41, 64);
        let v = rand_i8(&mut rng, 64, 32);
        let mut c1 = MatI32::zeros(41, 32);
        let mut c2 = MatI32::zeros(41, 32);
        gemm_u8i8(&p, &v, &mut c1);
        par_gemm_u8i8(&p, &v, &mut c2, &tpool(5));
        assert_eq!(c1, c2);
    }

    #[test]
    fn u8i8_zero_rows_are_skipped_correctly() {
        // All-zero P row must produce a zero output row (sparsity path).
        let p = MatU8::from_vec(2, 3, vec![0, 0, 0, 1, 2, 3]);
        let v = MatI8::from_vec(3, 2, vec![1, -1, 2, -2, 3, -3]);
        let mut c = MatI32::zeros(2, 2);
        gemm_u8i8(&p, &v, &mut c);
        assert_eq!(c.row(0), &[0, 0]);
        assert_eq!(c.row(1), &[1 * 1 + 2 * 2 + 3 * 3, -(1 * 1 + 2 * 2 + 3 * 3)]);
    }

    #[test]
    fn i8_notrans_matches_u8_variant_on_nonneg() {
        let mut rng = Pcg64::seed_from_u64(7);
        let (m, l, d) = (6, 20, 8);
        let pu: MatU8 =
            MatU8::from_vec(m, l, (0..m * l).map(|_| rng.below(128) as u8).collect());
        let pi: MatI8 = pu.map(|x| x as i8);
        let v = rand_i8(&mut rng, l, d);
        let mut cu = MatI32::zeros(m, d);
        let mut ci = MatI32::zeros(m, d);
        gemm_u8i8(&pu, &v, &mut cu);
        gemm_i8_notrans(&pi, &v, &mut ci);
        assert_eq!(cu, ci);
    }

    #[test]
    fn f16_gemm_close_to_f32() {
        let mut rng = Pcg64::seed_from_u64(8);
        let (m, n, k) = (8, 12, 32);
        let a = rand_f32(&mut rng, m, k);
        let bt = rand_f32(&mut rng, n, k);
        let mut c_ref = MatF32::zeros(m, n);
        gemm_f32(&a, &bt, &mut c_ref);
        let ah: Vec<F16> = a.as_slice().iter().map(|&x| F16::from_f32(x)).collect();
        let bh: Vec<F16> = bt.as_slice().iter().map(|&x| F16::from_f32(x)).collect();
        let mut c = vec![0f32; m * n];
        gemm_f16(&ah, &bh, m, n, k, &mut c);
        for (x, y) in c.iter().zip(c_ref.as_slice()) {
            // f16 inputs: rel error ~2^-11 per element, k=32 accumulation.
            assert!((x - y).abs() <= 0.02 * y.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn slice_kernels_match_mat_kernels() {
        let mut rng = Pcg64::seed_from_u64(9);
        let (m, n, k) = (7, 19, 33);
        // f32
        let a = rand_f32(&mut rng, m, k);
        let bt = rand_f32(&mut rng, n, k);
        let mut c_ref = MatF32::zeros(m, n);
        gemm_f32(&a, &bt, &mut c_ref);
        let mut c = vec![0f32; m * n];
        gemm_f32_slices(a.as_slice(), bt.as_slice(), &mut c, m, n, k);
        assert!(c
            .iter()
            .zip(c_ref.as_slice())
            .all(|(x, y)| (x - y).abs() < 1e-4));
        let mut c_par = vec![0f32; m * n];
        par_gemm_f32_slices(a.as_slice(), bt.as_slice(), &mut c_par, m, n, k, &tpool(3));
        assert_eq!(c, c_par);
        // i8
        let ai = rand_i8(&mut rng, m, k);
        let bi = rand_i8(&mut rng, n, k);
        let mut ci_ref = MatI32::zeros(m, n);
        gemm_i8(&ai, &bi, &mut ci_ref);
        let mut ci = vec![0i32; m * n];
        gemm_i8_slices(ai.as_slice(), bi.as_slice(), &mut ci, m, n, k);
        assert_eq!(&ci, ci_ref.as_slice());
        let mut ci_par = vec![0i32; m * n];
        par_gemm_i8_slices(ai.as_slice(), bi.as_slice(), &mut ci_par, m, n, k, &tpool(4));
        assert_eq!(ci, ci_par);
    }

    #[test]
    fn notrans_slice_kernels_match_mat_kernels() {
        let mut rng = Pcg64::seed_from_u64(10);
        let (m, l, d) = (6, 21, 10);
        let pu = rand_u8(&mut rng, m, l);
        let v = rand_i8(&mut rng, l, d);
        let mut c_ref = MatI32::zeros(m, d);
        gemm_u8i8(&pu, &v, &mut c_ref);
        let mut c = vec![0i32; m * d];
        gemm_u8i8_slices(pu.as_slice(), v.as_slice(), &mut c, m, l, d);
        assert_eq!(&c, c_ref.as_slice());
        // i8 notrans
        let pi: MatI8 = pu.map(|x| (x / 2) as i8);
        let mut ci_ref = MatI32::zeros(m, d);
        gemm_i8_notrans(&pi, &v, &mut ci_ref);
        let mut ci = vec![0i32; m * d];
        gemm_i8_notrans_slices(pi.as_slice(), v.as_slice(), &mut ci, m, l, d);
        assert_eq!(&ci, ci_ref.as_slice());
        // f32 notrans
        let pf = rand_f32(&mut rng, m, l);
        let vf = rand_f32(&mut rng, l, d);
        let mut cf_ref = MatF32::zeros(m, d);
        gemm_f32_notrans(&pf, &vf, &mut cf_ref);
        let mut cf = vec![0f32; m * d];
        gemm_f32_notrans_slices(pf.as_slice(), vf.as_slice(), &mut cf, m, l, d);
        assert!(cf
            .iter()
            .zip(cf_ref.as_slice())
            .all(|(x, y)| (x - y).abs() < 1e-5));
    }

    #[test]
    fn f16_notrans_close_to_f32_reference() {
        let mut rng = Pcg64::seed_from_u64(11);
        let (m, l, d) = (4, 16, 8);
        let pf = rand_f32(&mut rng, m, l);
        let vf = rand_f32(&mut rng, l, d);
        let mut c_ref = MatF32::zeros(m, d);
        gemm_f32_notrans(&pf, &vf, &mut c_ref);
        let ph: Vec<F16> = pf.as_slice().iter().map(|&x| F16::from_f32(x)).collect();
        let vh: Vec<F16> = vf.as_slice().iter().map(|&x| F16::from_f32(x)).collect();
        let mut c = vec![0f32; m * d];
        gemm_f16_notrans(&ph, &vh, &mut c, m, l, d);
        for (x, y) in c.iter().zip(c_ref.as_slice()) {
            assert!((x - y).abs() <= 0.05 * y.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn shape_mismatch_panics() {
        let a = MatI8::zeros(2, 3);
        let bt = MatI8::zeros(2, 4);
        let mut c = MatI32::zeros(2, 2);
        gemm_i8(&a, &bt, &mut c);
    }

    #[test]
    fn grouped_i8_matches_per_group_slice_kernels() {
        // Ragged batch: per-group context lengths differ; grouped output
        // must equal B independent slice-kernel calls, serial and pooled,
        // for single-page ("contiguous") and page-split resident operands.
        let mut rng = Pcg64::seed_from_u64(20);
        let k = 48;
        let ns = [1usize, 7, 33, 12, 64];
        let qs: Vec<MatI8> = ns.iter().map(|_| rand_i8(&mut rng, 1, k)).collect();
        let kvs: Vec<MatI8> = ns.iter().map(|&n| rand_i8(&mut rng, n, k)).collect();
        let mut want: Vec<Vec<i32>> = Vec::new();
        for ((q, kv), &n) in qs.iter().zip(&kvs).zip(&ns) {
            let mut c = vec![0i32; n];
            gemm_i8_slices(q.as_slice(), kv.as_slice(), &mut c, 1, n, k);
            want.push(c);
        }
        // Serial driver, then the pooled one at several widths (the dynamic
        // cursor must hand out every group exactly once); per-group page
        // sizes vary within a batch (real batches mix state geometries).
        for page_rows in [usize::MAX, 1, 2, 5] {
            for threads in [0, 1, 2, 3, 16] {
                let pool = tpool(threads.max(1));
                let pages: Vec<Vec<&[i8]>> = kvs
                    .iter()
                    .map(|kv| split_pages(kv.as_slice(), k, page_rows))
                    .collect();
                let mut outs: Vec<Vec<i32>> = ns.iter().map(|&n| vec![0i32; n]).collect();
                let mut groups: Vec<GroupI8> = qs
                    .iter()
                    .zip(&pages)
                    .zip(outs.iter_mut())
                    .map(|((q, kp), out)| GroupI8 {
                        a: q.as_slice(),
                        b: kp.as_slice(),
                        out: out.as_mut_slice(),
                    })
                    .collect();
                if threads == 0 {
                    gemm_i8_grouped(&mut groups, k);
                } else {
                    par_gemm_i8_grouped(&mut groups, k, &pool);
                }
                drop(groups);
                assert_eq!(outs, want, "threads={threads} page_rows={page_rows}");
            }
        }
    }

    #[test]
    fn paged_kernels_byte_match_slice_kernels_across_page_splits() {
        // The paged-residency contract: every *_paged kernel is byte-equal
        // to its contiguous *_slices sibling over the concatenated pages,
        // at page sizes that land mid-row-run and at the degenerate 1-row
        // page. Exact equality, floats included (same ops, same order).
        let mut rng = Pcg64::seed_from_u64(55);
        let (m, n, k, d) = (5, 23, 32, 12);
        let ai = rand_i8(&mut rng, m, k);
        let ki = rand_i8(&mut rng, n, k);
        let af = rand_f32(&mut rng, m, k);
        let kf = rand_f32(&mut rng, n, k);
        let pu = rand_u8(&mut rng, m, n);
        let vi = rand_i8(&mut rng, n, d);
        let pf = rand_f32(&mut rng, m, n);
        let vf = rand_f32(&mut rng, n, d);
        let ah: Vec<F16> = af.as_slice().iter().map(|&x| F16::from_f32(x)).collect();
        let kh: Vec<F16> = kf.as_slice().iter().map(|&x| F16::from_f32(x)).collect();
        let ph: Vec<F16> = pf.as_slice().iter().map(|&x| F16::from_f32(x)).collect();
        let vh: Vec<F16> = vf.as_slice().iter().map(|&x| F16::from_f32(x)).collect();
        // Contiguous oracles.
        let mut ci_ref = vec![0i32; m * n];
        gemm_i8_slices(ai.as_slice(), ki.as_slice(), &mut ci_ref, m, n, k);
        let mut cf_ref = vec![0f32; m * n];
        gemm_f32_slices(af.as_slice(), kf.as_slice(), &mut cf_ref, m, n, k);
        let mut ch_ref = vec![0f32; m * n];
        gemm_f16(&ah, &kh, m, n, k, &mut ch_ref);
        let mut cu_ref = vec![0i32; m * d];
        gemm_u8i8_slices(pu.as_slice(), vi.as_slice(), &mut cu_ref, m, n, d);
        let pi: MatI8 = pu.map(|x| (x / 2) as i8);
        let mut cn_ref = vec![0i32; m * d];
        gemm_i8_notrans_slices(pi.as_slice(), vi.as_slice(), &mut cn_ref, m, n, d);
        let mut cfn_ref = vec![0f32; m * d];
        gemm_f32_notrans_slices(pf.as_slice(), vf.as_slice(), &mut cfn_ref, m, n, d);
        let mut chn_ref = vec![0f32; m * d];
        gemm_f16_notrans(&ph, &vh, &mut chn_ref, m, n, d);
        let pool = tpool(3);
        for page_rows in [1usize, 2, 3, 7, 64] {
            let kip = split_pages(ki.as_slice(), k, page_rows);
            let kfp = split_pages(kf.as_slice(), k, page_rows);
            let khp = split_pages(&kh, k, page_rows);
            let vip = split_pages(vi.as_slice(), d, page_rows);
            let vfp = split_pages(vf.as_slice(), d, page_rows);
            let vhp = split_pages(&vh, d, page_rows);
            let mut ci = vec![0i32; m * n];
            gemm_i8_paged(ai.as_slice(), &kip, &mut ci, m, n, k);
            assert_eq!(ci, ci_ref, "i8 QK @ {page_rows}");
            let mut ci_par = vec![0i32; m * n];
            par_gemm_i8_paged(ai.as_slice(), &kip, &mut ci_par, m, n, k, &pool);
            assert_eq!(ci_par, ci_ref, "par i8 QK @ {page_rows}");
            let mut cf = vec![0f32; m * n];
            gemm_f32_paged(af.as_slice(), &kfp, &mut cf, m, n, k);
            assert_eq!(cf, cf_ref, "f32 QK @ {page_rows}");
            let mut cf_par = vec![0f32; m * n];
            par_gemm_f32_paged(af.as_slice(), &kfp, &mut cf_par, m, n, k, &pool);
            assert_eq!(cf_par, cf_ref, "par f32 QK @ {page_rows}");
            let mut ch = vec![0f32; m * n];
            gemm_f16_paged(&ah, &khp, m, n, k, &mut ch);
            assert_eq!(ch, ch_ref, "f16 QK @ {page_rows}");
            let mut cu = vec![0i32; m * d];
            gemm_u8i8_paged(pu.as_slice(), &vip, &mut cu, m, n, d);
            assert_eq!(cu, cu_ref, "u8i8 PV @ {page_rows}");
            let mut cn = vec![0i32; m * d];
            gemm_i8_notrans_paged(pi.as_slice(), &vip, &mut cn, m, n, d);
            assert_eq!(cn, cn_ref, "i8 notrans PV @ {page_rows}");
            let mut cfn = vec![0f32; m * d];
            gemm_f32_notrans_paged(pf.as_slice(), &vfp, &mut cfn, m, n, d);
            assert_eq!(cfn, cfn_ref, "f32 PV @ {page_rows}");
            let mut chn = vec![0f32; m * d];
            gemm_f16_notrans_paged(&ph, &vhp, &mut chn, m, n, d);
            assert_eq!(chn, chn_ref, "f16 PV @ {page_rows}");
        }
    }

    #[test]
    fn grouped_u8i8_and_i8_notrans_match_slice_kernels() {
        let mut rng = Pcg64::seed_from_u64(21);
        let d = 16;
        let ls = [3usize, 1, 29, 17];
        let ps: Vec<MatU8> = ls.iter().map(|&l| rand_u8(&mut rng, 1, l)).collect();
        let vs: Vec<MatI8> = ls.iter().map(|&l| rand_i8(&mut rng, l, d)).collect();
        // u8 probabilities.
        let mut want: Vec<Vec<i32>> = Vec::new();
        for ((p, v), &l) in ps.iter().zip(&vs).zip(&ls) {
            let mut c = vec![0i32; d];
            gemm_u8i8_slices(p.as_slice(), v.as_slice(), &mut c, 1, l, d);
            want.push(c);
        }
        // Serial driver first, then the pooled one; contiguous (one page)
        // and page-split resident values.
        for page_rows in [usize::MAX, 2] {
            for threads in [0usize, 2] {
                let pool = tpool(threads.max(1));
                let pages: Vec<Vec<&[i8]>> = vs
                    .iter()
                    .map(|v| split_pages(v.as_slice(), d, page_rows))
                    .collect();
                let mut outs: Vec<Vec<i32>> = ls.iter().map(|_| vec![0i32; d]).collect();
                let mut groups: Vec<GroupU8I8> = ps
                    .iter()
                    .zip(&pages)
                    .zip(outs.iter_mut())
                    .map(|((p, vp), out)| GroupU8I8 {
                        a: p.as_slice(),
                        b: vp.as_slice(),
                        out: out.as_mut_slice(),
                    })
                    .collect();
                if threads == 0 {
                    gemm_u8i8_grouped(&mut groups, d);
                } else {
                    par_gemm_u8i8_grouped(&mut groups, d, &pool);
                }
                drop(groups);
                assert_eq!(outs, want, "threads={threads} page_rows={page_rows}");
            }
        }
        // Signed i8 probabilities (Quant-Only).
        let pis: Vec<MatI8> = ps.iter().map(|p| p.map(|x| (x / 2) as i8)).collect();
        let mut want_i: Vec<Vec<i32>> = Vec::new();
        for ((p, v), &l) in pis.iter().zip(&vs).zip(&ls) {
            let mut c = vec![0i32; d];
            gemm_i8_notrans_slices(p.as_slice(), v.as_slice(), &mut c, 1, l, d);
            want_i.push(c);
        }
        for page_rows in [usize::MAX, 3] {
            for threads in [0usize, 3] {
                let pool = tpool(threads.max(1));
                let pages: Vec<Vec<&[i8]>> = vs
                    .iter()
                    .map(|v| split_pages(v.as_slice(), d, page_rows))
                    .collect();
                let mut outs_i: Vec<Vec<i32>> = ls.iter().map(|_| vec![0i32; d]).collect();
                let mut groups_i: Vec<GroupI8> = pis
                    .iter()
                    .zip(&pages)
                    .zip(outs_i.iter_mut())
                    .map(|((p, vp), out)| GroupI8 {
                        a: p.as_slice(),
                        b: vp.as_slice(),
                        out: out.as_mut_slice(),
                    })
                    .collect();
                if threads == 0 {
                    gemm_i8_notrans_grouped(&mut groups_i, d);
                } else {
                    par_gemm_i8_notrans_grouped(&mut groups_i, d, &pool);
                }
                drop(groups_i);
                assert_eq!(outs_i, want_i, "threads={threads} page_rows={page_rows}");
            }
        }
    }

    #[test]
    fn grouped_float_kernels_bit_match_serial_kernels() {
        let mut rng = Pcg64::seed_from_u64(22);
        let (k, d) = (24, 8);
        let ns = [5usize, 13, 2];
        // f32 QK side.
        let qs: Vec<MatF32> = ns.iter().map(|_| rand_f32(&mut rng, 1, k)).collect();
        let ks: Vec<MatF32> = ns.iter().map(|&n| rand_f32(&mut rng, n, k)).collect();
        let mut want: Vec<Vec<f32>> = Vec::new();
        for ((q, kk), &n) in qs.iter().zip(&ks).zip(&ns) {
            let mut c = vec![0f32; n];
            gemm_f32_slices(q.as_slice(), kk.as_slice(), &mut c, 1, n, k);
            want.push(c);
        }
        let mut outs: Vec<Vec<f32>> = ns.iter().map(|&n| vec![0f32; n]).collect();
        let k_pages: Vec<Vec<&[f32]>> = ks
            .iter()
            .map(|kk| split_pages(kk.as_slice(), k, 2))
            .collect();
        let mut groups: Vec<GroupF32> = qs
            .iter()
            .zip(&k_pages)
            .zip(outs.iter_mut())
            .map(|((q, kp), out)| GroupF32 {
                a: q.as_slice(),
                b: kp.as_slice(),
                out: out.as_mut_slice(),
            })
            .collect();
        par_gemm_f32_grouped(&mut groups, k, &tpool(2));
        drop(groups);
        assert_eq!(outs, want, "grouped f32 QK must be bit-identical");
        // f16 PV side.
        let ls = [4usize, 9];
        let ph: Vec<Vec<F16>> = ls
            .iter()
            .map(|&l| {
                (0..l)
                    .map(|_| F16::from_f32(rng.normal().abs().min(1.0)))
                    .collect()
            })
            .collect();
        let vh: Vec<Vec<F16>> = ls
            .iter()
            .map(|&l| (0..l * d).map(|_| F16::from_f32(rng.normal())).collect())
            .collect();
        let mut want_h: Vec<Vec<f32>> = Vec::new();
        for ((p, v), &l) in ph.iter().zip(&vh).zip(&ls) {
            let mut c = vec![0f32; d];
            gemm_f16_notrans(p, v, &mut c, 1, l, d);
            want_h.push(c);
        }
        let mut outs_h: Vec<Vec<f32>> = ls.iter().map(|_| vec![0f32; d]).collect();
        let v_pages: Vec<Vec<&[F16]>> = vh.iter().map(|v| split_pages(v, d, 3)).collect();
        let mut groups_h: Vec<GroupF16> = ph
            .iter()
            .zip(&v_pages)
            .zip(outs_h.iter_mut())
            .map(|((p, vp), out)| GroupF16 {
                a: p.as_slice(),
                b: vp.as_slice(),
                out: out.as_mut_slice(),
            })
            .collect();
        par_gemm_f16_notrans_grouped(&mut groups_h, d, &tpool(2));
        drop(groups_h);
        assert_eq!(outs_h, want_h, "grouped f16 PV must be bit-identical");
    }

    #[test]
    fn pooled_drivers_bit_identical_across_pool_sizes() {
        // The persistent-runtime determinism contract: every par_* driver's
        // output is bit-identical at pool sizes 1/2/8 (grain 1, so the
        // multi-worker sizes genuinely dispatch) for every dtype. Chunk
        // boundaries and claim order move whole rows/groups between
        // workers; they never change what any output element computes.
        let mut rng = Pcg64::seed_from_u64(40);
        let (m, n, k) = (23, 17, 40);
        let af = rand_f32(&mut rng, m, k);
        let bf = rand_f32(&mut rng, n, k);
        let ai = rand_i8(&mut rng, m, k);
        let bi = rand_i8(&mut rng, n, k);
        let pu = rand_u8(&mut rng, m, n);
        let vi = rand_i8(&mut rng, n, k);
        // Single-thread references (pool size 1 == inline serial path).
        let p1 = tpool(1);
        let mut cf_ref = vec![0f32; m * n];
        par_gemm_f32_slices(af.as_slice(), bf.as_slice(), &mut cf_ref, m, n, k, &p1);
        let mut ci_ref = MatI32::zeros(m, n);
        par_gemm_i8(&ai, &bi, &mut ci_ref, &p1);
        let mut cu_ref = MatI32::zeros(m, k);
        par_gemm_u8i8(&pu, &vi, &mut cu_ref, &p1);
        for threads in [2usize, 8] {
            let pool = tpool(threads);
            let mut cf = vec![0f32; m * n];
            par_gemm_f32_slices(af.as_slice(), bf.as_slice(), &mut cf, m, n, k, &pool);
            assert_eq!(cf, cf_ref, "f32 slices @ {threads}");
            let mut ci = MatI32::zeros(m, n);
            par_gemm_i8(&ai, &bi, &mut ci, &pool);
            assert_eq!(ci, ci_ref, "i8 @ {threads}");
            let mut cu = MatI32::zeros(m, k);
            par_gemm_u8i8(&pu, &vi, &mut cu, &pool);
            assert_eq!(cu, cu_ref, "u8i8 @ {threads}");
        }
        // Grouped f16 QK (the remaining dtype driver): per group exactly one
        // gemm_f16 call, so pooled output must bit-match the serial call.
        let ns = [3usize, 9, 1, 14];
        let qh: Vec<Vec<F16>> = ns
            .iter()
            .map(|_| (0..k).map(|_| F16::from_f32(rng.normal())).collect())
            .collect();
        let kh: Vec<Vec<F16>> = ns
            .iter()
            .map(|&nn| (0..nn * k).map(|_| F16::from_f32(rng.normal())).collect())
            .collect();
        let mut want: Vec<Vec<f32>> = Vec::new();
        for ((q, kk), &nn) in qh.iter().zip(&kh).zip(&ns) {
            let mut c = vec![0f32; nn];
            gemm_f16(q, kk, 1, nn, k, &mut c);
            want.push(c);
        }
        for threads in [1usize, 2, 8] {
            let pool = tpool(threads);
            let k_pages: Vec<Vec<&[F16]>> = kh.iter().map(|kk| split_pages(kk, k, 4)).collect();
            let mut outs: Vec<Vec<f32>> = ns.iter().map(|&nn| vec![0f32; nn]).collect();
            let mut groups: Vec<GroupF16> = qh
                .iter()
                .zip(&k_pages)
                .zip(outs.iter_mut())
                .map(|((q, kp), out)| GroupF16 {
                    a: q.as_slice(),
                    b: kp.as_slice(),
                    out: out.as_mut_slice(),
                })
                .collect();
            par_gemm_f16_grouped(&mut groups, k, &pool);
            drop(groups);
            assert_eq!(outs, want, "grouped f16 QK @ {threads}");
        }
    }

    use crate::softmax::exaq::{ExaqConfig, ExaqSoftmax};
    use crate::softmax::index_softmax::IndexSoftmax;

    /// Flat-layout reference for the fused integer walk: the same two-phase
    /// online row driven over pre-computed whole-row logits. Any divergence
    /// from [`fused_decode_i8`] is a paging/wiring bug (tile offsets, V̂-row
    /// indexing), not an arithmetic one.
    fn fused_ref_i8(
        ix: &IndexSoftmax,
        alpha: f32,
        logits: &[i32],
        v: &[i8],
        d: usize,
    ) -> (Vec<i64>, u64, u64) {
        let mut row = ix.online_begin(alpha);
        for &a in logits {
            row.observe_max(a);
        }
        let mut acc = vec![0i64; d];
        for (j, &a) in logits.iter().enumerate() {
            let e = row.gather(a, &ix.lut.u8_table);
            if e != 0 {
                for (x, &vx) in acc.iter_mut().zip(&v[j * d..(j + 1) * d]) {
                    *x += e as i64 * vx as i64;
                }
            }
        }
        (acc, row.esum(), row.nnz())
    }

    #[test]
    fn fused_i8_matches_flat_reference_at_every_page_size() {
        let mut rng = Pcg64::seed_from_u64(40);
        let ix = IndexSoftmax::default();
        let (k, d, alpha) = (64usize, 16usize, 0.002f32);
        for l in [1usize, 7, 33, 128] {
            let q = rand_i8(&mut rng, 1, k);
            let kmat = rand_i8(&mut rng, l, k);
            let vmat = rand_i8(&mut rng, l, d);
            let mut logits = MatI32::zeros(1, l);
            gemm_i8(&q, &kmat, &mut logits);
            let (want_acc, want_esum, want_nnz) =
                fused_ref_i8(&ix, alpha, logits.as_slice(), vmat.as_slice(), d);
            for pr in [1usize, 2, 5, 64, 128] {
                let kp = split_pages(kmat.as_slice(), k, pr);
                let vp = split_pages(vmat.as_slice(), d, pr);
                let mut row = ix.online_begin(alpha);
                let mut acc = vec![0i64; d];
                let mut tile = vec![0i32; pr.min(l)];
                fused_decode_i8(
                    q.as_slice(),
                    &kp,
                    &vp,
                    &mut row,
                    &ix.lut.u8_table,
                    &mut acc,
                    &mut tile,
                );
                // Max-then-gather against the final max ⇒ byte-identical at
                // every page size.
                assert_eq!(acc, want_acc, "l={l} pr={pr}");
                assert_eq!(row.esum(), want_esum, "l={l} pr={pr}");
                assert_eq!(row.nnz(), want_nnz, "l={l} pr={pr}");
            }
        }
    }

    #[test]
    fn fused_i8_single_key_is_exact() {
        // Degenerate row: one key ⇒ acc = 255·V̂_row, ΣÊ = 255 — the case
        // where fused and two-pass normalize identically (P̂ = 255 exactly).
        let ix = IndexSoftmax::default();
        let (k, d) = (8usize, 4usize);
        let q = vec![3i8; k];
        let kv = vec![-2i8; k];
        let v: Vec<i8> = vec![7, -7, 0, 127];
        let mut row = ix.online_begin(0.01);
        let mut acc = vec![0i64; d];
        let mut tile = vec![0i32; 1];
        fused_decode_i8(&q, &[&kv], &[&v], &mut row, &ix.lut.u8_table, &mut acc, &mut tile);
        let want: Vec<i64> = v.iter().map(|&x| 255 * x as i64).collect();
        assert_eq!(acc, want);
        assert_eq!(row.esum(), 255);
        assert_eq!(row.norm_div().div_round(255 * 255 * 7), 255 * 7);
    }

    #[test]
    fn fused_exaq_matches_flat_reference_at_every_page_size() {
        let mut rng = Pcg64::seed_from_u64(41);
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let (k, d, l, alpha, clip) = (32usize, 8usize, 50usize, 0.004f32, 1.9f32);
        let lut = ex.lut_f32(clip);
        let q = rand_i8(&mut rng, 1, k);
        let kmat = rand_i8(&mut rng, l, k);
        let vmat = rand_i8(&mut rng, l, d);
        let mut logits = MatI32::zeros(1, l);
        gemm_i8(&q, &kmat, &mut logits);
        // Flat reference: same two-phase walk over whole-row logits. The
        // bucketed accumulator is pure integer, so equality is exact.
        let mut rref = ex.online_begin(alpha, clip);
        for &a in logits.as_slice() {
            rref.observe_max(a);
        }
        let zb = rref.zero_bucket();
        let mut want = vec![0i64; (zb + 1) * d];
        for (j, &a) in logits.as_slice().iter().enumerate() {
            let b = rref.gather(a);
            if b != zb {
                let vrow = &vmat.as_slice()[j * d..(j + 1) * d];
                for (x, &vx) in want[b * d..(b + 1) * d].iter_mut().zip(vrow) {
                    *x += vx as i64;
                }
            }
        }
        for pr in [1usize, 2, 64] {
            let kp = split_pages(kmat.as_slice(), k, pr);
            let vp = split_pages(vmat.as_slice(), d, pr);
            let mut row = ex.online_begin(alpha, clip);
            let mut acc = vec![0i64; (zb + 1) * d];
            let mut tile = vec![0i32; pr.min(l)];
            fused_decode_exaq(q.as_slice(), &kp, &vp, &mut row, &mut acc, &mut tile);
            assert_eq!(acc, want, "pr={pr}");
            assert_eq!(row.counts(), rref.counts(), "pr={pr}");
            assert_eq!(row.fsum(&lut).to_bits(), rref.fsum(&lut).to_bits(), "pr={pr}");
            assert_eq!(row.stats(alpha), rref.stats(alpha), "pr={pr}");
            assert_eq!(row.nnz(), rref.nnz(), "pr={pr}");
        }
    }

    #[test]
    fn fused_span_drivers_match_sequential_exactly() {
        // Page-parallel span schedule vs the sequential grouped oracle: for
        // every split width, every sequence's merged (ΣÊ, nnz, accumulator)
        // must be byte-identical — the tentpole's core claim.
        let mut rng = Pcg64::seed_from_u64(42);
        let ix = IndexSoftmax::default();
        let (k, d, alpha) = (32usize, 8usize, 0.003f32);
        let ls = [19usize, 1, 64, 5];
        let qs: Vec<MatI8> = ls.iter().map(|_| rand_i8(&mut rng, 1, k)).collect();
        let ks: Vec<MatI8> = ls.iter().map(|&l| rand_i8(&mut rng, l, k)).collect();
        let vs: Vec<MatI8> = ls.iter().map(|&l| rand_i8(&mut rng, l, d)).collect();
        let kps: Vec<Vec<&[i8]>> = ks.iter().map(|m| split_pages(m.as_slice(), k, 4)).collect();
        let vps: Vec<Vec<&[i8]>> = vs.iter().map(|m| split_pages(m.as_slice(), d, 4)).collect();
        // `width` page spans per sequence (clamped to its page count); each
        // span job gets its own row/acc/tile, results land in span job 0.
        let run = |width: usize, pool: Option<&ParallelPool>| {
            let mut spans: Vec<usize> = Vec::new();
            let mut cuts: Vec<(usize, usize, usize)> = Vec::new(); // (seq, page a, page b)
            for (s, kp) in kps.iter().enumerate() {
                let n = decode_split_spans(width, kp.len(), usize::MAX, 1).min(kp.len());
                spans.push(n);
                let (base, extra) = (kp.len() / n, kp.len() % n);
                let mut at = 0;
                for i in 0..n {
                    let take = base + usize::from(i < extra);
                    cuts.push((s, at, at + take));
                    at += take;
                }
            }
            let total = cuts.len();
            let mut accs: Vec<Vec<i64>> = (0..total).map(|_| vec![0i64; d]).collect();
            let mut tiles: Vec<Vec<i32>> = (0..total).map(|_| vec![0i32; 4]).collect();
            let mut jobs: Vec<FusedJobI8> = Vec::new();
            for (&(s, a, b), (acc, tile)) in
                cuts.iter().zip(accs.iter_mut().zip(tiles.iter_mut()))
            {
                jobs.push(FusedJobI8 {
                    q: qs[s].as_slice(),
                    kp: &kps[s][a..b],
                    vp: &vps[s][a..b],
                    row: ix.online_begin(alpha),
                    acc,
                    tile,
                });
            }
            match pool {
                Some(p) => par_fused_decode_i8_spans(&mut jobs, &spans, &ix.lut.u8_table, p),
                None => fused_decode_i8_grouped(&mut jobs, &ix.lut.u8_table),
            }
            // Collect each sequence's result from its first span job.
            let mut firsts: Vec<usize> = Vec::new();
            let mut at = 0;
            for &s in &spans {
                firsts.push(at);
                at += s;
            }
            let stats: Vec<(u64, u64)> =
                firsts.iter().map(|&i| (jobs[i].row.esum(), jobs[i].row.nnz())).collect();
            drop(jobs);
            let accs: Vec<Vec<i64>> = firsts.iter().map(|&i| accs[i].clone()).collect();
            (accs, stats)
        };
        let (acc_ref, stats_ref) = run(1, None);
        for width in [1usize, 2, 4, 8] {
            for threads in [2usize, 8] {
                let pool = tpool(threads);
                let (acc, stats) = run(width, Some(&pool));
                assert_eq!(acc, acc_ref, "spans w={width} @ {threads}");
                assert_eq!(stats, stats_ref, "span stats w={width} @ {threads}");
            }
        }
    }

    #[test]
    fn fused_exaq_span_drivers_match_sequential_exactly() {
        let mut rng = Pcg64::seed_from_u64(43);
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let (k, d, alpha, clip) = (32usize, 8usize, 0.004f32, 1.7f32);
        let lut = ex.lut_f32(clip);
        let entries = ex.online_begin(alpha, clip).zero_bucket() + 1;
        let ls = [21usize, 1, 48];
        let qs: Vec<MatI8> = ls.iter().map(|_| rand_i8(&mut rng, 1, k)).collect();
        let ks: Vec<MatI8> = ls.iter().map(|&l| rand_i8(&mut rng, l, k)).collect();
        let vs: Vec<MatI8> = ls.iter().map(|&l| rand_i8(&mut rng, l, d)).collect();
        let kps: Vec<Vec<&[i8]>> = ks.iter().map(|m| split_pages(m.as_slice(), k, 4)).collect();
        let vps: Vec<Vec<&[i8]>> = vs.iter().map(|m| split_pages(m.as_slice(), d, 4)).collect();
        let run = |width: usize, pool: Option<&ParallelPool>| {
            let mut spans: Vec<usize> = Vec::new();
            let mut cuts: Vec<(usize, usize, usize)> = Vec::new();
            for (s, kp) in kps.iter().enumerate() {
                let n = decode_split_spans(width, kp.len(), usize::MAX, 1).min(kp.len());
                spans.push(n);
                let (base, extra) = (kp.len() / n, kp.len() % n);
                let mut at = 0;
                for i in 0..n {
                    let take = base + usize::from(i < extra);
                    cuts.push((s, at, at + take));
                    at += take;
                }
            }
            let total = cuts.len();
            let mut accs: Vec<Vec<i64>> = (0..total).map(|_| vec![0i64; entries * d]).collect();
            let mut tiles: Vec<Vec<i32>> = (0..total).map(|_| vec![0i32; 4]).collect();
            let mut jobs: Vec<FusedJobExaq> = Vec::new();
            for (&(s, a, b), (acc, tile)) in
                cuts.iter().zip(accs.iter_mut().zip(tiles.iter_mut()))
            {
                jobs.push(FusedJobExaq {
                    q: qs[s].as_slice(),
                    kp: &kps[s][a..b],
                    vp: &vps[s][a..b],
                    row: ex.online_begin(alpha, clip),
                    lut: &lut,
                    acc,
                    tile,
                });
            }
            match pool {
                Some(p) => par_fused_decode_exaq_spans(&mut jobs, &spans, p),
                None => fused_decode_exaq_grouped(&mut jobs),
            }
            let mut firsts: Vec<usize> = Vec::new();
            let mut at = 0;
            for &s in &spans {
                firsts.push(at);
                at += s;
            }
            let stats: Vec<(Vec<u64>, u32, u64)> = firsts
                .iter()
                .map(|&i| {
                    (
                        jobs[i].row.counts().to_vec(),
                        jobs[i].row.fsum(&lut).to_bits(),
                        jobs[i].row.nnz(),
                    )
                })
                .collect();
            drop(jobs);
            let accs: Vec<Vec<i64>> = firsts.iter().map(|&i| accs[i].clone()).collect();
            (accs, stats)
        };
        let (acc_ref, stats_ref) = run(1, None);
        for width in [1usize, 2, 4, 8] {
            let pool = tpool(4);
            let (acc, stats) = run(width, Some(&pool));
            assert_eq!(acc, acc_ref, "exaq spans w={width}");
            assert_eq!(stats, stats_ref, "exaq span stats w={width}");
        }
    }

    #[test]
    fn decode_split_spans_policy() {
        // Explicit width clamps to the page count; zero means auto (pool
        // workers over batch rows); everything is at least one span.
        assert_eq!(decode_split_spans(4, 2, 8, 1), 2);
        assert_eq!(decode_split_spans(4, 100, 8, 1), 4);
        assert_eq!(decode_split_spans(0, 100, 8, 1), 8);
        assert_eq!(decode_split_spans(0, 100, 8, 4), 2);
        assert_eq!(decode_split_spans(0, 100, 8, 32), 1);
        assert_eq!(decode_split_spans(0, 3, 8, 1), 3);
        assert_eq!(decode_split_spans(1, 0, 8, 1), 1);
        assert_eq!(decode_split_spans(0, 16, 0, 0), 1);
    }

    #[test]
    fn tiled_prefill_i8_matches_materialized_oracle_bitwise() {
        // Tiled prefill vs forward_into + paged P̂·V̂: identical integer ops
        // in identical order ⇒ bit-for-bit equal i32 outputs, at every page
        // size, under the causal mask, with per-row (grouped-Q) parameters.
        let mut rng = Pcg64::seed_from_u64(44);
        let ix = IndexSoftmax::default();
        let (m, l, k, d) = (9usize, 37usize, 32usize, 8usize);
        let alphas: Vec<f32> = (0..m).map(|r| 0.002 + 0.0005 * r as f32).collect();
        let q = rand_i8(&mut rng, m, k);
        let kmat = rand_i8(&mut rng, l, k);
        let vmat = rand_i8(&mut rng, l, d);
        let mut logits = MatI32::zeros(m, l);
        gemm_i8(&q, &kmat, &mut logits);
        let n1 = ix.lut.max_index() as u64;
        for mask in [Mask::None, Mask::CausalFrom(l - m)] {
            let (probs, want_nnz) = ix.forward_grouped(&logits, |r| r, &alphas, mask);
            for pr in [1usize, 2, 64] {
                let kp = split_pages(kmat.as_slice(), k, pr);
                let vp = split_pages(vmat.as_slice(), d, pr);
                let mut want = MatI32::zeros(m, d);
                gemm_u8i8_paged(probs.as_slice(), &vp, want.as_mut_slice(), m, l, d);
                let params: Vec<(u64, MulShiftDiv)> = alphas
                    .iter()
                    .map(|&a| {
                        let ci = ix.c_int(a) as u64;
                        (ci, MulShiftDiv::new(ci))
                    })
                    .collect();
                let mut out = vec![0i32; m * d];
                let mut tile = vec![0i32; PREFILL_TILE_ROWS];
                let mut job = TiledPrefillJobI8 {
                    q: q.as_slice(),
                    row0: 0,
                    mask,
                    l,
                    kp: &kp,
                    vp: &vp,
                    params: &params,
                    n1,
                    out: &mut out,
                    tile: &mut tile,
                    nnz: 0,
                };
                tiled_prefill_i8(&mut job, &ix.lut.u8_table);
                let nnz = job.nnz;
                drop(job);
                assert_eq!(out, want.as_slice(), "tiled prefill pr={pr} mask={mask:?}");
                assert_eq!(nnz, want_nnz, "tiled prefill nnz pr={pr} mask={mask:?}");
            }
        }
    }

    #[test]
    fn tiled_prefill_i8_row_blocks_compose() {
        // Splitting the row range into ROW_BLOCK jobs (with row0 offsets
        // into a causal mask) reproduces the single-job walk exactly, and
        // the parallel driver matches at any pool width.
        let mut rng = Pcg64::seed_from_u64(45);
        let ix = IndexSoftmax::default();
        let (m, l, k, d, alpha) = (13usize, 29usize, 16usize, 8usize, 0.003f32);
        let q = rand_i8(&mut rng, m, k);
        let kmat = rand_i8(&mut rng, l, k);
        let vmat = rand_i8(&mut rng, l, d);
        let mask = Mask::CausalFrom(l - m);
        let mut logits = MatI32::zeros(m, l);
        gemm_i8(&q, &kmat, &mut logits);
        let probs = ix.forward(&logits, alpha, mask);
        let kp = split_pages(kmat.as_slice(), k, 3);
        let vp = split_pages(vmat.as_slice(), d, 3);
        let mut want = MatI32::zeros(m, d);
        gemm_u8i8_paged(probs.as_slice(), &vp, want.as_mut_slice(), m, l, d);
        let ci = ix.c_int(alpha) as u64;
        let n1 = ix.lut.max_index() as u64;
        let blocks: Vec<(usize, usize)> = (0..m)
            .step_by(ROW_BLOCK)
            .map(|r0| (r0, (r0 + ROW_BLOCK).min(m)))
            .collect();
        let mut outs: Vec<Vec<i32>> = blocks.iter().map(|&(a, b)| vec![0i32; (b - a) * d]).collect();
        let mut tiles: Vec<Vec<i32>> = blocks.iter().map(|_| vec![0i32; PREFILL_TILE_ROWS]).collect();
        let params: Vec<Vec<(u64, MulShiftDiv)>> = blocks
            .iter()
            .map(|&(a, b)| (a..b).map(|_| (ci, MulShiftDiv::new(ci))).collect())
            .collect();
        let mut jobs: Vec<TiledPrefillJobI8> = Vec::new();
        for ((&(a, b), out), (tile, params)) in blocks
            .iter()
            .zip(outs.iter_mut())
            .zip(tiles.iter_mut().zip(params.iter()))
        {
            jobs.push(TiledPrefillJobI8 {
                q: &q.as_slice()[a * k..b * k],
                row0: a,
                mask,
                l,
                kp: &kp,
                vp: &vp,
                params,
                n1,
                out,
                tile,
                nnz: 0,
            });
        }
        let pool = tpool(4);
        par_tiled_prefill_i8(&mut jobs, &ix.lut.u8_table, &pool);
        drop(jobs);
        let got: Vec<i32> = outs.concat();
        assert_eq!(got, want.as_slice(), "row-block composition");
    }

    #[test]
    fn tiled_prefill_exaq_matches_materialized_oracle() {
        // Stats pass: exact integer moments about the final max (checked
        // against a direct reduction). PV pass at a fixed clip: bit-equal to
        // forward_with_clip_counted + paged P̂·V̂ (same f32 ops, same order).
        let mut rng = Pcg64::seed_from_u64(46);
        let ex = ExaqSoftmax::new(ExaqConfig::int3());
        let (m, l, k, d, alpha, clip) = (6usize, 41usize, 16usize, 8usize, 0.004f32, 1.6f32);
        let q = rand_i8(&mut rng, m, k);
        let kmat = rand_i8(&mut rng, l, k);
        let vmat = rand_i8(&mut rng, l, d);
        let mask = Mask::CausalFrom(l - m);
        let mut logits = MatI32::zeros(m, l);
        gemm_i8(&q, &kmat, &mut logits);
        for pr in [1usize, 2, 64] {
            let kp = split_pages(kmat.as_slice(), k, pr);
            let vp = split_pages(vmat.as_slice(), d, pr);
            let mut maxes = vec![0i32; m];
            let mut moments = vec![(0i128, 0i128, 0u64); m];
            let mut tile = vec![0i32; PREFILL_TILE_ROWS];
            let mut sjob = TiledPrefillStatsJob {
                q: q.as_slice(),
                row0: 0,
                mask,
                l,
                kp: &kp,
                maxes: &mut maxes,
                moments: &mut moments,
                tile: &mut tile,
            };
            tiled_prefill_exaq_stats(&mut sjob);
            drop(sjob);
            for r in 0..m {
                let valid = mask.valid_cols(r, l);
                let row = &logits.row(r)[..valid];
                let wm = *row.iter().max().unwrap();
                assert_eq!(maxes[r], wm, "max r={r} pr={pr}");
                let (mut ds, mut dq) = (0i128, 0i128);
                for &a in row {
                    let delta = (wm as i64 - a as i64) as i128;
                    ds += delta;
                    dq += delta * delta;
                }
                assert_eq!(moments[r], (ds, dq, valid as u64), "moments r={r} pr={pr}");
            }
            let (probs, want_nnz) = ex.forward_with_clip_counted(&logits, alpha, mask, clip);
            let mut want = MatI32::zeros(m, d);
            gemm_u8i8_paged(probs.as_slice(), &vp, want.as_mut_slice(), m, l, d);
            let lut = ex.lut_f32(clip);
            let clip_int = (clip.max(1e-3) / alpha).max(1.0);
            let mut out = vec![0i32; m * d];
            let mut job = TiledPrefillExaqJob {
                q: q.as_slice(),
                row0: 0,
                mask,
                l,
                kp: &kp,
                vp: &vp,
                maxes: &maxes,
                lut: &lut,
                clip_int,
                out: &mut out,
                tile: &mut tile,
                nnz: 0,
            };
            tiled_prefill_exaq_pv(&mut job);
            let nnz = job.nnz;
            drop(job);
            assert_eq!(out, want.as_slice(), "exaq tiled prefill pr={pr}");
            assert_eq!(nnz, want_nnz, "exaq tiled prefill nnz pr={pr}");
        }
    }

    #[test]
    fn prefill_tile_walk_covers_valid_prefix_only() {
        // The tile walk visits exactly the valid prefix, in order, in tiles
        // no wider than PREFILL_TILE_ROWS, even when a page is bigger.
        let (k, l) = (4usize, 600usize);
        let q = vec![1i8; k];
        let kbuf = vec![1i8; l * k];
        let kp = split_pages(&kbuf, k, 512); // one huge page + remainder
        let mut tile = vec![0i32; PREFILL_TILE_ROWS];
        for valid in [0usize, 1, 255, 256, 257, 512, 600] {
            let mut seen = 0usize;
            prefill_qk_tiles(&q, &kp, k, valid, &mut tile, |_, _, t| {
                assert!(t.len() <= PREFILL_TILE_ROWS);
                for &a in t {
                    assert_eq!(a, k as i32); // 1·1 dot over k lanes
                    seen += 1;
                }
            });
            assert_eq!(seen, valid, "valid={valid}");
        }
    }
}
