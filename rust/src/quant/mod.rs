//! Dynamic symmetric quantization (paper §2.1 eq. 2–3 and §3.3 eq. 16).
//!
//! Per-tensor symmetric INT8 with zero point fixed at 0:
//!
//! ```text
//! s_X = max|X| / 127
//! X̂  = clamp(round(X / s_X), −127, 127)
//! X  ≈ s_X · X̂
//! ```
//!
//! plus the per-group (per-channel / per-block) generalization of §3.3 where
//! each group `g` carries its own scale `s^(g)` and, downstream, its own
//! integer clipping threshold `c_int^(g)`.

use crate::tensor::{MatF32, MatI8, MatU8};

/// A per-tensor INT8 quantization result.
#[derive(Clone, Debug)]
pub struct QuantizedI8 {
    pub data: MatI8,
    /// The dequantization scale `s_X` (eq. 2); `X ≈ s_X · X̂`.
    pub scale: f32,
}

/// Quantize with per-tensor symmetric INT8 (eq. 2–3).
///
/// An all-zero tensor gets scale 1.0 (any scale dequantizes zeros to zeros).
pub fn quantize_i8(x: &MatF32) -> QuantizedI8 {
    let amax = x.abs_max();
    let scale = if amax == 0.0 { 1.0 } else { amax / 127.0 };
    let inv = 1.0 / scale;
    let data = x.map(|v| {
        let q = (v * inv).round();
        q.clamp(-127.0, 127.0) as i8
    });
    QuantizedI8 { data, scale }
}

/// Dequantize an INT8 tensor back to f32.
pub fn dequantize_i8(q: &QuantizedI8) -> MatF32 {
    q.data.map(|v| v as f32 * q.scale)
}

/// Quantize an FP32 probability matrix (entries in `[0,1]`) to UINT8 with
/// the paper's ×255 unsigned formulation (§3.2): `P̂ = round(255·P)`.
pub fn quantize_p_u8(p: &MatF32) -> MatU8 {
    p.map(|v| (v * 255.0).round().clamp(0.0, 255.0) as u8)
}

/// The signed-INT8 alternative the paper ablates against in Table 9:
/// `P̂ = round(127·P)` stored in `i8`, wasting the negative half-range.
pub fn quantize_p_i8(p: &MatF32) -> MatI8 {
    p.map(|v| (v * 127.0).round().clamp(-127.0, 127.0) as i8)
}

// AUDIT: int-only begin requantize-probs-i8
// This region IS the float→int boundary of the Quant-Only detour (the
// conversions `attention::counts::requantize_probs` bills, one per valid
// probability): its `f32` reads and ×127 constants are the allowlisted
// exception, and the fence pins the boundary to exactly these two helpers —
// a new float op here without an allowlist edit fails the audit.

/// [`quantize_p_i8`] that also reports the nonzero count (the PV GEMM's
/// exact zero-skipping work) so pipelines never re-scan the matrix.
pub fn quantize_p_i8_counted(p: &MatF32) -> (MatI8, u64) {
    let mut out = MatI8::zeros(p.rows(), p.cols());
    let mut nnz = 0u64;
    for (o, &v) in out.as_mut_slice().iter_mut().zip(p.as_slice()) {
        let q = (v * 127.0).round().clamp(-127.0, 127.0) as i8;
        *o = q;
        nnz += (q != 0) as u64;
    }
    (out, nnz)
}

/// Slice form of [`quantize_p_i8`] for the decode hot path: quantizes one
/// probability row into a reusable buffer and returns the nonzero count.
pub fn quantize_p_i8_into(p: &[f32], out: &mut [i8]) -> u64 {
    assert_eq!(p.len(), out.len());
    let mut nnz = 0u64;
    for (o, &v) in out.iter_mut().zip(p) {
        let q = (v * 127.0).round().clamp(-127.0, 127.0) as i8;
        *o = q;
        nnz += (q != 0) as u64;
    }
    nnz
}
// AUDIT: int-only end

/// Dequantize a ×255 UINT8 probability matrix.
pub fn dequantize_p_u8(p: &MatU8) -> MatF32 {
    p.map(|v| v as f32 / 255.0)
}

/// Dequantize a ×127 INT8 probability matrix.
pub fn dequantize_p_i8(p: &MatI8) -> MatF32 {
    p.map(|v| v as f32 / 127.0)
}

// ---------------------------------------------------------------------------
// Group-wise quantization (§3.3)

/// How to group rows/channels for finer-grained scales.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupScheme {
    /// One scale for the whole tensor (the paper's default).
    PerTensor,
    /// One scale per row (per-token for Q, per-key for K).
    PerRow,
    /// One scale per contiguous block of `block` rows.
    PerRowBlock(usize),
}

/// Group-quantized tensor: INT8 data plus one scale per group, and the
/// row→group assignment implied by the scheme.
#[derive(Clone, Debug)]
pub struct GroupQuantizedI8 {
    pub data: MatI8,
    pub scales: Vec<f32>,
    pub scheme: GroupScheme,
}

impl GroupQuantizedI8 {
    /// Group index of row `r`.
    #[inline]
    pub fn group_of_row(&self, r: usize) -> usize {
        match self.scheme {
            GroupScheme::PerTensor => 0,
            GroupScheme::PerRow => r,
            GroupScheme::PerRowBlock(b) => r / b,
        }
    }

    #[inline]
    pub fn scale_of_row(&self, r: usize) -> f32 {
        self.scales[self.group_of_row(r)]
    }

    pub fn num_groups(&self) -> usize {
        self.scales.len()
    }
}

/// Quantize with a group scheme (eq. 16's scale bookkeeping).
pub fn quantize_grouped_i8(x: &MatF32, scheme: GroupScheme) -> GroupQuantizedI8 {
    let rows = x.rows();
    let groups: usize = match scheme {
        GroupScheme::PerTensor => 1,
        GroupScheme::PerRow => rows,
        GroupScheme::PerRowBlock(b) => {
            assert!(b > 0, "block size must be positive");
            rows.div_ceil(b)
        }
    };
    // Pass 1: per-group abs-max.
    let mut amax = vec![0.0f32; groups];
    for r in 0..rows {
        let g = match scheme {
            GroupScheme::PerTensor => 0,
            GroupScheme::PerRow => r,
            GroupScheme::PerRowBlock(b) => r / b,
        };
        for &v in x.row(r) {
            amax[g] = amax[g].max(v.abs());
        }
    }
    let scales: Vec<f32> = amax
        .iter()
        .map(|&m| if m == 0.0 { 1.0 } else { m / 127.0 })
        .collect();
    // Pass 2: quantize.
    let mut data = MatI8::zeros(rows, x.cols());
    for r in 0..rows {
        let g = match scheme {
            GroupScheme::PerTensor => 0,
            GroupScheme::PerRow => r,
            GroupScheme::PerRowBlock(b) => r / b,
        };
        let inv = 1.0 / scales[g];
        let dst = data.row_mut(r);
        for (d, &v) in dst.iter_mut().zip(x.row(r)) {
            *d = (v * inv).round().clamp(-127.0, 127.0) as i8;
        }
    }
    GroupQuantizedI8 { data, scales, scheme }
}

/// Dequantize a group-quantized tensor.
pub fn dequantize_grouped_i8(q: &GroupQuantizedI8) -> MatF32 {
    let mut out = MatF32::zeros(q.data.rows(), q.data.cols());
    for r in 0..q.data.rows() {
        let s = q.scale_of_row(r);
        let dst = out.row_mut(r);
        for (d, &v) in dst.iter_mut().zip(q.data.row(r)) {
            *d = v as f32 * s;
        }
    }
    out
}

/// Quantization error metrics (used by tests and the Table 9 driver).
pub fn quant_error_i8(x: &MatF32) -> (f64, f64) {
    let q = quantize_i8(x);
    let back = dequantize_i8(&q);
    let rel = crate::util::stats::relative_l1(x.as_slice(), back.as_slice());
    let rm = crate::util::stats::rmse(x.as_slice(), back.as_slice());
    (rel, rm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn random_mat(rng: &mut Pcg64, rows: usize, cols: usize, std: f32) -> MatF32 {
        MatF32::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal_ms(0.0, std)).collect())
    }

    #[test]
    fn scale_formula_matches_paper() {
        let x = MatF32::from_vec(1, 3, vec![0.0, -2.54, 1.0]);
        let q = quantize_i8(&x);
        assert!((q.scale - 2.54 / 127.0).abs() < 1e-7);
        assert_eq!(q.data.as_slice()[1], -127);
    }

    #[test]
    fn zero_tensor_is_safe() {
        let x = MatF32::zeros(4, 4);
        let q = quantize_i8(&x);
        assert_eq!(q.scale, 1.0);
        assert!(q.data.as_slice().iter().all(|&v| v == 0));
        assert!(dequantize_i8(&q).as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn round_trip_error_bounded_by_half_step() {
        let mut rng = Pcg64::seed_from_u64(1);
        let x = random_mat(&mut rng, 16, 64, 1.0);
        let q = quantize_i8(&x);
        let back = dequantize_i8(&q);
        let half_step = q.scale / 2.0 + 1e-7;
        for (&a, &b) in x.as_slice().iter().zip(back.as_slice()) {
            assert!((a - b).abs() <= half_step, "a={a} b={b} step={}", q.scale);
        }
    }

    #[test]
    fn values_stay_in_sym_range() {
        let mut rng = Pcg64::seed_from_u64(2);
        let x = random_mat(&mut rng, 8, 8, 100.0);
        let q = quantize_i8(&x);
        assert!(q.data.as_slice().iter().all(|&v| (-127..=127).contains(&(v as i32))));
    }

    #[test]
    fn p_u8_uses_full_range() {
        let p = MatF32::from_vec(1, 3, vec![0.0, 0.5, 1.0]);
        let q = quantize_p_u8(&p);
        assert_eq!(q.as_slice(), &[0, 128, 255]);
        let back = dequantize_p_u8(&q);
        assert!(back.allclose(&p, 1.0 / 255.0, 0.0));
    }

    #[test]
    fn p_i8_wastes_half_range() {
        let p = MatF32::from_vec(1, 2, vec![0.0, 1.0]);
        let q = quantize_p_i8(&p);
        assert_eq!(q.as_slice(), &[0, 127]);
    }

    #[test]
    fn p_i8_counted_and_into_match_map_form() {
        let mut rng = Pcg64::seed_from_u64(9);
        let p = random_mat(&mut rng, 3, 17, 0.02).map(f32::abs);
        let want = quantize_p_i8(&p);
        let (got, nnz) = quantize_p_i8_counted(&p);
        assert_eq!(got, want);
        assert_eq!(nnz, want.as_slice().iter().filter(|&&x| x != 0).count() as u64);
        let mut row = vec![0i8; 17];
        let row_nnz = quantize_p_i8_into(p.row(1), &mut row);
        assert_eq!(&row[..], want.row(1));
        assert_eq!(row_nnz, row.iter().filter(|&&x| x != 0).count() as u64);
    }

    #[test]
    fn u8_p_quant_beats_i8_on_probabilities() {
        // The Table 9 claim at unit level: for a normalized probability row,
        // UINT8(×255) has lower RMSE than INT8(×127).
        let mut rng = Pcg64::seed_from_u64(3);
        let logits: Vec<f32> = (0..256).map(|_| rng.normal_ms(0.0, 2.0)).collect();
        let m = logits.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
        let z: f32 = exps.iter().sum();
        let p = MatF32::from_vec(1, 256, exps.iter().map(|&e| e / z).collect());
        let u8_err = crate::util::stats::rmse(
            p.as_slice(),
            dequantize_p_u8(&quantize_p_u8(&p)).as_slice(),
        );
        let i8_err = crate::util::stats::rmse(
            p.as_slice(),
            dequantize_p_i8(&quantize_p_i8(&p)).as_slice(),
        );
        assert!(u8_err < i8_err, "u8={u8_err} i8={i8_err}");
    }

    #[test]
    fn per_row_groups_have_row_count_scales() {
        let mut rng = Pcg64::seed_from_u64(4);
        let x = random_mat(&mut rng, 6, 8, 1.0);
        let q = quantize_grouped_i8(&x, GroupScheme::PerRow);
        assert_eq!(q.num_groups(), 6);
        assert_eq!(q.group_of_row(5), 5);
    }

    #[test]
    fn per_block_groups_round_up() {
        let mut rng = Pcg64::seed_from_u64(5);
        let x = random_mat(&mut rng, 10, 4, 1.0);
        let q = quantize_grouped_i8(&x, GroupScheme::PerRowBlock(4));
        assert_eq!(q.num_groups(), 3);
        assert_eq!(q.group_of_row(9), 2);
    }

    #[test]
    fn per_tensor_group_matches_plain_quantize() {
        let mut rng = Pcg64::seed_from_u64(6);
        let x = random_mat(&mut rng, 5, 7, 2.0);
        let a = quantize_i8(&x);
        let b = quantize_grouped_i8(&x, GroupScheme::PerTensor);
        assert_eq!(a.data, b.data);
        assert_eq!(b.scales.len(), 1);
        assert!((a.scale - b.scales[0]).abs() < 1e-9);
    }

    #[test]
    fn grouped_round_trip_improves_on_outlier_rows() {
        // A tensor with one huge-magnitude row: per-row scales must give a
        // strictly better reconstruction of the small rows.
        let mut rng = Pcg64::seed_from_u64(7);
        let mut x = random_mat(&mut rng, 4, 32, 0.1);
        for v in x.row_mut(0) {
            *v *= 1000.0;
        }
        let per_tensor = dequantize_grouped_i8(&quantize_grouped_i8(&x, GroupScheme::PerTensor));
        let per_row = dequantize_grouped_i8(&quantize_grouped_i8(&x, GroupScheme::PerRow));
        let err_t = crate::util::stats::rmse(x.row(2), &per_tensor.as_slice()[2 * 32..3 * 32]);
        let err_r = crate::util::stats::rmse(x.row(2), &per_row.as_slice()[2 * 32..3 * 32]);
        assert!(err_r < err_t, "per-row {err_r} vs per-tensor {err_t}");
    }

    #[test]
    fn quant_error_metrics_sane() {
        let mut rng = Pcg64::seed_from_u64(8);
        let x = random_mat(&mut rng, 32, 32, 1.0);
        let (rel, rm) = quant_error_i8(&x);
        assert!(rel > 0.0 && rel < 0.02, "rel={rel}");
        assert!(rm > 0.0 && rm < 0.02, "rmse={rm}");
    }
}
