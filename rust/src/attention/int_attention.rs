//! **IntAttention** — the paper's contribution (§3, Figure 3): a contiguous
//! integer dataflow from the `Q̂K̂ᵀ` logits to the `P̂V̂` aggregation.
//!
//! Stage structure (contrast with `quant_only.rs` — the Dequantize and
//! Requantize stages are *gone*):
//!   1. Quantize — dynamic per-tensor INT8 of Q, K, V (eq. 2–3)
//!   2. QkGemm   — `Â = Q̂K̂ᵀ` in i8×i8→i32 (eq. 4)
//!   3. Softmax  — **IndexSoftmax** (eq. 7–15): integer clipping, 32-entry
//!                 UINT8 LUT, integer normalization → UINT8 `P̂`
//!   4. PvGemm   — `P̂·V̂` in u8×i8→i32, skipping clipped-to-zero entries
//!   5. Output   — `O = (s_V/255)·(P̂V̂)` (the only float op, once per output
//!                 element, outside the attention loop — eq. 5 + eq. 15 scale)
//!
//! Supports per-tensor (default) and grouped (§3.3) quantization of Q.
//!
//! Stateful paths are prefix-sharing safe: K̂/V̂ reads go through
//! `page_list()` descriptors (the grouped decode GEMMs tolerate pages
//! shared copy-on-write with other sequences), and both mutations —
//! append-quantize and the running-scale re-map — fork shared pages before
//! writing (see `crate::attention::state`).

use crate::attention::state::{Int8KvState, KvState};
use crate::attention::{
    batch_output_rescale, batch_rows, counts, validate_batch_shapes, validate_shapes,
    validate_state_shapes, AttentionConfig, AttentionPipeline, PipelineKind,
};
use crate::energy::OpCounts;
use crate::gemm::{
    decode_split_spans, gemm_u8i8, gemm_u8i8_paged, par_fused_decode_i8_spans, par_gemm_i8,
    par_gemm_i8_grouped, par_gemm_i8_paged, par_gemm_u8i8_grouped, par_tiled_prefill_i8,
    FusedJobI8, GroupI8, GroupU8I8, TiledPrefillJobI8, PREFILL_TILE_ROWS, ROW_BLOCK,
};
use crate::quant::{
    quantize_grouped_i8, quantize_i8, GroupQuantizedI8, GroupScheme, QuantizedI8,
};
use crate::softmax::index_softmax::{IndexSoftmax, Mask, MulShiftDiv};
use crate::tensor::{MatF32, MatI32, MatI8, MatU8};
use crate::util::timer::{Stage, StageTimes};

/// Q quantized under the configured scheme, plus the IndexSoftmax dispatch
/// that pairs with it — shared by the one-shot and stateful paths so the
/// grouped-Q handling can never drift between them.
enum QQuant {
    PerTensor(QuantizedI8),
    Grouped(GroupQuantizedI8),
}

impl QQuant {
    fn quantize(q: &MatF32, scheme: GroupScheme) -> QQuant {
        match scheme {
            GroupScheme::PerTensor => QQuant::PerTensor(quantize_i8(q)),
            s => QQuant::Grouped(quantize_grouped_i8(q, s)),
        }
    }

    fn data(&self) -> &MatI8 {
        match self {
            QQuant::PerTensor(t) => &t.data,
            QQuant::Grouped(g) => &g.data,
        }
    }

    /// IndexSoftmax over `logits` with this Q's scale(s) × `k_scale`/√d.
    /// Also returns the nonzero-`P̂` count (the PV GEMM's exact work) so
    /// callers never re-scan the probability matrix.
    fn softmax(
        &self,
        softmax: &IndexSoftmax,
        logits: &MatI32,
        k_scale: f32,
        sqrt_d: f32,
        mask: Mask,
    ) -> (MatU8, u64) {
        match self {
            QQuant::PerTensor(t) => {
                let alpha = t.scale * k_scale / sqrt_d;
                let mut out = MatU8::zeros(logits.rows(), logits.cols());
                let nnz = softmax.forward_into(logits, alpha, mask, &mut out);
                (out, nnz)
            }
            QQuant::Grouped(g) => {
                let alphas: Vec<f32> =
                    g.scales.iter().map(|&s| s * k_scale / sqrt_d).collect();
                let scheme = g.scheme;
                softmax.forward_grouped(
                    logits,
                    move |r| match scheme {
                        GroupScheme::PerTensor => 0,
                        GroupScheme::PerRow => r,
                        GroupScheme::PerRowBlock(b) => r / b,
                    },
                    &alphas,
                    mask,
                )
            }
        }
    }

    /// The `α` of this (single-row) decode query: a decode block has exactly
    /// one row, so every grouped scheme maps it to group 0 — identical to
    /// what [`Self::softmax`] would derive for row 0.
    fn decode_alpha(&self, k_scale: f32, sqrt_d: f32) -> f32 {
        match self {
            QQuant::PerTensor(t) => t.scale * k_scale / sqrt_d,
            QQuant::Grouped(g) => g.scales[0] * k_scale / sqrt_d,
        }
    }

    /// Per-row `(c_int, idx_div)` IndexSoftmax parameters for the tiled
    /// prefill walk — row `r`'s group under the configured scheme, so the
    /// tiled path derives exactly the dividers [`Self::softmax`] would.
    fn row_params(
        &self,
        softmax: &IndexSoftmax,
        k_scale: f32,
        sqrt_d: f32,
        rows: usize,
    ) -> Vec<(u64, MulShiftDiv)> {
        let of = |alpha: f32| {
            let ci = softmax.c_int(alpha) as u64;
            (ci, MulShiftDiv::new(ci))
        };
        match self {
            QQuant::PerTensor(t) => vec![of(t.scale * k_scale / sqrt_d); rows],
            QQuant::Grouped(g) => {
                let group: Vec<(u64, MulShiftDiv)> =
                    g.scales.iter().map(|&s| of(s * k_scale / sqrt_d)).collect();
                let scheme = g.scheme;
                (0..rows)
                    .map(|r| match scheme {
                        GroupScheme::PerTensor => group[0],
                        GroupScheme::PerRow => group[r],
                        GroupScheme::PerRowBlock(bsz) => group[r / bsz],
                    })
                    .collect()
            }
        }
    }
}

pub struct IntAttention {
    cfg: AttentionConfig,
    softmax: IndexSoftmax,
    /// Quantization granularity for Q (K and V stay per-tensor; §3.3 notes
    /// only the Q/K scales enter `c_int`, and per-row Q is the common
    /// fine-grained deployment).
    pub q_scheme: GroupScheme,
    times: StageTimes,
    ops: OpCounts,
    /// Reusable decode-step scratch: the unfused path's flat logit/prob/acc
    /// rows and the fused path's i64 accumulators + page tiles. Capacity
    /// grows to the working batch shape once, then every decode step runs
    /// allocation-free (asserted in `tests/fused_decode.rs`).
    dec_logits: Vec<i32>,
    dec_probs: Vec<u8>,
    dec_acc: Vec<i32>,
    dec_facc: Vec<i64>,
    dec_tile: Vec<i32>,
}

impl IntAttention {
    pub fn new(cfg: AttentionConfig) -> Self {
        IntAttention {
            softmax: IndexSoftmax::new(cfg.isx),
            cfg,
            q_scheme: GroupScheme::PerTensor,
            times: StageTimes::new(),
            ops: OpCounts::default(),
            dec_logits: Vec::new(),
            dec_probs: Vec::new(),
            dec_acc: Vec::new(),
            dec_facc: Vec::new(),
            dec_tile: Vec::new(),
        }
    }

    /// Enable grouped Q quantization (per-row or per-row-block, §3.3).
    pub fn with_q_scheme(mut self, scheme: GroupScheme) -> Self {
        self.q_scheme = scheme;
        self
    }

    /// The UINT8 probability matrix of the last forward (for fidelity
    /// evaluations like Table 9); recomputed on demand.
    pub fn probabilities(&self, q: &MatF32, k: &MatF32) -> MatU8 {
        let d = self.cfg.head_dim;
        let qq = quantize_i8(q);
        let kq = quantize_i8(k);
        let mut logits = MatI32::zeros(q.rows(), k.rows());
        par_gemm_i8(&qq.data, &kq.data, &mut logits, self.cfg.pool);
        let alpha = qq.scale * kq.scale / (d as f32).sqrt();
        self.softmax.forward(&logits, alpha, self.cfg.mask)
    }
}

impl AttentionPipeline for IntAttention {
    fn kind(&self) -> PipelineKind {
        PipelineKind::IntAttention
    }

    fn config(&self) -> &AttentionConfig {
        &self.cfg
    }

    fn forward(&mut self, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_shapes(&self.cfg, q, k, v);
        let (m, l, d) = (q.rows(), self.cfg.seq_len, self.cfg.head_dim);
        let pool = self.cfg.pool;
        let sqrt_d = (d as f32).sqrt();

        // (1) dynamic quantization (grouped for Q if configured).
        let (qq, kq, vq) = self.times.measure(Stage::Quantize, || {
            (QQuant::quantize(q, self.q_scheme), quantize_i8(k), quantize_i8(v))
        });
        self.ops.add(&counts::quantize_qkv(m, l, d));

        // (2) integer similarity GEMM.
        let mut logits = MatI32::zeros(m, l);
        self.times.measure(Stage::QkGemm, || {
            par_gemm_i8(qq.data(), &kq.data, &mut logits, pool);
        });
        self.ops.add(&counts::qk_gemm(m, l, d, 1, 4));

        // (3) IndexSoftmax — integer in, UINT8 out. No Dequantize stage,
        // no Requantize stage: this is the paper's point. The operator
        // reports the nonzero-P̂ count as it normalizes — no re-scan.
        let (p, nnz) = self.times.measure(Stage::Softmax, || {
            qq.softmax(&self.softmax, &logits, kq.scale, sqrt_d, self.cfg.mask)
        });
        let valid = counts::valid_positions(m, l, self.cfg.mask);
        self.ops.add(&counts::index_softmax(valid, m as u64));

        // (4) integer aggregation GEMM (u8 × i8 → i32), zero-skipping.
        let mut acc = MatI32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            gemm_u8i8(&p, &vq.data, &mut acc);
        });
        self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));

        // (5) single output rescale: s_V/255 (eq. 5 with the ×255 P scale).
        let out_scale = vq.scale / 255.0;
        let o = self
            .times
            .measure(Stage::Output, || acc.map(|x| x as f32 * out_scale));
        self.ops.add(&counts::output_rescale(m, d));
        o
    }

    /// Stateful block forward: the integer dataflow of [`Self::forward`],
    /// but K̂/V̂ live in the INT8 state — only the new rows are quantized,
    /// and history is never copied, dequantized or re-quantized.
    fn prefill(&mut self, state: &mut KvState, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_state_shapes(&self.cfg, state, q, k, v);
        let (m, d) = (q.rows(), self.cfg.head_dim);
        let pool = self.cfg.pool;
        let sqrt_d = (d as f32).sqrt();

        // (1) quantize the query block fresh; append-quantize only the new
        // K/V rows (the state re-scales resident rows only if their running
        // abs-max grew — see `Int8Side::append`).
        let q_scheme = self.q_scheme;
        let (qq, remapped) = self.times.measure(Stage::Quantize, || {
            let remapped = state.append(k, v);
            (QQuant::quantize(q, q_scheme), remapped)
        });
        self.ops.add(&counts::quantize_qkv(m, k.rows(), d));
        if remapped > 0 {
            self.ops.add(&counts::kv_rescale(remapped as u64));
        }

        let st = state.as_int8();
        let l = st.len();
        let mask = Mask::CausalFrom(l - m);
        let k_pages = st.k.data.page_list();

        if self.cfg.tiled_prefill {
            // Online-tiled prefill: per query row, three bounded-tile passes
            // over the K̂/V̂ page walk (max, ΣÊ, normalize+P̂V̂) — no m×L
            // score block at any context length, bit-identical output to
            // the materialized path below (see `crate::gemm` module docs).
            // Row blocks fan out across the pool.
            let v_pages = st.v.data.page_list();
            let params = qq.row_params(&self.softmax, st.k.scale, sqrt_d, m);
            let n1 = self.softmax.lut.max_index() as u64;
            let table = &self.softmax.lut.u8_table;
            let qdata = qq.data().as_slice();
            let blocks: Vec<(usize, usize)> = (0..m)
                .step_by(ROW_BLOCK)
                .map(|r0| (r0, (r0 + ROW_BLOCK).min(m)))
                .collect();
            let mut out_i32 = vec![0i32; m * d];
            let mut tiles = vec![0i32; blocks.len() * PREFILL_TILE_ROWS];
            let mut jobs: Vec<TiledPrefillJobI8> = Vec::with_capacity(blocks.len());
            let mut out_rest: &mut [i32] = &mut out_i32;
            let mut tile_rest: &mut [i32] = &mut tiles;
            for &(a, bb) in &blocks {
                let (orow, orest) = out_rest.split_at_mut((bb - a) * d);
                out_rest = orest;
                let (tl, tr) = tile_rest.split_at_mut(PREFILL_TILE_ROWS);
                tile_rest = tr;
                jobs.push(TiledPrefillJobI8 {
                    q: &qdata[a * d..bb * d],
                    row0: a,
                    mask,
                    l,
                    kp: &k_pages,
                    vp: &v_pages,
                    params: &params[a..bb],
                    n1,
                    out: orow,
                    tile: tl,
                    nnz: 0,
                });
            }
            // One launch covers QK, softmax and P̂V̂; booked under QkGemm
            // (the dominating stage) like the fused decode walk. Op counts
            // still split per operator: the row is recomputed three times,
            // so three QK walks are billed.
            self.times.measure(Stage::QkGemm, || {
                par_tiled_prefill_i8(&mut jobs, table, pool);
            });
            let nnz: u64 = jobs.iter().map(|j| j.nnz).sum();
            drop(jobs);
            for _ in 0..3 {
                self.ops.add(&counts::qk_gemm(m, l, d, 1, 4));
            }
            let valid = counts::valid_positions(m, l, mask);
            self.ops.add(&counts::index_softmax(valid, m as u64));
            self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));

            let out_scale = st.v.scale / 255.0;
            let o = self.times.measure(Stage::Output, || {
                let mut o = MatF32::zeros(m, d);
                for (ov, &av) in o.as_mut_slice().iter_mut().zip(&out_i32) {
                    *ov = av as f32 * out_scale;
                }
                o
            });
            self.ops.add(&counts::output_rescale(m, d));
            return o;
        }

        // (2) Q̂·K̂ᵀ against the resident INT8 keys — walking the K̂ page
        // list in place (an O(pages) pointer descriptor, never a copy).
        let mut logits = MatI32::zeros(m, l);
        self.times.measure(Stage::QkGemm, || {
            par_gemm_i8_paged(qq.data().as_slice(), &k_pages, logits.as_mut_slice(), m, l, d, pool);
        });
        self.ops.add(&counts::qk_gemm(m, l, d, 1, 4));

        // (3) IndexSoftmax with the offset-causal mask (a chunked-prefill
        // block sees the whole history up to each row's position).
        let (p, nnz) = self.times.measure(Stage::Softmax, || {
            qq.softmax(&self.softmax, &logits, st.k.scale, sqrt_d, mask)
        });
        let valid = counts::valid_positions(m, l, mask);
        self.ops.add(&counts::index_softmax(valid, m as u64));

        // (4) P̂·V̂ from the resident INT8 value pages, zero-skipping.
        let v_pages = st.v.data.page_list();
        let mut acc = MatI32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            gemm_u8i8_paged(p.as_slice(), &v_pages, acc.as_mut_slice(), m, l, d);
        });
        self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));

        // (5) single output rescale with the state's running V scale.
        let out_scale = st.v.scale / 255.0;
        let o = self
            .times
            .measure(Stage::Output, || acc.map(|x| x as f32 * out_scale));
        self.ops.add(&counts::output_rescale(m, d));
        o
    }

    /// Single-sequence decode is batched decode with one lane: routing it
    /// through [`Self::decode_step_batch`] keeps one code path (fused or
    /// unfused by `cfg.fused_decode`) and reuses the same scratch buffers.
    fn decode_step(
        &mut self,
        state: &mut KvState,
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        debug_assert_eq!(q.rows(), 1, "decode_step takes a single query row");
        self.decode_step_batch(&mut [state], q, k_new, v_new)
    }

    /// Batched decode over the grouped integer kernels. Per sequence this is
    /// bit-identical to single-lane [`AttentionPipeline::decode_step`]:
    /// quantization, running scales and IndexSoftmax thresholds stay
    /// per-sequence — only the launches are grouped, the kernels are walked
    /// sequentially per sequence, and integer arithmetic is exact.
    ///
    /// With `cfg.fused_decode` set (the default) each sequence runs the
    /// two-phase fused walk — `Q̂K̂ᵀ` tiles through the max fold, then a
    /// zipped re-walk gathering `Ê·V̂` against the pinned max — never
    /// materializing an L-length score row, and `cfg.decode_split` page
    /// spans per sequence fan the walk itself across the pool with exact
    /// integer merges (see the module docs of `crate::attention` for the
    /// fidelity contract against the unfused oracle).
    fn decode_step_batch(
        &mut self,
        states: &mut [&mut KvState],
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        validate_batch_shapes(&self.cfg, states, q, k_new, v_new);
        let b = states.len();
        let d = self.cfg.head_dim;
        if b == 0 {
            return MatF32::zeros(0, d);
        }
        let pool = self.cfg.pool;
        let sqrt_d = (d as f32).sqrt();
        let q_scheme = self.q_scheme;

        // (1) per-sequence: append-quantize the new K/V row into each
        // resident state, quantize each query row against its own scale.
        let rows = batch_rows(q, k_new, v_new);
        let (qqs, remapped) = self.times.measure(Stage::Quantize, || {
            let mut remapped = 0usize;
            let mut qqs = Vec::with_capacity(b);
            for (st, (qr, kr, vr)) in states.iter_mut().zip(&rows) {
                remapped += st.append(kr, vr);
                qqs.push(QQuant::quantize(qr, q_scheme));
            }
            (qqs, remapped)
        });
        for _ in 0..b {
            self.ops.add(&counts::quantize_qkv(1, 1, d));
        }
        if remapped > 0 {
            self.ops.add(&counts::kv_rescale(remapped as u64));
        }

        let ints: Vec<&Int8KvState> = states.iter().map(|st| st.as_int8()).collect();
        let ls: Vec<usize> = ints.iter().map(|s| s.len()).collect();
        let k_pages: Vec<Vec<&[i8]>> = ints.iter().map(|s| s.k.data.page_list()).collect();
        let v_pages: Vec<Vec<&[i8]>> = ints.iter().map(|s| s.v.data.page_list()).collect();

        if self.cfg.fused_decode {
            // Fused flash-decode, span-parallel: each sequence's resident
            // page list splits into `decode_split_spans` contiguous page
            // spans (subslices of the page list — no copies), each span a
            // job with its own online row + O(d) accumulator, merged
            // exactly after the two-phase walk. Working set per span is the
            // i64 accumulator plus a QK tile the size of its widest page —
            // no L-length row anywhere.
            let split = self.cfg.decode_split;
            let spans: Vec<usize> = k_pages
                .iter()
                .map(|kp| decode_split_spans(split, kp.len(), pool.size(), b))
                .collect();
            let total_spans: usize = spans.iter().sum();
            // (sequence, first page, one-past-last page) per span, balanced
            // by page count.
            let mut cuts: Vec<(usize, usize, usize)> = Vec::with_capacity(total_spans);
            for (i, (&n, kp)) in spans.iter().zip(&k_pages).enumerate() {
                let (base, extra) = (kp.len() / n, kp.len() % n);
                let mut at = 0;
                for s in 0..n {
                    let take = base + usize::from(s < extra);
                    cuts.push((i, at, at + take));
                    at += take;
                }
            }
            let tile_rows: Vec<usize> = cuts
                .iter()
                .map(|&(i, a, e)| k_pages[i][a..e].iter().map(|p| p.len() / d).max().unwrap_or(0))
                .collect();
            let tile_total: usize = tile_rows.iter().sum();
            let mut facc = std::mem::take(&mut self.dec_facc);
            let mut tile = std::mem::take(&mut self.dec_tile);
            facc.clear();
            facc.resize(total_spans * d, 0);
            tile.clear();
            tile.resize(tile_total, 0);

            let softmax = &self.softmax;
            let mut jobs: Vec<FusedJobI8> = Vec::with_capacity(total_spans);
            let mut acc_rest: &mut [i64] = &mut facc;
            let mut tile_rest: &mut [i32] = &mut tile;
            for (ci, &(i, a, e)) in cuts.iter().enumerate() {
                let (acc, ar) = acc_rest.split_at_mut(d);
                acc_rest = ar;
                let (tl, tr) = tile_rest.split_at_mut(tile_rows[ci]);
                tile_rest = tr;
                jobs.push(FusedJobI8 {
                    q: qqs[i].data().as_slice(),
                    kp: &k_pages[i][a..e],
                    vp: &v_pages[i][a..e],
                    row: softmax.online_begin(qqs[i].decode_alpha(ints[i].k.scale, sqrt_d)),
                    acc,
                    tile: tl,
                });
            }

            // The whole walk (QK tiles, online softmax, Ê·V̂ accumulation)
            // is one schedule of launches; it is booked under QkGemm, the
            // stage that dominates it. The op counters still split per
            // operator — the K̂ pages are walked twice (max phase + gather
            // phase), so two QK walks are billed.
            let table = &softmax.lut.u8_table;
            self.times.measure(Stage::QkGemm, || {
                par_fused_decode_i8_spans(&mut jobs, &spans, table, pool);
            });
            // Each sequence's merged result lives in its first span job.
            let mut firsts: Vec<usize> = Vec::with_capacity(b);
            let mut at = 0;
            for &n in &spans {
                firsts.push(at);
                at += n;
            }
            for (&f, &l) in firsts.iter().zip(&ls) {
                self.ops.add(&counts::qk_gemm(1, l, d, 1, 4));
                self.ops.add(&counts::qk_gemm(1, l, d, 1, 4));
                self.ops.add(&counts::index_softmax(l as u64, 1));
                self.ops.add(&counts::pv_gemm(jobs[f].row.nnz(), l, d, 1, 4));
            }

            // Final per-lane normalize `round(255·acc/ΣÊ)` and the single
            // float rescale — the only rounding the fused path applies.
            // AUDIT: int-only begin int-decode-output-rescale
            // (`s_V/255` and the one `as f32` per output lane are the
            //  allowlisted boundary conversions `counts::output_rescale`
            //  bills — everything upstream of this closure is integer.)
            let o = self.times.measure(Stage::Output, || {
                let mut out = MatF32::zeros(b, d);
                for ((&f, s), orow) in
                    firsts.iter().zip(&ints).zip(out.as_mut_slice().chunks_mut(d))
                {
                    let job = &jobs[f];
                    let nd = job.row.norm_div();
                    let out_scale = s.v.scale / 255.0;
                    for (ov, &av) in orow.iter_mut().zip(job.acc.iter()) {
                        let pv = if av >= 0 {
                            nd.div_round(255 * av as u64) as i64
                        } else {
                            -(nd.div_round(255 * (-av) as u64) as i64)
                        };
                        *ov = pv as f32 * out_scale;
                    }
                }
                out
            });
            // AUDIT: int-only end
            for _ in 0..b {
                self.ops.add(&counts::output_rescale(1, d));
            }
            drop(jobs);
            self.dec_facc = facc;
            self.dec_tile = tile;
            return o;
        }

        // ------------------------- unfused oracle -------------------------
        // (2) one grouped Q̂·K̂ᵀ launch over the B resident K̂ page lists
        // into one flat reusable logit buffer (per-sequence spans).
        let total: usize = ls.iter().sum();
        let mut logits = std::mem::take(&mut self.dec_logits);
        let mut probs = std::mem::take(&mut self.dec_probs);
        let mut acc = std::mem::take(&mut self.dec_acc);
        logits.clear();
        logits.resize(total, 0);
        probs.clear();
        probs.resize(total, 0);
        acc.clear();
        acc.resize(b * d, 0);

        self.times.measure(Stage::QkGemm, || {
            let mut groups: Vec<GroupI8> = Vec::with_capacity(b);
            let mut rest: &mut [i32] = &mut logits;
            for (qq, (kp, &l)) in qqs.iter().zip(k_pages.iter().zip(&ls)) {
                let (lg, r) = rest.split_at_mut(l);
                rest = r;
                groups.push(GroupI8 { a: qq.data().as_slice(), b: kp, out: lg });
            }
            par_gemm_i8_grouped(&mut groups, d, pool);
        });
        for &l in &ls {
            self.ops.add(&counts::qk_gemm(1, l, d, 1, 4));
        }

        // (3) per-sequence IndexSoftmax: each sequence keeps its own α (its
        // Q/K scales; a decode row is group 0 under every grouped scheme).
        // A decode row at offset L−1 sees the whole history, so the row form
        // needs no mask. Nonzero counts come back with the normalize pass.
        // AUDIT: int-only begin int-decode-softmax
        let nnzs: Vec<u64> = self.times.measure(Stage::Softmax, || {
            let softmax = &self.softmax;
            let mut nnzs = Vec::with_capacity(b);
            let mut lg_rest: &[i32] = &logits;
            let mut pr_rest: &mut [u8] = &mut probs;
            for (qq, (s, &l)) in qqs.iter().zip(ints.iter().zip(&ls)) {
                let (lg, lr) = lg_rest.split_at(l);
                lg_rest = lr;
                let (pr, prr) = pr_rest.split_at_mut(l);
                pr_rest = prr;
                nnzs.push(softmax.forward_row_into(lg, qq.decode_alpha(s.k.scale, sqrt_d), pr));
            }
            nnzs
        });
        // AUDIT: int-only end
        for &l in &ls {
            self.ops.add(&counts::index_softmax(l as u64, 1));
        }

        // (4) one grouped P̂·V̂ launch over the B resident V̂ page lists.
        self.times.measure(Stage::PvGemm, || {
            let mut groups: Vec<GroupU8I8> = Vec::with_capacity(b);
            let mut pr_rest: &[u8] = &probs;
            for ((vp, &l), out) in v_pages.iter().zip(&ls).zip(acc.chunks_mut(d)) {
                let (pr, r) = pr_rest.split_at(l);
                pr_rest = r;
                groups.push(GroupU8I8 { a: pr, b: vp, out });
            }
            par_gemm_u8i8_grouped(&mut groups, d, pool);
        });
        for (&nnz, &l) in nnzs.iter().zip(&ls) {
            self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));
        }

        // (5) per-sequence output rescale with each state's running V scale.
        let o = self
            .times
            .measure(Stage::Output, || {
                batch_output_rescale(&acc, d, |i| ints[i].v.scale / 255.0)
            });
        for _ in 0..b {
            self.ops.add(&counts::output_rescale(1, d));
        }
        self.dec_logits = logits;
        self.dec_probs = probs;
        self.dec_acc = acc;
        o
    }

    fn stage_times(&self) -> &StageTimes {
        &self.times
    }

    fn op_counts(&self) -> &OpCounts {
        &self.ops
    }

    fn reset_stats(&mut self) {
        self.times.reset();
        self.ops = OpCounts::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::fp32::reference_attention;
    use crate::util::prng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
        MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn close_to_fp32_reference() {
        let mut rng = Pcg64::seed_from_u64(1);
        let cfg = AttentionConfig::new(64, 32);
        let q = rand_mat(&mut rng, 32, 32);
        let k = rand_mat(&mut rng, 64, 32);
        let v = rand_mat(&mut rng, 64, 32);
        let got = IntAttention::new(cfg).forward(&q, &k, &v);
        let want = reference_attention(&q, &k, &v, Mask::None);
        let cos = crate::util::stats::cosine_similarity(got.as_slice(), want.as_slice());
        assert!(cos > 0.99, "cos={cos}");
    }

    #[test]
    fn causal_close_to_reference() {
        let mut rng = Pcg64::seed_from_u64(2);
        let cfg = AttentionConfig::new(48, 16).causal();
        let q = rand_mat(&mut rng, 48, 16);
        let k = rand_mat(&mut rng, 48, 16);
        let v = rand_mat(&mut rng, 48, 16);
        let got = IntAttention::new(cfg).forward(&q, &k, &v);
        let want = reference_attention(&q, &k, &v, Mask::Causal);
        let cos = crate::util::stats::cosine_similarity(got.as_slice(), want.as_slice());
        assert!(cos > 0.99, "cos={cos}");
    }

    #[test]
    fn no_detour_stages() {
        let mut rng = Pcg64::seed_from_u64(3);
        let cfg = AttentionConfig::new(64, 32);
        let q = rand_mat(&mut rng, 64, 32);
        let k = rand_mat(&mut rng, 64, 32);
        let v = rand_mat(&mut rng, 64, 32);
        let mut pipe = IntAttention::new(cfg);
        let _ = pipe.forward(&q, &k, &v);
        // No dequantize, no requantize — the defining property.
        assert_eq!(pipe.stage_times().get_ns(Stage::Dequantize), 0);
        assert_eq!(pipe.stage_times().get_ns(Stage::Requantize), 0);
        assert!(pipe.stage_times().get_ns(Stage::Softmax) > 0);
        // No float exponentials in the op mix.
        assert_eq!(pipe.op_counts().fp32_exp, 0);
        assert!(pipe.op_counts().lut_gather > 0);
    }

    #[test]
    fn grouped_q_still_accurate() {
        let mut rng = Pcg64::seed_from_u64(4);
        let cfg = AttentionConfig::new(32, 16);
        let q = rand_mat(&mut rng, 32, 16);
        let k = rand_mat(&mut rng, 32, 16);
        let v = rand_mat(&mut rng, 32, 16);
        let want = reference_attention(&q, &k, &v, Mask::None);
        for scheme in [GroupScheme::PerRow, GroupScheme::PerRowBlock(8)] {
            let got = IntAttention::new(cfg).with_q_scheme(scheme).forward(&q, &k, &v);
            let cos = crate::util::stats::cosine_similarity(got.as_slice(), want.as_slice());
            assert!(cos > 0.99, "{scheme:?}: cos={cos}");
        }
    }

    #[test]
    fn grouped_q_helps_with_row_outliers() {
        // A Q with one extreme-magnitude row: per-row scales must beat
        // per-tensor on the *other* rows' outputs (the §3.3 motivation).
        let mut rng = Pcg64::seed_from_u64(5);
        let cfg = AttentionConfig::new(32, 16);
        let mut q = rand_mat(&mut rng, 32, 16);
        for x in q.row_mut(0) {
            *x *= 500.0;
        }
        let k = rand_mat(&mut rng, 32, 16);
        let v = rand_mat(&mut rng, 32, 16);
        let want = reference_attention(&q, &k, &v, Mask::None);
        let got_pt = IntAttention::new(cfg).forward(&q, &k, &v);
        let got_pr = IntAttention::new(cfg)
            .with_q_scheme(GroupScheme::PerRow)
            .forward(&q, &k, &v);
        let tail = |m: &MatF32| m.as_slice()[16..].to_vec(); // rows 1.. only
        let err_pt = crate::util::stats::rmse(&tail(&want), &tail(&got_pt));
        let err_pr = crate::util::stats::rmse(&tail(&want), &tail(&got_pr));
        assert!(err_pr < err_pt, "per-row {err_pr} !< per-tensor {err_pt}");
    }

    fn rows_of(m: &MatF32, r0: usize, r1: usize) -> MatF32 {
        let c = m.cols();
        MatF32::from_vec(r1 - r0, c, m.as_slice()[r0 * c..r1 * c].to_vec())
    }

    #[test]
    fn stateful_prefill_matches_one_shot() {
        let mut rng = Pcg64::seed_from_u64(7);
        let (l, d) = (48, 16);
        let q = rand_mat(&mut rng, l, d);
        let k = rand_mat(&mut rng, l, d);
        let v = rand_mat(&mut rng, l, d);
        let want = IntAttention::new(AttentionConfig::new(l, d).causal()).forward(&q, &k, &v);
        let mut pipe = IntAttention::new(AttentionConfig::new(l, d));
        let mut st = pipe.begin_state();
        let o1 = pipe.prefill(&mut st, &rows_of(&q, 0, 24), &rows_of(&k, 0, 24), &rows_of(&v, 0, 24));
        let o2 = pipe.prefill(&mut st, &rows_of(&q, 24, 48), &rows_of(&k, 24, 48), &rows_of(&v, 24, 48));
        assert_eq!(st.len(), 48);
        let got: Vec<f32> = o1.as_slice().iter().chain(o2.as_slice()).cloned().collect();
        let cos = crate::util::stats::cosine_similarity(&got, want.as_slice());
        assert!(cos > 0.999, "chunked prefill vs one-shot: cos={cos}");
    }

    #[test]
    fn decode_step_quantize_work_is_constant_in_context_length() {
        // The tentpole invariant: a decode step converts only the new row
        // (and the output), so its dtype-conversion count must not depend on
        // how much history is cached.
        let mut rng = Pcg64::seed_from_u64(8);
        let d = 16;
        let mut pipe = IntAttention::new(AttentionConfig::new(32, d));
        let mut st = pipe.begin_state();
        let block = rand_mat(&mut rng, 32, d);
        let _ = pipe.prefill(&mut st, &block, &block, &block);
        let mut deltas = Vec::new();
        let mut prev = pipe.op_counts().dtype_conv;
        for _ in 0..3 {
            let q1 = rand_mat(&mut rng, 1, d);
            // Damped K/V rows keep the running amax flat, so the (counted)
            // re-scale path cannot fire and the deltas are exact.
            let mut kv = rand_mat(&mut rng, 1, d);
            for x in kv.as_mut_slice() {
                *x *= 0.5;
            }
            let _ = pipe.decode_step(&mut st, &q1, &kv, &kv);
            let now = pipe.op_counts().dtype_conv;
            deltas.push(now - prev);
            prev = now;
        }
        // (1 query + 2 kv rows)·d quantized + 1·d output restored per step,
        // identical at L=33, 34, 35.
        assert_eq!(deltas[0], deltas[1]);
        assert_eq!(deltas[1], deltas[2]);
        assert_eq!(deltas[0], 3 * d as u64 + d as u64);
        // And nothing ever passes through the dequantize/requantize detour.
        assert_eq!(pipe.stage_times().get_ns(Stage::Dequantize), 0);
        assert_eq!(pipe.stage_times().get_ns(Stage::Requantize), 0);
    }

    #[test]
    fn probabilities_rows_normalized() {
        let mut rng = Pcg64::seed_from_u64(6);
        let cfg = AttentionConfig::new(24, 8);
        let q = rand_mat(&mut rng, 12, 8);
        let k = rand_mat(&mut rng, 24, 8);
        let pipe = IntAttention::new(cfg);
        let p = pipe.probabilities(&q, &k);
        for r in 0..12 {
            let s: i32 = p.row(r).iter().map(|&x| x as i32).sum();
            assert!((s - 255).abs() <= 16, "row {r} sum {s}");
        }
    }
}
