//! INT8 **Quant-Only** pipeline (paper §2.1–2.2, the "conventional quantized
//! attention" of Figure 1 top): integer GEMMs, but the softmax path takes the
//! dequantize → FP32 softmax → requantize detour the paper identifies as the
//! dominant cost (57–65 % of latency, Figure 2).
//!
//! Stage structure (each separately timed):
//!   1. Quantize   — dynamic per-tensor INT8 of Q, K, V (eq. 2–3)
//!   2. QkGemm     — `Â = Q̂K̂ᵀ` in i8×i8→i32 (eq. 4)
//!   3. Dequantize — `A = α·Â` to FP32
//!   4. Softmax    — stable FP32 softmax (eq. 6)
//!   5. Requantize — `P̂ = round(127·P)` signed INT8 (the conventional choice
//!                    the paper ablates in Table 9)
//!   6. PvGemm     — `P̂·V̂` in i8×i8→i32
//!   7. Output     — `O = (s_V/127)·(P̂V̂)`
//!
//! Stateful paths are prefix-sharing safe: K̂/V̂ reads go through
//! `page_list()` descriptors (fine over pages shared copy-on-write across
//! sequences), and both mutations — append-quantize and the running-scale
//! re-map — fork shared pages before writing, so a sharer's re-scale never
//! rewrites another sequence's resident grid
//! (see `crate::attention::state`).

use crate::attention::state::{Int8KvState, KvState};
use crate::attention::{
    batch_output_rescale, batch_rows, counts, validate_batch_shapes, validate_shapes,
    validate_state_shapes, AttentionConfig, AttentionPipeline, PipelineKind,
};
use crate::energy::OpCounts;
use crate::gemm::{
    gemm_i8_notrans, gemm_i8_notrans_paged, par_gemm_i8, par_gemm_i8_grouped,
    par_gemm_i8_notrans_grouped, par_gemm_i8_paged, GroupI8,
};
use crate::quant::{quantize_i8, quantize_p_i8_counted, quantize_p_i8_into};
use crate::softmax::float_softmax::{softmax_row, softmax_rows};
use crate::softmax::index_softmax::Mask;
use crate::tensor::{MatF32, MatI32};
use crate::util::timer::{Stage, StageTimes};

pub struct QuantOnlyAttention {
    cfg: AttentionConfig,
    times: StageTimes,
    ops: OpCounts,
    /// Reusable decode-step scratch: flat logit/dequantized/prob/acc rows.
    /// Quant-Only keeps the unfused three-pass decode on purpose — the
    /// pipeline exists to measure the conversion detour, which a fused walk
    /// would hide — but still runs allocation-free in steady state.
    dec_logits: Vec<i32>,
    dec_deq: Vec<f32>,
    dec_probs: Vec<i8>,
    dec_acc: Vec<i32>,
}

impl QuantOnlyAttention {
    pub fn new(cfg: AttentionConfig) -> Self {
        QuantOnlyAttention {
            cfg,
            times: StageTimes::new(),
            ops: OpCounts::default(),
            dec_logits: Vec::new(),
            dec_deq: Vec::new(),
            dec_probs: Vec::new(),
            dec_acc: Vec::new(),
        }
    }
}

impl AttentionPipeline for QuantOnlyAttention {
    fn kind(&self) -> PipelineKind {
        PipelineKind::QuantOnly
    }

    fn config(&self) -> &AttentionConfig {
        &self.cfg
    }

    fn forward(&mut self, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_shapes(&self.cfg, q, k, v);
        let (m, l, d) = (q.rows(), self.cfg.seq_len, self.cfg.head_dim);
        let pool = self.cfg.pool;

        // (1) dynamic quantization.
        let (qq, kq, vq) = self.times.measure(Stage::Quantize, || {
            (quantize_i8(q), quantize_i8(k), quantize_i8(v))
        });
        self.ops.add(&counts::quantize_qkv(m, l, d));
        let alpha = qq.scale * kq.scale / (d as f32).sqrt();

        // (2) integer similarity GEMM.
        let mut logits = MatI32::zeros(m, l);
        self.times.measure(Stage::QkGemm, || {
            par_gemm_i8(&qq.data, &kq.data, &mut logits, pool);
        });
        self.ops.add(&counts::qk_gemm(m, l, d, 1, 4));

        // (3) dequantize the full logit matrix to FP32 — the detour begins.
        let mut a = self
            .times
            .measure(Stage::Dequantize, || logits.map(|x| x as f32 * alpha));
        let valid = counts::valid_positions(m, l, self.cfg.mask);
        self.ops.add(&counts::dequantize_logits((m * l) as u64));

        // (4) FP32 softmax.
        self.times.measure(Stage::Softmax, || {
            softmax_rows(&mut a, self.cfg.mask);
        });
        self.ops.add(&counts::fp32_softmax(valid, m as u64));

        // (5) requantize probabilities to signed INT8 (×127); the operator
        // reports the nonzero count — no re-scan.
        let (p8, nnz) = self.times.measure(Stage::Requantize, || quantize_p_i8_counted(&a));
        self.ops.add(&counts::requantize_probs(valid));

        // (6) integer aggregation GEMM.
        let mut acc = MatI32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            gemm_i8_notrans(&p8, &vq.data, &mut acc);
        });
        self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));

        // (7) output rescale.
        let out_scale = vq.scale / 127.0;
        let o = self
            .times
            .measure(Stage::Output, || acc.map(|x| x as f32 * out_scale));
        self.ops.add(&counts::output_rescale(m, d));
        o
    }

    /// Stateful block forward. The K/V history stays resident as INT8 — the
    /// stateful path saves Quant-Only the per-token history re-quantization,
    /// but its logit matrix still takes the dequantize→softmax→requantize
    /// detour every step (the paper's point stands in serving, too).
    fn prefill(&mut self, state: &mut KvState, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_state_shapes(&self.cfg, state, q, k, v);
        let (m, d) = (q.rows(), self.cfg.head_dim);
        let pool = self.cfg.pool;

        // (1) quantize the query block + append-quantize the new K/V rows.
        let (qq, remapped) = self.times.measure(Stage::Quantize, || {
            let remapped = state.append(k, v);
            (quantize_i8(q), remapped)
        });
        self.ops.add(&counts::quantize_qkv(m, k.rows(), d));
        if remapped > 0 {
            self.ops.add(&counts::kv_rescale(remapped as u64));
        }

        let st = state.as_int8();
        let l = st.len();
        let mask = Mask::CausalFrom(l - m);
        let alpha = qq.scale * st.k.scale / (d as f32).sqrt();

        // (2) Q̂·K̂ᵀ against the resident INT8 key pages.
        let k_pages = st.k.data.page_list();
        let mut logits = MatI32::zeros(m, l);
        self.times.measure(Stage::QkGemm, || {
            par_gemm_i8_paged(qq.data.as_slice(), &k_pages, logits.as_mut_slice(), m, l, d, pool);
        });
        self.ops.add(&counts::qk_gemm(m, l, d, 1, 4));

        // (3) dequantize the block's logits — the detour, every step.
        let mut a = self
            .times
            .measure(Stage::Dequantize, || logits.map(|x| x as f32 * alpha));
        let valid = counts::valid_positions(m, l, mask);
        self.ops.add(&counts::dequantize_logits((m * l) as u64));

        // (4) FP32 softmax over the offset-causal window.
        self.times.measure(Stage::Softmax, || {
            softmax_rows(&mut a, mask);
        });
        self.ops.add(&counts::fp32_softmax(valid, m as u64));

        // (5) requantize probabilities to signed INT8.
        let (p8, nnz) = self.times.measure(Stage::Requantize, || quantize_p_i8_counted(&a));
        self.ops.add(&counts::requantize_probs(valid));

        // (6) aggregation against the resident INT8 value pages.
        let v_pages = st.v.data.page_list();
        let mut acc = MatI32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            gemm_i8_notrans_paged(p8.as_slice(), &v_pages, acc.as_mut_slice(), m, l, d);
        });
        self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));

        // (7) output rescale with the state's running V scale.
        let out_scale = st.v.scale / 127.0;
        let o = self
            .times
            .measure(Stage::Output, || acc.map(|x| x as f32 * out_scale));
        self.ops.add(&counts::output_rescale(m, d));
        o
    }

    /// Single-token decode: delegates to the batched path with one state so
    /// both entry points share the reusable-scratch implementation below.
    fn decode_step(
        &mut self,
        state: &mut KvState,
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        debug_assert_eq!(q.rows(), 1, "decode_step takes a single query row");
        self.decode_step_batch(&mut [state], q, k_new, v_new)
    }

    /// Batched decode: grouped integer GEMMs around the per-sequence
    /// dequantize→softmax→requantize detour (the detour itself cannot be
    /// batched across sequences — each row has its own α and history
    /// length, which is the paper's point about this pipeline). All stage
    /// buffers live in the pipeline's reusable scratch, so steady-state
    /// decode allocates nothing per token. Bit-identical per sequence to
    /// [`AttentionPipeline::decode_step`].
    fn decode_step_batch(
        &mut self,
        states: &mut [&mut KvState],
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        validate_batch_shapes(&self.cfg, states, q, k_new, v_new);
        let b = states.len();
        let d = self.cfg.head_dim;
        if b == 0 {
            return MatF32::zeros(0, d);
        }
        let pool = self.cfg.pool;
        let sqrt_d = (d as f32).sqrt();

        // (1) per-sequence append + query quantization (own scales).
        let rows = batch_rows(q, k_new, v_new);
        let (qqs, remapped) = self.times.measure(Stage::Quantize, || {
            let mut remapped = 0usize;
            let mut qqs = Vec::with_capacity(b);
            for (st, (qr, kr, vr)) in states.iter_mut().zip(&rows) {
                remapped += st.append(kr, vr);
                qqs.push(quantize_i8(qr));
            }
            (qqs, remapped)
        });
        for _ in 0..b {
            self.ops.add(&counts::quantize_qkv(1, 1, d));
        }
        if remapped > 0 {
            self.ops.add(&counts::kv_rescale(remapped as u64));
        }

        let ints: Vec<&Int8KvState> = states.iter().map(|st| st.as_int8()).collect();
        let ls: Vec<usize> = ints.iter().map(|s| s.len()).collect();
        let total: usize = ls.iter().sum();

        // (2) one grouped Q̂·K̂ᵀ launch over the B resident K̂ page lists,
        // into per-sequence spans of the flat logit scratch.
        let k_pages: Vec<Vec<&[i8]>> = ints.iter().map(|s| s.k.data.page_list()).collect();
        let mut logits = std::mem::take(&mut self.dec_logits);
        logits.clear();
        logits.resize(total, 0);
        self.times.measure(Stage::QkGemm, || {
            let mut groups: Vec<GroupI8> = Vec::with_capacity(b);
            let mut rest: &mut [i32] = &mut logits;
            for ((qq, kp), &l) in qqs.iter().zip(&k_pages).zip(&ls) {
                let (lg, tail) = rest.split_at_mut(l);
                rest = tail;
                groups.push(GroupI8 { a: qq.data.as_slice(), b: kp.as_slice(), out: lg });
            }
            par_gemm_i8_grouped(&mut groups, d, pool);
        });
        for &l in &ls {
            self.ops.add(&counts::qk_gemm(1, l, d, 1, 4));
        }

        // (3) per-sequence dequantize with that sequence's α — the detour,
        // every step, every sequence.
        let mut deq = std::mem::take(&mut self.dec_deq);
        deq.clear();
        deq.resize(total, 0.0);
        self.times.measure(Stage::Dequantize, || {
            let mut off = 0usize;
            for ((qq, s), &l) in qqs.iter().zip(&ints).zip(&ls) {
                let alpha = qq.scale * s.k.scale / sqrt_d;
                for (dv, &lv) in deq[off..off + l].iter_mut().zip(&logits[off..off + l]) {
                    *dv = lv as f32 * alpha;
                }
                off += l;
            }
        });
        for &l in &ls {
            self.ops.add(&counts::dequantize_logits(l as u64));
        }

        // (4) per-sequence FP32 softmax over its full history (a decode row
        // attends everywhere, so the row form needs no mask).
        self.times.measure(Stage::Softmax, || {
            let mut off = 0usize;
            for &l in &ls {
                softmax_row(&mut deq[off..off + l]);
                off += l;
            }
        });
        for &l in &ls {
            self.ops.add(&counts::fp32_softmax(l as u64, 1));
        }

        // (5) per-sequence requantize to signed INT8; the operator reports
        // each span's nonzero count — no re-scan.
        let mut probs = std::mem::take(&mut self.dec_probs);
        probs.clear();
        probs.resize(total, 0);
        let nnzs: Vec<u64> = self.times.measure(Stage::Requantize, || {
            let mut nnzs = Vec::with_capacity(b);
            let mut off = 0usize;
            for &l in &ls {
                nnzs.push(quantize_p_i8_into(&deq[off..off + l], &mut probs[off..off + l]));
                off += l;
            }
            nnzs
        });
        for &l in &ls {
            self.ops.add(&counts::requantize_probs(l as u64));
        }

        // (6) one grouped P̂·V̂ launch over the B resident V̂ page lists.
        let v_pages: Vec<Vec<&[i8]>> = ints.iter().map(|s| s.v.data.page_list()).collect();
        let mut acc = std::mem::take(&mut self.dec_acc);
        acc.clear();
        acc.resize(b * d, 0);
        self.times.measure(Stage::PvGemm, || {
            let mut groups: Vec<GroupI8> = Vec::with_capacity(b);
            let mut rest: &mut [i32] = &mut acc;
            let mut off = 0usize;
            for (vp, &l) in v_pages.iter().zip(&ls) {
                let (out, tail) = rest.split_at_mut(d);
                rest = tail;
                groups.push(GroupI8 { a: &probs[off..off + l], b: vp.as_slice(), out });
                off += l;
            }
            par_gemm_i8_notrans_grouped(&mut groups, d, pool);
        });
        for (&nnz, &l) in nnzs.iter().zip(&ls) {
            self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));
        }

        // (7) per-sequence output rescale (running V scale / 127).
        let o = self
            .times
            .measure(Stage::Output, || {
                batch_output_rescale(&acc, d, |i| ints[i].v.scale / 127.0)
            });
        for _ in 0..b {
            self.ops.add(&counts::output_rescale(1, d));
        }

        self.dec_logits = logits;
        self.dec_deq = deq;
        self.dec_probs = probs;
        self.dec_acc = acc;
        o
    }

    fn stage_times(&self) -> &StageTimes {
        &self.times
    }

    fn op_counts(&self) -> &OpCounts {
        &self.ops
    }

    fn reset_stats(&mut self) {
        self.times.reset();
        self.ops = OpCounts::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::fp32::reference_attention;
    use crate::softmax::index_softmax::Mask;
    use crate::util::prng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
        MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn close_to_fp32_reference() {
        let mut rng = Pcg64::seed_from_u64(1);
        let cfg = AttentionConfig::new(64, 32);
        let q = rand_mat(&mut rng, 32, 32);
        let k = rand_mat(&mut rng, 64, 32);
        let v = rand_mat(&mut rng, 64, 32);
        let got = QuantOnlyAttention::new(cfg).forward(&q, &k, &v);
        let want = reference_attention(&q, &k, &v, Mask::None);
        // INT8 quantization of Q,K,V plus INT8 P: a few percent error.
        let cos = crate::util::stats::cosine_similarity(got.as_slice(), want.as_slice());
        assert!(cos > 0.99, "cos={cos}");
    }

    #[test]
    fn causal_close_to_reference() {
        let mut rng = Pcg64::seed_from_u64(2);
        let cfg = AttentionConfig::new(32, 16).causal();
        let q = rand_mat(&mut rng, 32, 16);
        let k = rand_mat(&mut rng, 32, 16);
        let v = rand_mat(&mut rng, 32, 16);
        let got = QuantOnlyAttention::new(cfg).forward(&q, &k, &v);
        let want = reference_attention(&q, &k, &v, Mask::Causal);
        let cos = crate::util::stats::cosine_similarity(got.as_slice(), want.as_slice());
        assert!(cos > 0.99, "cos={cos}");
    }

    #[test]
    fn detour_stages_are_timed() {
        let mut rng = Pcg64::seed_from_u64(3);
        let cfg = AttentionConfig::new(64, 32);
        let q = rand_mat(&mut rng, 64, 32);
        let k = rand_mat(&mut rng, 64, 32);
        let v = rand_mat(&mut rng, 64, 32);
        let mut pipe = QuantOnlyAttention::new(cfg);
        let _ = pipe.forward(&q, &k, &v);
        // The detour's three stages must all be visible.
        assert!(pipe.stage_times().get_ns(Stage::Dequantize) > 0);
        assert!(pipe.stage_times().get_ns(Stage::Softmax) > 0);
        assert!(pipe.stage_times().get_ns(Stage::Requantize) > 0);
        // And the conversion op counters populated (the energy story).
        assert!(pipe.op_counts().dtype_conv > 0);
        assert_eq!(pipe.op_counts().int8_mac > 0, true);
        assert_eq!(pipe.op_counts().fp32_mac, 0);
    }
}
