//! FP32 baseline attention (paper eq. 1 + eq. 6): `A = QKᵀ/√d`,
//! `P = softmax(A)`, `O = PV`, everything in f32.
//!
//! The stateful paths are prefix-sharing safe by construction: every read
//! of resident K/V goes through `page_list()` descriptors (`&[f32]` slices
//! that tolerate pages shared copy-on-write with other sequences), and the
//! only mutation — `KvState::append` — forks a shared tail page before
//! writing (see `crate::attention::state`).

use crate::attention::state::{F32KvState, KvState};
use crate::attention::{
    batch_row, counts, validate_batch_shapes, validate_shapes, validate_state_shapes,
    AttentionConfig, AttentionPipeline, PipelineKind,
};
use crate::energy::OpCounts;
use crate::gemm::{
    gemm_f32_notrans_paged, par_gemm_f32, par_gemm_f32_grouped, par_gemm_f32_notrans_grouped,
    par_gemm_f32_paged, GroupF32,
};
use crate::softmax::float_softmax::softmax_rows;
use crate::softmax::index_softmax::Mask;
use crate::tensor::MatF32;
use crate::util::timer::{Stage, StageTimes};

pub struct Fp32Attention {
    cfg: AttentionConfig,
    times: StageTimes,
    ops: OpCounts,
}

impl Fp32Attention {
    pub fn new(cfg: AttentionConfig) -> Self {
        Fp32Attention { cfg, times: StageTimes::new(), ops: OpCounts::default() }
    }
}

impl AttentionPipeline for Fp32Attention {
    fn kind(&self) -> PipelineKind {
        PipelineKind::Fp32
    }

    fn config(&self) -> &AttentionConfig {
        &self.cfg
    }

    fn forward(&mut self, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_shapes(&self.cfg, q, k, v);
        let (m, l, d) = (q.rows(), self.cfg.seq_len, self.cfg.head_dim);
        let scale = 1.0 / (d as f32).sqrt();
        let pool = self.cfg.pool;

        // QKᵀ — K is already in "transposed" (keys-as-rows) layout.
        let mut a = MatF32::zeros(m, l);
        self.times.measure(Stage::QkGemm, || {
            par_gemm_f32(q, k, &mut a, pool);
        });
        self.ops.add(&counts::qk_gemm(m, l, d, 4, 4));

        // Scale + stable softmax.
        self.times.measure(Stage::Softmax, || {
            for x in a.as_mut_slice() {
                *x *= scale;
            }
            softmax_rows(&mut a, self.cfg.mask);
        });
        let valid = counts::valid_positions(m, l, self.cfg.mask);
        self.ops.add(&counts::fp32_softmax(valid, m as u64));

        // PV: transpose V once (O(L·d)) so the aggregation runs as blocked
        // dot products — an order faster than the branchy SAXPY form on
        // dense float probability rows.
        let mut o = MatF32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            let vt = v.transpose();
            par_gemm_f32(&a, &vt, &mut o, pool);
        });
        self.ops.add(&counts::pv_gemm(valid, l, d, 4, 4));
        o
    }

    /// Stateful block forward over FP32-resident K/V rows. The float
    /// baseline keeps history in its native dtype — appended once, never
    /// copied again; the PV aggregation streams V rows in place.
    fn prefill(&mut self, state: &mut KvState, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_state_shapes(&self.cfg, state, q, k, v);
        let (m, d) = (q.rows(), self.cfg.head_dim);
        let pool = self.cfg.pool;
        let scale = 1.0 / (d as f32).sqrt();

        state.append(k, v);
        let st = state.as_f32();
        let l = st.len();
        let mask = Mask::CausalFrom(l - m);

        // QKᵀ — the resident K pages are already the "transposed" layout.
        let k_pages = st.k.page_list();
        let mut a = MatF32::zeros(m, l);
        self.times.measure(Stage::QkGemm, || {
            par_gemm_f32_paged(q.as_slice(), &k_pages, a.as_mut_slice(), m, l, d, pool);
        });
        self.ops.add(&counts::qk_gemm(m, l, d, 4, 4));

        // Scale + stable softmax over the offset-causal window.
        self.times.measure(Stage::Softmax, || {
            for x in a.as_mut_slice() {
                *x *= scale;
            }
            softmax_rows(&mut a, mask);
        });
        let valid = counts::valid_positions(m, l, mask);
        self.ops.add(&counts::fp32_softmax(valid, m as u64));

        // PV directly over the resident `L×d` row pages (masked entries
        // are exact zeros and are skipped).
        let v_pages = st.v.page_list();
        let mut o = MatF32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            gemm_f32_notrans_paged(a.as_slice(), &v_pages, o.as_mut_slice(), m, l, d);
        });
        self.ops.add(&counts::pv_gemm(valid, l, d, 4, 4));
        o
    }

    /// Batched decode over the grouped f32 kernels — bit-identical per
    /// sequence to [`AttentionPipeline::decode_step`] (the grouping only
    /// moves whole dot products between threads, never splits one).
    fn decode_step_batch(
        &mut self,
        states: &mut [&mut KvState],
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        validate_batch_shapes(&self.cfg, states, q, k_new, v_new);
        let b = states.len();
        let d = self.cfg.head_dim;
        if b == 0 {
            return MatF32::zeros(0, d);
        }
        let pool = self.cfg.pool;
        let scale = 1.0 / (d as f32).sqrt();

        // Append each sequence's new K/V row in the native dtype (untimed,
        // like the sequential path).
        for (i, st) in states.iter_mut().enumerate() {
            st.append(&batch_row(k_new, i), &batch_row(v_new, i));
        }
        let fs: Vec<&F32KvState> = states.iter().map(|st| st.as_f32()).collect();

        // One grouped QKᵀ launch over the B resident K page lists.
        let k_pages: Vec<Vec<&[f32]>> = fs.iter().map(|s| s.k.page_list()).collect();
        let mut a_rows: Vec<MatF32> = fs.iter().map(|s| MatF32::zeros(1, s.len())).collect();
        self.times.measure(Stage::QkGemm, || {
            let mut groups: Vec<GroupF32> = Vec::with_capacity(b);
            for (i, (kp, ar)) in k_pages.iter().zip(a_rows.iter_mut()).enumerate() {
                groups.push(GroupF32 { a: q.row(i), b: kp.as_slice(), out: ar.as_mut_slice() });
            }
            par_gemm_f32_grouped(&mut groups, d, pool);
        });
        for s in &fs {
            self.ops.add(&counts::qk_gemm(1, s.len(), d, 4, 4));
        }

        // Per-sequence scale + stable softmax at that sequence's offset.
        self.times.measure(Stage::Softmax, || {
            for (ar, s) in a_rows.iter_mut().zip(&fs) {
                for x in ar.as_mut_slice() {
                    *x *= scale;
                }
                softmax_rows(ar, Mask::CausalFrom(s.len() - 1));
            }
        });
        for s in &fs {
            self.ops.add(&counts::fp32_softmax(s.len() as u64, 1));
        }

        // One grouped PV launch over the B resident V page lists.
        let v_pages: Vec<Vec<&[f32]>> = fs.iter().map(|s| s.v.page_list()).collect();
        let mut o = MatF32::zeros(b, d);
        self.times.measure(Stage::PvGemm, || {
            let mut groups: Vec<GroupF32> = Vec::with_capacity(b);
            for ((ar, vp), orow) in a_rows.iter().zip(&v_pages).zip(o.as_mut_slice().chunks_mut(d)) {
                groups.push(GroupF32 { a: ar.as_slice(), b: vp.as_slice(), out: orow });
            }
            par_gemm_f32_notrans_grouped(&mut groups, d, pool);
        });
        for s in &fs {
            self.ops.add(&counts::pv_gemm(s.len() as u64, s.len(), d, 4, 4));
        }
        o
    }

    fn stage_times(&self) -> &StageTimes {
        &self.times
    }

    fn op_counts(&self) -> &OpCounts {
        &self.ops
    }

    fn reset_stats(&mut self) {
        self.times.reset();
        self.ops = OpCounts::default();
    }
}

/// Scalar textbook reference (no blocking, no instrumentation) used as the
/// numerical oracle by the cross-pipeline tests.
pub fn reference_attention(q: &MatF32, k: &MatF32, v: &MatF32, mask: crate::softmax::index_softmax::Mask) -> MatF32 {
    let (m, d) = (q.rows(), q.cols());
    let l = k.rows();
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = MatF32::zeros(m, d);
    for i in 0..m {
        let valid = mask.valid_cols(i, l);
        // logits
        let mut logits = vec![0f32; valid];
        for (j, lg) in logits.iter_mut().enumerate() {
            let mut s = 0f32;
            for c in 0..d {
                s += q.get(i, c) * k.get(j, c);
            }
            *lg = s * scale;
        }
        // softmax
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for lg in logits.iter_mut() {
            *lg = (*lg - mx).exp();
            z += *lg;
        }
        // aggregate
        for (j, &p) in logits.iter().enumerate() {
            let w = p / z;
            for c in 0..d {
                let cur = out.get(i, c);
                out.set(i, c, cur + w * v.get(j, c));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::index_softmax::Mask;
    use crate::util::prng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
        MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn matches_reference() {
        let mut rng = Pcg64::seed_from_u64(1);
        let cfg = AttentionConfig::new(32, 16);
        let q = rand_mat(&mut rng, 8, 16);
        let k = rand_mat(&mut rng, 32, 16);
        let v = rand_mat(&mut rng, 32, 16);
        let mut pipe = Fp32Attention::new(cfg);
        let got = pipe.forward(&q, &k, &v);
        let want = reference_attention(&q, &k, &v, Mask::None);
        assert!(got.allclose(&want, 1e-5, 1e-4));
    }

    #[test]
    fn causal_matches_reference() {
        let mut rng = Pcg64::seed_from_u64(2);
        let cfg = AttentionConfig::new(24, 8).causal();
        let q = rand_mat(&mut rng, 24, 8);
        let k = rand_mat(&mut rng, 24, 8);
        let v = rand_mat(&mut rng, 24, 8);
        let mut pipe = Fp32Attention::new(cfg);
        let got = pipe.forward(&q, &k, &v);
        let want = reference_attention(&q, &k, &v, Mask::Causal);
        assert!(got.allclose(&want, 1e-5, 1e-4));
    }

    #[test]
    fn stage_times_and_ops_populated() {
        let mut rng = Pcg64::seed_from_u64(3);
        let cfg = AttentionConfig::new(64, 32);
        let q = rand_mat(&mut rng, 64, 32);
        let k = rand_mat(&mut rng, 64, 32);
        let v = rand_mat(&mut rng, 64, 32);
        let mut pipe = Fp32Attention::new(cfg);
        let _ = pipe.forward(&q, &k, &v);
        assert!(pipe.stage_times().get_ns(Stage::QkGemm) > 0);
        assert!(pipe.stage_times().get_ns(Stage::Softmax) > 0);
        assert_eq!(pipe.stage_times().get_ns(Stage::Dequantize), 0);
        assert_eq!(pipe.op_counts().fp32_mac, 2 * 64 * 64 * 32);
        assert_eq!(pipe.op_counts().fp32_exp, 64 * 64);
        pipe.reset_stats();
        assert_eq!(pipe.stage_times().total_ns(), 0);
    }

    #[test]
    fn stateful_path_matches_one_shot() {
        let mut rng = Pcg64::seed_from_u64(5);
        let (l, d) = (24, 8);
        let q = rand_mat(&mut rng, l, d);
        let k = rand_mat(&mut rng, l, d);
        let v = rand_mat(&mut rng, l, d);
        let want = Fp32Attention::new(AttentionConfig::new(l, d).causal()).forward(&q, &k, &v);
        let mut pipe = Fp32Attention::new(AttentionConfig::new(l, d));
        let mut st = pipe.begin_state();
        let part = |m: &MatF32, r0: usize, r1: usize| {
            MatF32::from_vec(r1 - r0, d, m.as_slice()[r0 * d..r1 * d].to_vec())
        };
        // Chunked prefill of 16 rows, then 8 single-row decode steps.
        let mut got = Vec::new();
        let o = pipe.prefill(&mut st, &part(&q, 0, 16), &part(&k, 0, 16), &part(&v, 0, 16));
        got.extend_from_slice(o.as_slice());
        for r in 16..l {
            let o = pipe.decode_step(&mut st, &part(&q, r, r + 1), &part(&k, r, r + 1), &part(&v, r, r + 1));
            got.extend_from_slice(o.as_slice());
        }
        assert_eq!(st.len(), l);
        let got = MatF32::from_vec(l, d, got);
        // Same dot products, different PV accumulation order: tiny eps.
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn first_row_of_causal_attends_itself_only() {
        let mut rng = Pcg64::seed_from_u64(4);
        let cfg = AttentionConfig::new(8, 4).causal();
        let q = rand_mat(&mut rng, 8, 4);
        let k = rand_mat(&mut rng, 8, 4);
        let v = rand_mat(&mut rng, 8, 4);
        let mut pipe = Fp32Attention::new(cfg);
        let got = pipe.forward(&q, &k, &v);
        for c in 0..4 {
            assert!((got.get(0, c) - v.get(0, c)).abs() < 1e-6);
        }
    }
}
