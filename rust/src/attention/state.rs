//! Per-sequence KV state for the stateful prefill/decode attention API.
//!
//! The paper's whole point is an unbroken integer dataflow; a serving path
//! that stores FP32 K/V history and re-quantizes it on every decode step
//! breaks that dataflow and costs O(L·d) redundant conversions per token.
//! Instead, each pipeline owns a [`KvState`] per sequence (per head) holding
//! K/V **in the pipeline's native operand format**:
//!
//! * integer pipelines (Quant-Only, IntAttention, EXAQ) keep K̂/V̂ as INT8
//!   rows plus one running per-tensor scale each ([`Int8KvState`]). A decode
//!   step quantizes only the new row. When a new row's magnitude exceeds the
//!   running abs-max, the resident rows are re-mapped to the wider grid in
//!   the integer domain (`round(x̂·s_old/s_new)`) — an O(L·d) event that
//!   occurs only when the running maximum actually grows, not per token
//!   (the same "keep quantized operands resident" discipline as I-BERT and
//!   the ITA accelerator).
//! * FP32 / FP16 pipelines keep native-dtype rows ([`F32KvState`],
//!   [`F16KvState`]).
//!
//! States also carry the running Δ-statistics EXAQ's dynamic clipping needs
//! ([`ExaqRunningStats`]), so EXAQ decode keeps its O(1)-per-token cost
//! instead of re-scanning history for the clip range.

use crate::attention::PipelineKind;
use crate::tensor::MatF32;
use crate::util::f16::{encode_slice, F16};

/// One side (K or V) of an INT8-resident state: quantized rows plus the
/// running per-tensor scale bookkeeping.
#[derive(Clone, Debug)]
pub struct Int8Side {
    /// Quantized rows, `len×d` row-major.
    pub data: Vec<i8>,
    /// Dequantization scale: `x ≈ scale · x̂` (1.0 while all-zero).
    pub scale: f32,
    /// Running abs-max over every row ever appended.
    pub amax: f32,
    /// How many times the resident rows were re-mapped to a wider grid.
    pub rescales: u64,
}

impl Int8Side {
    fn new() -> Self {
        Int8Side { data: Vec::new(), scale: 1.0, amax: 0.0, rescales: 0 }
    }

    /// Quantize and append `rows`, widening the grid first if the running
    /// abs-max grew. Matches `quantize_i8`'s conventions (symmetric ±127,
    /// scale 1.0 for all-zero data), so after any append sequence the scale
    /// equals what one-shot quantization of the concatenated rows would use.
    ///
    /// Returns the number of resident elements re-mapped by the re-scale
    /// path (0 on the common fast path) so callers can charge the work to
    /// their op counters.
    fn append(&mut self, rows: &MatF32) -> usize {
        let mut remapped = 0;
        let new_amax = rows.abs_max();
        if new_amax > self.amax {
            let new_scale = new_amax / 127.0;
            if !self.data.is_empty() && self.amax > 0.0 {
                // Re-scale path: re-map resident INT8 rows onto the wider
                // grid entirely in the quantized domain (no FP32 history
                // exists to re-quantize from — that is the point).
                let ratio = self.scale / new_scale;
                for q in self.data.iter_mut() {
                    *q = ((*q as f32) * ratio).round().clamp(-127.0, 127.0) as i8;
                }
                self.rescales += 1;
                remapped = self.data.len();
            }
            self.amax = new_amax;
            self.scale = new_scale;
        }
        let inv = 1.0 / self.scale;
        self.data.reserve(rows.len());
        for &x in rows.as_slice() {
            self.data.push((x * inv).round().clamp(-127.0, 127.0) as i8);
        }
        remapped
    }
}

/// Running statistics of the max-subtracted distances `Δ = m − a` (scaled by
/// α), accumulated across prefill/decode calls — EXAQ's dynamic clip range
/// without the per-step O(L) history re-scan.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExaqRunningStats {
    pub sum: f64,
    pub sumsq: f64,
    pub n: u64,
}

impl ExaqRunningStats {
    pub fn merge(&mut self, sum: f64, sumsq: f64, n: u64) {
        self.sum += sum;
        self.sumsq += sumsq;
        self.n += n;
    }

    /// Standard deviation of all Δ seen so far (0 before any data).
    pub fn sigma(&self) -> f32 {
        if self.n == 0 {
            return 0.0;
        }
        let mean = self.sum / self.n as f64;
        let var = (self.sumsq / self.n as f64 - mean * mean).max(0.0);
        var.sqrt() as f32
    }
}

/// INT8-resident K/V state (Quant-Only, IntAttention, EXAQ pipelines).
#[derive(Clone, Debug)]
pub struct Int8KvState {
    pub d: usize,
    pub len: usize,
    pub k: Int8Side,
    pub v: Int8Side,
    /// Used only by the EXAQ pipelines (zero-cost for the others).
    pub exaq: ExaqRunningStats,
}

/// FP32-resident K/V state.
#[derive(Clone, Debug)]
pub struct F32KvState {
    pub d: usize,
    pub len: usize,
    /// `len×d` row-major keys.
    pub k: Vec<f32>,
    /// `len×d` row-major values.
    pub v: Vec<f32>,
}

/// FP16-storage K/V state (binary16 rows, decoded tile-wise at compute time).
#[derive(Clone, Debug)]
pub struct F16KvState {
    pub d: usize,
    pub len: usize,
    pub k: Vec<F16>,
    pub v: Vec<F16>,
}

/// A per-sequence (per-head) KV cache entry owned by the pipeline kind that
/// created it. Appending K/V rows converts them **once** into the pipeline's
/// operand format; no later call re-quantizes or re-copies history.
#[derive(Clone, Debug)]
pub enum KvState {
    F32(F32KvState),
    F16(F16KvState),
    Int8(Int8KvState),
}

impl KvState {
    /// The state format a pipeline kind keeps resident.
    pub fn new(kind: PipelineKind, head_dim: usize) -> KvState {
        assert!(head_dim > 0, "head_dim must be positive");
        match kind {
            PipelineKind::Fp32 => KvState::F32(F32KvState {
                d: head_dim,
                len: 0,
                k: Vec::new(),
                v: Vec::new(),
            }),
            PipelineKind::Fp16 => KvState::F16(F16KvState {
                d: head_dim,
                len: 0,
                k: Vec::new(),
                v: Vec::new(),
            }),
            PipelineKind::QuantOnly
            | PipelineKind::IntAttention
            | PipelineKind::ExaqInt2
            | PipelineKind::ExaqInt3 => KvState::Int8(Int8KvState {
                d: head_dim,
                len: 0,
                k: Int8Side::new(),
                v: Int8Side::new(),
                exaq: ExaqRunningStats::default(),
            }),
        }
    }

    /// Cached positions.
    pub fn len(&self) -> usize {
        match self {
            KvState::F32(s) => s.len,
            KvState::F16(s) => s.len,
            KvState::Int8(s) => s.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Head dimension the state was built for.
    pub fn head_dim(&self) -> usize {
        match self {
            KvState::F32(s) => s.d,
            KvState::F16(s) => s.d,
            KvState::Int8(s) => s.d,
        }
    }

    /// Append `k_rows`/`v_rows` (equal row counts, `head_dim` columns) in
    /// the state's native format. Returns the number of resident elements
    /// the INT8 re-scale path re-mapped (0 for float states and on the
    /// common integer fast path).
    pub fn append(&mut self, k_rows: &MatF32, v_rows: &MatF32) -> usize {
        let n = k_rows.rows();
        assert_eq!(v_rows.rows(), n, "K/V row count mismatch");
        assert_eq!(k_rows.cols(), self.head_dim(), "K head_dim");
        assert_eq!(v_rows.cols(), self.head_dim(), "V head_dim");
        match self {
            KvState::F32(s) => {
                s.k.extend_from_slice(k_rows.as_slice());
                s.v.extend_from_slice(v_rows.as_slice());
                s.len += n;
                0
            }
            KvState::F16(s) => {
                s.k.extend(encode_slice(k_rows.as_slice()));
                s.v.extend(encode_slice(v_rows.as_slice()));
                s.len += n;
                0
            }
            KvState::Int8(s) => {
                let remapped = s.k.append(k_rows) + s.v.append(v_rows);
                s.len += n;
                remapped
            }
        }
    }

    /// Actual memory footprint in bytes: K/V payload at the native element
    /// width, plus the scale/statistics bookkeeping integer states carry.
    /// This is what the coordinator's admission control charges per request.
    pub fn bytes(&self) -> usize {
        match self {
            KvState::F32(s) => (s.k.len() + s.v.len()) * 4,
            KvState::F16(s) => (s.k.len() + s.v.len()) * 2,
            // INT8 payload + per-side (scale, amax, rescales) + EXAQ stats.
            KvState::Int8(s) => s.k.data.len() + s.v.data.len() + 2 * 16 + 24,
        }
    }

    /// The INT8 state, panicking if this state was built by a float pipeline.
    pub fn as_int8(&self) -> &Int8KvState {
        match self {
            KvState::Int8(s) => s,
            other => panic!(
                "pipeline expects an INT8 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_int8_mut(&mut self) -> &mut Int8KvState {
        match self {
            KvState::Int8(s) => s,
            other => panic!(
                "pipeline expects an INT8 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_f32(&self) -> &F32KvState {
        match self {
            KvState::F32(s) => s,
            other => panic!(
                "pipeline expects an FP32 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut F32KvState {
        match self {
            KvState::F32(s) => s,
            other => panic!(
                "pipeline expects an FP32 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_f16(&self) -> &F16KvState {
        match self {
            KvState::F16(s) => s,
            other => panic!(
                "pipeline expects an FP16 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_f16_mut(&mut self) -> &mut F16KvState {
        match self {
            KvState::F16(s) => s,
            other => panic!(
                "pipeline expects an FP16 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    /// Storage format name (diagnostics).
    pub fn storage_name(&self) -> &'static str {
        match self {
            KvState::F32(_) => "fp32",
            KvState::F16(_) => "fp16",
            KvState::Int8(_) => "int8",
        }
    }
}

/// Bytes one cached token costs for `kind` at head dimension `d` across K
/// and V (payload only — the per-state constant overhead is excluded so the
/// estimate scales linearly for admission control).
pub fn kv_bytes_per_token(kind: PipelineKind, d: usize) -> usize {
    let elem = match kind {
        PipelineKind::Fp32 => 4,
        PipelineKind::Fp16 => 2,
        _ => 1,
    };
    2 * d * elem
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_i8;
    use crate::util::prng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
        MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn kinds_map_to_expected_storage() {
        assert_eq!(KvState::new(PipelineKind::Fp32, 8).storage_name(), "fp32");
        assert_eq!(KvState::new(PipelineKind::Fp16, 8).storage_name(), "fp16");
        for kind in [
            PipelineKind::QuantOnly,
            PipelineKind::IntAttention,
            PipelineKind::ExaqInt2,
            PipelineKind::ExaqInt3,
        ] {
            assert_eq!(KvState::new(kind, 8).storage_name(), "int8");
        }
    }

    #[test]
    fn int8_running_scale_matches_one_shot_quantization() {
        // Appending chunk-by-chunk must end with the same scale one-shot
        // per-tensor quantization of the concatenated rows produces.
        let mut rng = Pcg64::seed_from_u64(1);
        let full = rand_mat(&mut rng, 24, 8);
        let mut st = KvState::new(PipelineKind::IntAttention, 8);
        for start in (0..24).step_by(6) {
            let chunk = MatF32::from_vec(6, 8, full.as_slice()[start * 8..(start + 6) * 8].to_vec());
            st.append(&chunk, &chunk);
        }
        let s = st.as_int8();
        let one_shot = quantize_i8(&full);
        assert_eq!(s.len, 24);
        assert!((s.k.scale - one_shot.scale).abs() < 1e-12, "{} vs {}", s.k.scale, one_shot.scale);
        // Rows quantized after the amax stopped growing are bit-identical to
        // one-shot; earlier rows pick up ≤ half an LSB of extra rounding per
        // re-scale event (3 chunks after the first ⇒ ≤ 2 LSB here).
        for (a, b) in s.k.data.iter().zip(one_shot.data.as_slice()) {
            assert!((*a as i32 - *b as i32).abs() <= 2, "{a} vs {b}");
        }
    }

    #[test]
    fn rescale_fires_only_when_amax_grows() {
        let mut st = KvState::new(PipelineKind::IntAttention, 2);
        let small = MatF32::from_vec(1, 2, vec![0.5, -0.25]);
        let big = MatF32::from_vec(1, 2, vec![4.0, 1.0]);
        st.append(&small, &small);
        assert_eq!(st.as_int8().k.rescales, 0);
        st.append(&small, &small); // same magnitude: no rescale
        assert_eq!(st.as_int8().k.rescales, 0);
        st.append(&big, &big); // amax grows 0.5 → 4.0: resident rows re-map
        let s = st.as_int8();
        assert_eq!(s.k.rescales, 1);
        assert!((s.k.amax - 4.0).abs() < 1e-12);
        // Old rows re-mapped onto the wider grid: 0.5 at scale 4/127 → 16.
        assert_eq!(s.k.data[0], 16);
        st.append(&small, &small); // shrinking magnitudes never rescale
        assert_eq!(st.as_int8().k.rescales, 1);
    }

    #[test]
    fn zero_rows_are_safe() {
        let mut st = KvState::new(PipelineKind::QuantOnly, 4);
        let z = MatF32::zeros(3, 4);
        st.append(&z, &z);
        let s = st.as_int8();
        assert_eq!(s.k.scale, 1.0);
        assert!(s.k.data.iter().all(|&x| x == 0));
        // First nonzero append after zeros must not count as a "rescale"
        // (there is nothing to re-map).
        let nz = MatF32::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]);
        st.append(&nz, &nz);
        assert_eq!(st.as_int8().k.rescales, 0);
        assert_eq!(st.len(), 4);
    }

    #[test]
    fn bytes_reflect_native_widths() {
        let mut rng = Pcg64::seed_from_u64(2);
        let rows = rand_mat(&mut rng, 10, 16);
        let mut f32s = KvState::new(PipelineKind::Fp32, 16);
        let mut f16s = KvState::new(PipelineKind::Fp16, 16);
        let mut i8s = KvState::new(PipelineKind::IntAttention, 16);
        for s in [&mut f32s, &mut f16s, &mut i8s] {
            s.append(&rows, &rows);
        }
        assert_eq!(f32s.bytes(), 2 * 10 * 16 * 4);
        assert_eq!(f16s.bytes(), 2 * 10 * 16 * 2);
        // INT8: payload + 56 B of scale/stat bookkeeping.
        assert_eq!(i8s.bytes(), 2 * 10 * 16 + 56);
        assert_eq!(kv_bytes_per_token(PipelineKind::Fp32, 16), 128);
        assert_eq!(kv_bytes_per_token(PipelineKind::Fp16, 16), 64);
        assert_eq!(kv_bytes_per_token(PipelineKind::IntAttention, 16), 32);
    }

    #[test]
    fn exaq_stats_accumulate() {
        let mut st = ExaqRunningStats::default();
        assert_eq!(st.sigma(), 0.0);
        // Two batches of {0, 2} → mean 1, var 1.
        st.merge(2.0, 4.0, 2);
        st.merge(2.0, 4.0, 2);
        assert!((st.sigma() - 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "different pipeline kind")]
    fn cross_kind_access_panics() {
        let st = KvState::new(PipelineKind::Fp32, 4);
        let _ = st.as_int8();
    }
}
